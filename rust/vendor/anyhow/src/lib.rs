//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The offline vendor set ships no external crates, so this provides the
//! subset of `anyhow`'s API the workspace actually uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Errors are flattened to a
//! message string at construction time ("context: cause"); no source
//! chain or backtrace is kept.

use std::fmt;

/// A type-erased error: a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error`, which is what makes the blanket conversion below
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failure, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error/none case with `context: cause`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Lazily-evaluated variant of [`Context::context`].
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<u32> {
            let v: u32 = "nope".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn context_prefixes_message() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "), "{e}");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn bails() -> Result<()> {
            bail!("stop {x}", x = 1);
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop 1");
    }
}
