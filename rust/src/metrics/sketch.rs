//! Mergeable Greenwald–Khanna quantile summary (ε-approximate ranks).
//!
//! The streaming [`Recorder`](super::Recorder) needs percentiles over an
//! unbounded latency stream without keeping the samples — a weeks-uptime
//! `serve-http` instance cannot clone O(total samples) per `/metrics`
//! scrape. This is the classic GK01 summary, implemented in-crate (the
//! offline image has no crates.io): a sorted list of `(value, g, Δ)`
//! tuples where `g` is the gap in minimum rank to the previous tuple and
//! `Δ` bounds the rank uncertainty of the tuple itself, so any rank `r`
//! can be answered within `±⌈εn⌉` positions from O(1/ε · log εn) state.
//!
//! Properties relied on elsewhere:
//! - **Deterministic**: no randomization; the same insert sequence always
//!   yields the same summary (epoch-re-base regression tests compare
//!   reports across runs).
//! - **Mergeable**: [`merge`](QuantileSketch::merge) concatenates two
//!   summaries' tuples by value and re-compresses. Rank bounds stay
//!   *valid* after a merge, but the error budget grows to roughly
//!   ε₁ + ε₂ (the well-known GK merge bound) — the cluster folds each
//!   worker once into the system recorder, so merged error stays O(ε·W).
//! - **Bounded**: inserts are buffered ([`BUF_CAP`]) and flushed in one
//!   sorted merge pass; a hard backstop ([`MAX_ENTRIES`]) force-compacts
//!   in the astronomically unlikely case compression ever falls behind,
//!   trading extra ε for a guaranteed memory ceiling.

/// Default rank-error target for recorder series (0.5% of n).
pub const DEFAULT_EPS: f64 = 0.005;

/// Pending inserts held unsorted before a flush pass.
const BUF_CAP: usize = 256;

/// Hard ceiling on stored tuples. GK stays far below this at ε = 0.005
/// (≈ 2–3k tuples at n = 10⁹); the backstop only guards the memory
/// bound, never correctness (rank bounds remain valid, error grows).
const MAX_ENTRIES: usize = 8192;

/// One GK tuple: `v` covers ranks `[rmin, rmin + delta]` where `rmin` is
/// the running sum of `g` up to and including this tuple.
#[derive(Debug, Clone)]
struct Entry {
    v: f64,
    g: u64,
    delta: u64,
}

/// ε-approximate streaming quantile summary.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    eps: f64,
    /// Total observations (including buffered ones).
    n: u64,
    /// Sorted by `v`.
    entries: Vec<Entry>,
    buf: Vec<f64>,
}

impl Default for QuantileSketch {
    fn default() -> QuantileSketch {
        QuantileSketch::new(DEFAULT_EPS)
    }
}

impl QuantileSketch {
    pub fn new(eps: f64) -> QuantileSketch {
        assert!(eps > 0.0 && eps < 0.5, "eps must be in (0, 0.5)");
        QuantileSketch {
            eps,
            n: 0,
            entries: Vec::new(),
            buf: Vec::with_capacity(BUF_CAP),
        }
    }

    /// Observations inserted so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Stored tuples (diagnostic; bounded by [`MAX_ENTRIES`]).
    pub fn entry_count(&self) -> usize {
        self.entries.len() + self.buf.len()
    }

    pub fn insert(&mut self, x: f64) {
        if !x.is_finite() {
            return; // latencies are finite; never poison the summary
        }
        self.n += 1;
        self.buf.push(x);
        if self.buf.len() >= BUF_CAP {
            self.flush();
        }
    }

    /// `⌊2εn⌋` — the GK band capacity at the current stream length.
    fn capacity(&self) -> u64 {
        ((2.0 * self.eps * self.n as f64).floor() as u64).max(1)
    }

    /// Fold the pending buffer into the tuple list (one sorted merge
    /// pass), then compress.
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let mut b = std::mem::take(&mut self.buf);
        b.sort_by(f64::total_cmp);
        let delta_new = self.capacity().saturating_sub(1);
        let old = std::mem::take(&mut self.entries);
        let mut merged: Vec<Entry> = Vec::with_capacity(old.len() + b.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < old.len() || j < b.len() {
            let take_old = j >= b.len() || (i < old.len() && old[i].v <= b[j]);
            if take_old {
                merged.push(old[i].clone());
                i += 1;
            } else {
                // A new observation inserted as the global min or max is
                // rank-certain (Δ = 0); interior inserts carry the full
                // band uncertainty, as in the GK insert rule.
                let is_first = merged.is_empty();
                let is_last = i >= old.len() && j + 1 >= b.len();
                let delta = if is_first || is_last { 0 } else { delta_new };
                merged.push(Entry { v: b[j], g: 1, delta });
                j += 1;
            }
        }
        self.entries = merged;
        self.compress();
    }

    /// GK compress: absorb a tuple into its successor whenever the
    /// combined band `g_i + g_{i+1} + Δ_{i+1} ≤ ⌊2εn⌋` — rank bounds stay
    /// exact, resolution stays within ε.
    fn compress(&mut self) {
        let cap = self.capacity();
        if self.entries.len() < 3 {
            return;
        }
        let old = std::mem::take(&mut self.entries);
        let mut out: Vec<Entry> = Vec::with_capacity(old.len());
        let mut iter = old.into_iter().rev();
        let mut cur = iter.next().expect("len >= 3 checked above");
        for prev in iter {
            if prev.g + cur.g + cur.delta <= cap {
                cur.g += prev.g; // absorb: cur keeps its (larger) value
            } else {
                out.push(cur);
                cur = prev;
            }
        }
        out.push(cur);
        out.reverse();
        self.entries = out;

        // Memory backstop: force pairwise absorption if the summary ever
        // outgrows the hard cap (keeps bounds valid, widens error).
        while self.entries.len() > MAX_ENTRIES {
            let old = std::mem::take(&mut self.entries);
            let mut out = Vec::with_capacity(old.len() / 2 + 1);
            let mut it = old.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(mut b) => {
                        b.g += a.g;
                        out.push(b);
                    }
                    None => out.push(a),
                }
            }
            self.entries = out;
        }
    }

    /// A fully flushed copy: callers answering several quantiles per
    /// scrape take one of these so the buffered inserts are sorted and
    /// merged exactly once, not per query.
    pub fn flushed(&self) -> QuantileSketch {
        let mut c = self.clone();
        c.flush();
        c
    }

    /// Value at quantile `q ∈ [0, 1]`, within `±⌈εn⌉` ranks of the true
    /// order statistic. 0.0 on an empty sketch (matching
    /// [`crate::util::stats::percentile`] on an empty slice).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.buf.is_empty() {
            return Self::query(&self.entries, self.n, self.eps, q);
        }
        // One-off queries on a dirty sketch flush a clone so `&self`
        // callers stay side-effect free; batch callers use `flushed()`.
        let c = self.flushed();
        Self::query(&c.entries, c.n, c.eps, q)
    }

    fn query(entries: &[Entry], n: u64, eps: f64, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let margin = ((eps * n as f64).ceil() as u64).max(1);
        let mut rmin = 0u64;
        for (i, e) in entries.iter().enumerate() {
            rmin += e.g;
            match entries.get(i + 1) {
                Some(nx) => {
                    if rmin + nx.g + nx.delta > rank + margin {
                        return e.v;
                    }
                }
                None => return e.v,
            }
        }
        0.0
    }

    /// Fold another summary into this one. Rank bounds remain valid;
    /// the error budget grows toward `ε_self + ε_other` (standard GK
    /// merge behavior) — callers that merge W summaries should budget
    /// O(ε·W) rank error.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.n == 0 {
            return;
        }
        self.flush();
        let mut o = other.clone();
        o.flush();
        if self.n == 0 {
            self.n = o.n;
            self.entries = o.entries;
            return;
        }
        let a = std::mem::take(&mut self.entries);
        let b = o.entries;
        let mut merged: Vec<Entry> = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() || j < b.len() {
            let take_a = j >= b.len() || (i < a.len() && a[i].v <= b[j].v);
            if take_a {
                merged.push(a[i].clone());
                i += 1;
            } else {
                merged.push(b[j].clone());
                j += 1;
            }
        }
        self.entries = merged;
        self.n += o.n;
        self.compress();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    /// Rank distance between the sketch's answer and the true order
    /// statistic, in fractions of n (0.0 = exact).
    fn rank_error(sorted: &[f64], got: f64, q: f64) -> f64 {
        let n = sorted.len() as f64;
        let below = sorted.iter().filter(|&&x| x < got).count() as f64;
        let at_or_below = sorted.iter().filter(|&&x| x <= got).count() as f64;
        let target = (q * n).ceil().max(1.0);
        // `got` occupies the rank interval [below+1, at_or_below].
        if target < below + 1.0 {
            (below + 1.0 - target) / n
        } else if target > at_or_below {
            (target - at_or_below) / n
        } else {
            0.0
        }
    }

    fn assert_quantiles_close(values: &[f64], sketch: &QuantileSketch, tol: f64) {
        let mut sorted = values.to_vec();
        sorted.sort_by(f64::total_cmp);
        for &q in &[0.01, 0.25, 0.5, 0.9, 0.99] {
            let got = sketch.quantile(q);
            let err = rank_error(&sorted, got, q);
            assert!(
                err <= tol,
                "q={q}: rank error {err:.4} > {tol} (got {got}, exact {})",
                stats::percentile_sorted(&sorted, q * 100.0)
            );
        }
    }

    #[test]
    fn empty_and_single() {
        let mut s = QuantileSketch::default();
        assert_eq!(s.quantile(0.5), 0.0);
        s.insert(42.0);
        assert_eq!(s.count(), 1);
        assert_eq!(s.quantile(0.0), 42.0);
        assert_eq!(s.quantile(0.5), 42.0);
        assert_eq!(s.quantile(1.0), 42.0);
    }

    #[test]
    fn non_finite_inserts_are_ignored() {
        let mut s = QuantileSketch::default();
        s.insert(f64::NAN);
        s.insert(f64::INFINITY);
        assert_eq!(s.count(), 0);
        s.insert(1.0);
        assert_eq!(s.count(), 1);
        assert_eq!(s.quantile(0.5), 1.0);
    }

    #[test]
    fn ascending_stream_within_eps() {
        let mut s = QuantileSketch::default();
        let values: Vec<f64> = (0..50_000).map(|i| i as f64).collect();
        for &v in &values {
            s.insert(v);
        }
        assert_quantiles_close(&values, &s, 0.015);
        assert!(s.entry_count() < MAX_ENTRIES, "summary stays compact");
    }

    #[test]
    fn descending_and_constant_streams() {
        let mut d = QuantileSketch::default();
        let desc: Vec<f64> = (0..30_000).rev().map(|i| i as f64 * 0.5).collect();
        for &v in &desc {
            d.insert(v);
        }
        assert_quantiles_close(&desc, &d, 0.015);

        let mut c = QuantileSketch::default();
        for _ in 0..10_000 {
            c.insert(7.25);
        }
        assert_eq!(c.quantile(0.5), 7.25);
        assert_eq!(c.quantile(0.99), 7.25);
    }

    #[test]
    fn merge_of_sketches_tracks_concatenated_stream() {
        // Heavy-tailed halves: merged summary must answer within the
        // (documented) 2ε merge budget of the concatenated stream.
        let half_a: Vec<f64> = (0..20_000).map(|i| 1.0 / (1.0 + (i % 997) as f64)).collect();
        let half_b: Vec<f64> = (0..20_000).map(|i| 10.0 + (i % 463) as f64).collect();
        let mut a = QuantileSketch::default();
        let mut b = QuantileSketch::default();
        for &v in &half_a {
            a.insert(v);
        }
        for &v in &half_b {
            b.insert(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 40_000);
        let mut all = half_a;
        all.extend_from_slice(&half_b);
        assert_quantiles_close(&all, &a, 0.03);
    }

    #[test]
    fn merge_into_empty_adopts_other() {
        let mut a = QuantileSketch::default();
        let mut b = QuantileSketch::default();
        for i in 0..1000 {
            b.insert(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 1000);
        let vals: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert_quantiles_close(&vals, &a, 0.02);
        // Merging an empty sketch is a no-op.
        let before = a.count();
        a.merge(&QuantileSketch::default());
        assert_eq!(a.count(), before);
    }

    #[test]
    fn determinism_same_stream_same_answers() {
        let mk = || {
            let mut s = QuantileSketch::default();
            for i in 0..10_000u64 {
                s.insert(((i * 2654435761) % 10_007) as f64);
            }
            s
        };
        let (a, b) = (mk(), mk());
        for &q in &[0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), b.quantile(q));
        }
    }
}
