//! Serving metrics: TTFT, TBT, request throughput, GPU utilization
//! (§5.1 "Metrics").
//!
//! The recorder runs in one of two [`RecorderMode`]s:
//!
//! - [`Exact`](RecorderMode::Exact) (the default): per-sample history is
//!   kept and every report statistic is computed from the exact vectors —
//!   what the 13 `benches/fig*.rs` reproductions and the batch engines
//!   need. Memory grows with samples, which is fine for bounded runs.
//! - [`Streaming`](RecorderMode::Streaming): the serving path. Each
//!   latency series keeps only running count/mean/min/max/M2 plus a
//!   mergeable [`QuantileSketch`], so recorder state, `Recorder::merge`,
//!   and every `/metrics` scrape are O(1) in total samples served — a
//!   weeks-uptime `serve-http` instance neither grows memory with
//!   traffic nor clones sample vectors per scrape. Means, counts and
//!   extrema stay exact; p50/p90/p99 are within the sketch's rank-error
//!   budget (property-tested in `tests/metrics_streaming.rs`).
//!
//! Both modes maintain the running state, so recorders of different
//! modes merge soundly (an exact recorder merged with a streaming one
//! degrades to streaming statistics for the merged series).

pub mod sketch;

use crate::request::{Request, SloClass};
use crate::util::stats::Summary;

pub use sketch::QuantileSketch;

/// How a [`Recorder`] stores its latency series.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecorderMode {
    /// Keep every sample; report statistics are exact (benches, batch
    /// engine runs — inherently bounded workloads).
    #[default]
    Exact,
    /// Running aggregates + quantile sketch only; O(1) resident state
    /// and scrape cost regardless of traffic served (serving paths).
    Streaming,
}

/// Duration-weighted running mean (utilization series). Exact in both
/// recorder modes — the weighted mean needs only the two running sums.
#[derive(Debug, Clone, Copy, Default)]
struct WeightedMean {
    weight: f64,
    weighted_sum: f64,
}

impl WeightedMean {
    fn add(&mut self, w: f64, v: f64) {
        self.weight += w;
        self.weighted_sum += w * v;
    }

    fn merge(&mut self, other: &WeightedMean) {
        self.weight += other.weight;
        self.weighted_sum += other.weighted_sum;
    }

    fn mean(&self) -> f64 {
        if self.weight == 0.0 {
            0.0
        } else {
            self.weighted_sum / self.weight
        }
    }
}

/// One latency series (ttft / tbt / e2e): running moments + sketch,
/// plus the exact sample vector when the recorder is in
/// [`RecorderMode::Exact`].
#[derive(Debug, Clone)]
pub struct SeriesStat {
    n: u64,
    mean: f64,
    /// Sum of squared deviations (Welford M2); population std = √(M2/n).
    m2: f64,
    min: f64,
    max: f64,
    sketch: QuantileSketch,
    /// `Some` in exact mode; dropped on conversion to streaming.
    samples: Option<Vec<f64>>,
}

impl SeriesStat {
    fn with_mode(mode: RecorderMode) -> SeriesStat {
        SeriesStat {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sketch: QuantileSketch::default(),
            samples: match mode {
                RecorderMode::Exact => Some(Vec::new()),
                RecorderMode::Streaming => None,
            },
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        // Exactly one percentile source per mode: the sample history is
        // authoritative in exact mode (the sketch is materialized from
        // it lazily if the series ever degrades to streaming), so exact
        // recorders — every bench and batch engine — pay zero sketch
        // maintenance on the hot path.
        match &mut self.samples {
            Some(v) => v.push(x),
            None => self.sketch.insert(x),
        }
    }

    /// Rebuild the sketch from the exact history (insertion order), for
    /// a series about to lose its samples. No-op in streaming mode.
    fn materialize_sketch(&mut self) {
        let Some(v) = &self.samples else { return };
        let mut sk = QuantileSketch::default();
        for &x in v {
            sk.insert(x);
        }
        self.sketch = sk;
    }

    /// Fold another series in. Running state always merges; exact sample
    /// history survives only when both sides have it (otherwise this
    /// series degrades to streaming statistics, its sketch materialized
    /// from the history it is about to drop).
    pub fn merge(&mut self, other: &SeriesStat) {
        if other.n == 0 {
            return;
        }
        // Exact absorbing streaming: degrade — capture our history as a
        // sketch first, then drop it.
        if self.samples.is_some() && other.samples.is_none() {
            self.materialize_sketch();
            self.samples = None;
        }
        if let (Some(s), Some(os)) = (&mut self.samples, &other.samples) {
            // Both exact: the concatenated history stays authoritative
            // (sketches stay unmaintained on this path).
            s.extend_from_slice(os);
        } else {
            // The merged series is streaming: fold the other side's
            // percentile state — its live sketch, or (when the other
            // side is exact and never maintained one) a sketch built
            // from its history.
            match &other.samples {
                Some(os) => {
                    let mut tmp = QuantileSketch::default();
                    for &x in os.iter() {
                        tmp.insert(x);
                    }
                    self.sketch.merge(&tmp);
                }
                None => self.sketch.merge(&other.sketch),
            }
        }
        if self.n == 0 {
            self.n = other.n;
            self.mean = other.mean;
            self.m2 = other.m2;
            self.min = other.min;
            self.max = other.max;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other.mean - self.mean;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.mean += delta * n2 / n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.n += other.n;
    }

    fn drop_samples(&mut self) {
        self.materialize_sketch();
        self.samples = None;
    }

    /// Whether exact per-sample history is present for this series.
    fn has_samples(&self) -> bool {
        self.samples.is_some()
    }

    pub fn summary(&self) -> Summary {
        match &self.samples {
            Some(v) => Summary::of(v),
            None => {
                if self.n == 0 {
                    return Summary::of(&[]);
                }
                // Flush the sketch once for all three quantile queries.
                let sk = self.sketch.flushed();
                Summary {
                    n: self.n as usize,
                    mean: self.mean,
                    std: if self.n < 2 {
                        0.0
                    } else {
                        (self.m2 / self.n as f64).max(0.0).sqrt()
                    },
                    min: self.min,
                    p50: sk.quantile(0.50),
                    p90: sk.quantile(0.90),
                    p99: sk.quantile(0.99),
                    max: self.max,
                }
            }
        }
    }
}

/// Per-SLO-class goodput accounting: request count, SLO-attained count
/// (the DistServe goodput numerator), and the class's own TBT series.
/// Indexed by [`SloClass::index`] inside [`Recorder`]; merges across
/// shards and cluster workers like every other recorder field.
#[derive(Debug, Clone)]
pub struct ClassStat {
    /// Requests of this class completed.
    pub completed: u64,
    /// Of those, requests that met every SLO they declared
    /// ([`Request::slo_attained`]); requests declaring none count as
    /// attained, so goodput degrades to throughput for SLO-free classes.
    pub attained: u64,
    /// Inter-token gaps of this class's requests (per-class tbt-p99).
    pub tbt: SeriesStat,
}

impl ClassStat {
    fn with_mode(mode: RecorderMode) -> ClassStat {
        ClassStat {
            completed: 0,
            attained: 0,
            tbt: SeriesStat::with_mode(mode),
        }
    }

    fn merge(&mut self, other: &ClassStat) {
        self.completed += other.completed;
        self.attained += other.attained;
        self.tbt.merge(&other.tbt);
    }

    /// Attained fraction; `None` until a request of this class finished.
    pub fn attainment(&self) -> Option<f64> {
        if self.completed == 0 {
            None
        } else {
            Some(self.attained as f64 / self.completed as f64)
        }
    }
}

/// Per-run metrics recorder. Engines feed it finished requests and
/// iteration-level utilization samples; benches read the report.
#[derive(Debug, Clone)]
pub struct Recorder {
    mode: RecorderMode,
    sm_util: WeightedMean,
    hbm_util: WeightedMean,
    /// Wall-clock duration of the run (set at finish; cumulative across
    /// engine-clock epochs on the serving path).
    pub duration: f64,
    pub iterations: u64,
    pub spatial_iterations: u64,
    ttft: SeriesStat,
    tbt: SeriesStat,
    e2e: SeriesStat,
    pub completed: u64,
    pub output_tokens: u64,
    pub total_tokens: u64,
    /// Cumulative CPU scheduling overhead, seconds (Fig. 10 claims <1ms
    /// per iteration).
    pub sched_overhead: f64,
    /// Cumulative GPU busy time, seconds (per-device sum; divide by
    /// worker count × duration for average device utilization).
    pub busy_time: f64,
    /// Inter-token gaps checked against a per-request TBT SLO
    /// (requests submitted with `QosSpec::slo_tbt_ms`).
    pub slo_checked: u64,
    /// Of those, gaps that exceeded the request's SLO.
    pub slo_violations: u64,
    /// Requests whose admission matched a non-empty cached prefix
    /// (`kvcache::prefix`).
    pub prefix_hits: u64,
    /// Prompt tokens served from cached prefix blocks instead of being
    /// prefilled.
    pub prefix_cached_tokens: u64,
    /// Cached prefix blocks evicted under KV allocation pressure.
    pub prefix_evictions: u64,
    /// Prompt tokens actually computed by prefill iterations (equals the
    /// prompt volume minus cache hits; the prefix bench's compute-drop
    /// signal).
    pub prefilled_tokens: u64,
    /// Per-SLO-class goodput accounting, indexed by [`SloClass::index`].
    pub classes: [ClassStat; SloClass::COUNT],
    /// KV-pressure recompute preemptions: running requests evicted back
    /// to the waiting queue because an allocation failed.
    pub preemptions: u64,
    /// QoS preemptions: lower-class prefill chunks the duet scheduler
    /// shed because the roofline forecast predicted a latency-class
    /// decode TBT violation (one count per chunk per iteration).
    pub qos_preemptions: u64,
    /// Worker role reconfigurations the cluster planner performed
    /// (static Dynamo-style or elastic goodput-forecast).
    pub reconfigs: u64,
    /// Per-role worker occupancy seconds, in [`ROLE_NAMES`] order
    /// (unified, prefill, decode). Absolute engine time, summed over
    /// workers.
    pub role_occupancy: [f64; 3],
}

/// Labels for [`Recorder::role_occupancy`] /
/// [`Report::role_occupancy`], in index order.
pub const ROLE_NAMES: [&str; 3] = ["unified", "prefill", "decode"];

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::with_mode(RecorderMode::Exact)
    }
}

impl Recorder {
    /// Exact-mode recorder (per-sample history; the batch/bench default).
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Streaming-mode recorder: O(1) resident state in samples served.
    pub fn streaming() -> Recorder {
        Recorder::with_mode(RecorderMode::Streaming)
    }

    pub fn with_mode(mode: RecorderMode) -> Recorder {
        Recorder {
            mode,
            sm_util: WeightedMean::default(),
            hbm_util: WeightedMean::default(),
            duration: 0.0,
            iterations: 0,
            spatial_iterations: 0,
            ttft: SeriesStat::with_mode(mode),
            tbt: SeriesStat::with_mode(mode),
            e2e: SeriesStat::with_mode(mode),
            completed: 0,
            output_tokens: 0,
            total_tokens: 0,
            sched_overhead: 0.0,
            busy_time: 0.0,
            slo_checked: 0,
            slo_violations: 0,
            prefix_hits: 0,
            prefix_cached_tokens: 0,
            prefix_evictions: 0,
            prefilled_tokens: 0,
            classes: std::array::from_fn(|_| ClassStat::with_mode(mode)),
            preemptions: 0,
            qos_preemptions: 0,
            reconfigs: 0,
            role_occupancy: [0.0; 3],
        }
    }

    /// The accounting bucket for one SLO class.
    pub fn class(&self, class: SloClass) -> &ClassStat {
        &self.classes[class.index()]
    }

    pub fn mode(&self) -> RecorderMode {
        self.mode
    }

    /// Switch storage mode. Exact → streaming drops the sample history
    /// (the running state is already maintained). Streaming → exact is
    /// only meaningful on an empty recorder — discarded samples cannot
    /// be recovered, so a non-empty recorder stays streaming.
    pub fn set_mode(&mut self, mode: RecorderMode) {
        if mode == self.mode {
            return;
        }
        match mode {
            RecorderMode::Streaming => {
                self.ttft.drop_samples();
                self.tbt.drop_samples();
                self.e2e.drop_samples();
                for c in &mut self.classes {
                    c.tbt.drop_samples();
                }
                self.mode = RecorderMode::Streaming;
            }
            RecorderMode::Exact => {
                // Reattach empty histories only — iteration-level state
                // (util sums, counters, duration) already recorded must
                // survive the mode switch.
                if self.ttft.n == 0
                    && self.tbt.n == 0
                    && self.e2e.n == 0
                    && self.classes.iter().all(|c| c.tbt.n == 0)
                {
                    self.ttft = SeriesStat::with_mode(RecorderMode::Exact);
                    self.tbt = SeriesStat::with_mode(RecorderMode::Exact);
                    self.e2e = SeriesStat::with_mode(RecorderMode::Exact);
                    for c in &mut self.classes {
                        c.tbt = SeriesStat::with_mode(RecorderMode::Exact);
                    }
                    self.mode = RecorderMode::Exact;
                }
            }
        }
    }

    pub fn record_finished(&mut self, r: &Request) {
        if let Some(t) = r.ttft() {
            self.ttft.push(t);
        }
        for g in r.tbt_samples() {
            self.tbt.push(g);
        }
        if let Some(t) = r.e2e_latency() {
            self.e2e.push(t);
        }
        self.completed += 1;
        self.output_tokens += r.generated;
        self.total_tokens += r.prompt_len + r.generated;
        if let Some(slo) = r.slo_tbt {
            let gaps = r.tbt_samples();
            self.slo_checked += gaps.len() as u64;
            self.slo_violations += gaps.iter().filter(|&&g| g > slo).count() as u64;
        }
        let class = &mut self.classes[r.class.index()];
        class.completed += 1;
        if r.slo_attained() {
            class.attained += 1;
        }
        for g in r.tbt_samples() {
            class.tbt.push(g);
        }
    }

    /// Merge everything another recorder accumulated — iteration-level
    /// state *and* per-request latency series. The cluster engine folds
    /// each worker's recorder into one system-level recorder with this
    /// (`duration` is left to the caller: wall time is a max over
    /// workers, not a sum).
    ///
    /// Every counted field must be included here: a field that exists on
    /// `Recorder` but is skipped silently under-reports multi-worker
    /// runs. In particular the per-request SLO-attainment counts
    /// (`slo_checked`/`slo_violations`) are summed so
    /// [`Report::slo_attainment`] stays correct across cross-worker
    /// merges (regression-tested by `merge_preserves_slo_attainment`).
    /// In streaming mode the merge is O(sketch size), not O(samples) —
    /// the live `/metrics` fold stays O(1) in traffic served.
    pub fn merge(&mut self, other: &Recorder) {
        self.sm_util.merge(&other.sm_util);
        self.hbm_util.merge(&other.hbm_util);
        self.iterations += other.iterations;
        self.spatial_iterations += other.spatial_iterations;
        self.sched_overhead += other.sched_overhead;
        self.busy_time += other.busy_time;
        self.ttft.merge(&other.ttft);
        self.tbt.merge(&other.tbt);
        self.e2e.merge(&other.e2e);
        self.completed += other.completed;
        self.output_tokens += other.output_tokens;
        self.total_tokens += other.total_tokens;
        self.slo_checked += other.slo_checked;
        self.slo_violations += other.slo_violations;
        self.prefix_hits += other.prefix_hits;
        self.prefix_cached_tokens += other.prefix_cached_tokens;
        self.prefix_evictions += other.prefix_evictions;
        self.prefilled_tokens += other.prefilled_tokens;
        for (c, oc) in self.classes.iter_mut().zip(other.classes.iter()) {
            c.merge(oc);
        }
        self.preemptions += other.preemptions;
        self.qos_preemptions += other.qos_preemptions;
        self.reconfigs += other.reconfigs;
        for (a, b) in self.role_occupancy.iter_mut().zip(other.role_occupancy.iter()) {
            *a += b;
        }
        // An exact recorder that absorbed a streaming one lost its
        // sample history for the merged series: keep the mode accessor
        // truthful about what report() will answer from.
        if self.mode == RecorderMode::Exact
            && !(self.ttft.has_samples()
                && self.tbt.has_samples()
                && self.e2e.has_samples()
                && self.classes.iter().all(|c| c.tbt.has_samples()))
        {
            self.mode = RecorderMode::Streaming;
        }
    }

    pub fn record_util(&mut self, weight_s: f64, sm: f64, hbm: f64) {
        if weight_s > 0.0 {
            self.sm_util.add(weight_s, sm.clamp(0.0, 1.0));
            self.hbm_util.add(weight_s, hbm.clamp(0.0, 1.0));
        }
    }

    pub fn report(&self, system: &str) -> Report {
        let tbt = self.tbt.summary();
        let classes = std::array::from_fn(|i| {
            let c = &self.classes[i];
            ClassReport {
                completed: c.completed,
                attained: c.attained,
                tbt_p99: if c.tbt.n == 0 { 0.0 } else { c.tbt.summary().p99 },
            }
        });
        Report {
            system: system.to_string(),
            completed: self.completed,
            duration: self.duration,
            throughput_rps: self.completed as f64 / self.duration.max(1e-9),
            token_throughput: self.total_tokens as f64 / self.duration.max(1e-9),
            ttft: self.ttft.summary(),
            tbt,
            e2e: self.e2e.summary(),
            mean_sm_util: self.sm_util.mean(),
            mean_hbm_util: self.hbm_util.mean(),
            iterations: self.iterations,
            spatial_iterations: self.spatial_iterations,
            sched_overhead_per_iter: self.sched_overhead / self.iterations.max(1) as f64,
            // Identical to `stats::percentile(.., 99.0)` in exact mode
            // (Summary::of computes the same interpolated rank), without
            // a second sort/flush of the series.
            tbt_p99: tbt.p99,
            busy_frac: self.busy_time / self.duration.max(1e-9),
            slo_attainment: if self.slo_checked > 0 {
                Some(1.0 - self.slo_violations as f64 / self.slo_checked as f64)
            } else {
                None
            },
            queue_cap: None,
            engine_epoch: 0,
            engine_uptime_s: 0.0,
            prefix_hits: self.prefix_hits,
            prefix_cached_tokens: self.prefix_cached_tokens,
            prefix_evictions: self.prefix_evictions,
            prefilled_tokens: self.prefilled_tokens,
            classes,
            preemptions: self.preemptions,
            qos_preemptions: self.qos_preemptions,
            reconfigs: self.reconfigs,
            role_occupancy: self.role_occupancy,
        }
    }
}

/// Per-class slice of a [`Report`], indexed by [`SloClass::index`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassReport {
    /// Requests of this class completed.
    pub completed: u64,
    /// Of those, requests that met every SLO they declared.
    pub attained: u64,
    /// p99 inter-token gap of this class (0 when the class produced no
    /// multi-token request).
    pub tbt_p99: f64,
}

impl ClassReport {
    /// Attained fraction; `None` until a request of this class finished.
    pub fn attainment(&self) -> Option<f64> {
        if self.completed == 0 {
            None
        } else {
            Some(self.attained as f64 / self.completed as f64)
        }
    }
}

/// Final run report — the row a bench prints.
#[derive(Debug, Clone)]
pub struct Report {
    pub system: String,
    pub completed: u64,
    pub duration: f64,
    /// Completed requests / end-to-end duration (the paper's "output
    /// request throughput").
    pub throughput_rps: f64,
    /// Total (prompt + output) tokens / duration.
    pub token_throughput: f64,
    pub ttft: Summary,
    pub tbt: Summary,
    pub e2e: Summary,
    pub mean_sm_util: f64,
    pub mean_hbm_util: f64,
    pub iterations: u64,
    pub spatial_iterations: u64,
    pub sched_overhead_per_iter: f64,
    pub tbt_p99: f64,
    /// GPU busy time / wall time (sum across workers; divide by worker
    /// count for the average per-device utilization).
    pub busy_frac: f64,
    /// Fraction of SLO-checked inter-token gaps within their request's
    /// TBT SLO. `None` when no request declared one.
    pub slo_attainment: Option<f64>,
    /// Effective serving-front-end submission-queue bound (`--queue-cap`)
    /// for the run. `None` for batch engine runs, which have no
    /// submission queue.
    pub queue_cap: Option<usize>,
    /// Engine-clock epoch at report time: how many times the topology
    /// re-based its virtual clock after going fully idle (re-arming the
    /// divergence guard). 0 for batch runs, which never re-base.
    pub engine_epoch: u64,
    /// Total engine-clock seconds elapsed across all epochs (monotone
    /// per instance; the serving `/metrics` uptime counter).
    pub engine_uptime_s: f64,
    /// Requests that matched a non-empty cached prefix at admission.
    pub prefix_hits: u64,
    /// Prompt tokens served from the prefix cache instead of prefilled.
    pub prefix_cached_tokens: u64,
    /// Cached prefix blocks evicted under KV pressure.
    pub prefix_evictions: u64,
    /// Prompt tokens actually computed by prefill iterations.
    pub prefilled_tokens: u64,
    /// Per-SLO-class goodput slices, indexed by [`SloClass::index`].
    pub classes: [ClassReport; SloClass::COUNT],
    /// KV-pressure recompute preemptions.
    pub preemptions: u64,
    /// Lower-class prefill chunks shed under latency-class TBT pressure.
    pub qos_preemptions: u64,
    /// Worker role reconfigurations performed by the cluster planner.
    pub reconfigs: u64,
    /// Per-role worker occupancy seconds, in [`ROLE_NAMES`] order.
    pub role_occupancy: [f64; 3],
}

impl Report {
    /// The per-class slice for one SLO class.
    pub fn class(&self, class: SloClass) -> &ClassReport {
        &self.classes[class.index()]
    }

    pub fn header() -> Vec<&'static str> {
        vec![
            "system", "qps", "done", "thpt(req/s)", "tok/s", "ttft-mean(s)", "tbt-mean(ms)",
            "tbt-p99(ms)", "sm-util", "hbm-util",
        ]
    }

    pub fn row(&self, qps: f64) -> Vec<String> {
        vec![
            self.system.clone(),
            format!("{qps:.1}"),
            format!("{}", self.completed),
            format!("{:.2}", self.throughput_rps),
            format!("{:.0}", self.token_throughput),
            format!("{:.2}", self.ttft.mean),
            format!("{:.1}", self.tbt.mean * 1e3),
            format!("{:.1}", self.tbt_p99 * 1e3),
            format!("{:.2}", self.mean_sm_util),
            format!("{:.2}", self.mean_hbm_util),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{Request, SloClass};

    fn finished_request() -> Request {
        let mut r = Request::new(1, 0.0, 100, 3);
        r.advance_prefill(100);
        r.advance_decode(1.0);
        r.advance_decode(1.1);
        r.advance_decode(1.2);
        r
    }

    #[test]
    fn recorder_aggregates() {
        let mut m = Recorder::new();
        m.record_finished(&finished_request());
        m.duration = 2.0;
        m.iterations = 4;
        let rep = m.report("test");
        assert_eq!(rep.completed, 1);
        assert!((rep.throughput_rps - 0.5).abs() < 1e-9);
        assert!((rep.ttft.mean - 1.0).abs() < 1e-9);
        assert!((rep.tbt.mean - 0.1).abs() < 1e-6);
        assert_eq!(m.output_tokens, 3);
        assert_eq!(m.total_tokens, 103);
    }

    #[test]
    fn util_is_duration_weighted() {
        let mut m = Recorder::new();
        m.record_util(1.0, 1.0, 0.0);
        m.record_util(3.0, 0.0, 1.0);
        m.duration = 4.0;
        let rep = m.report("u");
        assert!((rep.mean_sm_util - 0.25).abs() < 1e-9);
        assert!((rep.mean_hbm_util - 0.75).abs() < 1e-9);
    }

    #[test]
    fn merge_combines_requests_and_iteration_state() {
        let mut a = Recorder::new();
        a.record_finished(&finished_request());
        a.record_util(1.0, 0.5, 0.5);
        a.iterations = 3;
        a.busy_time = 1.5;
        let mut b = Recorder::new();
        b.record_finished(&finished_request());
        b.iterations = 2;
        b.busy_time = 0.5;
        a.merge(&b);
        a.duration = 4.0;
        let rep = a.report("m");
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.iterations, 5);
        assert_eq!(a.total_tokens, 206);
        assert!((a.busy_time - 2.0).abs() < 1e-12);
        // latency samples from both recorders survive the merge
        assert_eq!(rep.tbt.n, 4);
    }

    #[test]
    fn slo_attainment_counts_violations() {
        let mut m = Recorder::new();
        let mut r = Request::new(1, 0.0, 10, 3).with_slo_tbt(0.15);
        r.advance_prefill(10);
        r.advance_decode(1.0);
        r.advance_decode(1.1); // gap 0.1: within SLO
        r.advance_decode(1.4); // gap 0.3: violation
        m.record_finished(&r);
        m.duration = 2.0;
        let rep = m.report("s");
        assert_eq!(m.slo_checked, 2);
        assert_eq!(m.slo_violations, 1);
        assert!((rep.slo_attainment.unwrap() - 0.5).abs() < 1e-9);
        // no SLO declared anywhere -> attainment is None
        let rep2 = Recorder::new().report("t");
        assert!(rep2.slo_attainment.is_none());
    }

    #[test]
    fn merge_preserves_slo_attainment() {
        // Two workers with different SLO outcomes: worker A checks 2 gaps
        // (1 violation), worker B checks 2 gaps (0 violations). The
        // merged attainment must be 3/4 — per-request attainment counts
        // survive cross-worker merges.
        let mut a = Recorder::new();
        let mut ra = Request::new(1, 0.0, 10, 3).with_slo_tbt(0.15);
        ra.advance_prefill(10);
        ra.advance_decode(1.0);
        ra.advance_decode(1.1); // gap 0.1: ok
        ra.advance_decode(1.5); // gap 0.4: violation
        a.record_finished(&ra);

        let mut b = Recorder::new();
        let mut rb = Request::new(2, 0.0, 10, 3).with_slo_tbt(0.15);
        rb.advance_prefill(10);
        rb.advance_decode(1.0);
        rb.advance_decode(1.05); // ok
        rb.advance_decode(1.1); // ok
        b.record_finished(&rb);

        a.merge(&b);
        a.duration = 2.0;
        assert_eq!(a.slo_checked, 4);
        assert_eq!(a.slo_violations, 1);
        let rep = a.report("m");
        assert!((rep.slo_attainment.unwrap() - 0.75).abs() < 1e-9);

        // Merging a no-SLO recorder must not erase the counts.
        a.merge(&Recorder::new());
        assert_eq!(a.slo_checked, 4);
        assert_eq!(a.slo_violations, 1);
    }

    #[test]
    fn merge_sums_prefix_counters() {
        let mut a = Recorder::new();
        a.prefix_hits = 2;
        a.prefix_cached_tokens = 96;
        a.prefix_evictions = 1;
        a.prefilled_tokens = 500;
        let mut b = Recorder::new();
        b.prefix_hits = 3;
        b.prefix_cached_tokens = 64;
        b.prefix_evictions = 4;
        b.prefilled_tokens = 700;
        a.merge(&b);
        a.duration = 1.0;
        let rep = a.report("p");
        assert_eq!(rep.prefix_hits, 5);
        assert_eq!(rep.prefix_cached_tokens, 160);
        assert_eq!(rep.prefix_evictions, 5);
        assert_eq!(rep.prefilled_tokens, 1200);
    }

    /// A finished request of `class`, with inter-token gaps of `gap`
    /// seconds and an optional TBT SLO.
    fn classed_request(id: u64, class: SloClass, gap: f64, slo: Option<f64>) -> Request {
        let mut r = Request::new(id, 0.0, 10, 3).with_class(class);
        if let Some(s) = slo {
            r = r.with_slo_tbt(s);
        }
        r.advance_prefill(10);
        r.advance_decode(1.0);
        r.advance_decode(1.0 + gap);
        r.advance_decode(1.0 + 2.0 * gap);
        r
    }

    #[test]
    fn per_class_attainment_and_tbt_recorded() {
        let mut m = Recorder::new();
        m.record_finished(&classed_request(1, SloClass::Latency, 0.02, Some(0.05)));
        m.record_finished(&classed_request(2, SloClass::Latency, 0.10, Some(0.05)));
        m.record_finished(&classed_request(3, SloClass::Batch, 0.30, None));
        m.duration = 2.0;
        let rep = m.report("c");
        let lat = rep.class(SloClass::Latency);
        assert_eq!(lat.completed, 2);
        assert_eq!(lat.attained, 1);
        assert!((lat.attainment().unwrap() - 0.5).abs() < 1e-9);
        assert!(lat.tbt_p99 > 0.0);
        // No declared SLO: batch goodput equals its throughput.
        let batch = rep.class(SloClass::Batch);
        assert_eq!(batch.completed, 1);
        assert_eq!(batch.attained, 1);
        assert_eq!(rep.class(SloClass::Standard).completed, 0);
        assert!(rep.class(SloClass::Standard).attainment().is_none());
    }

    #[test]
    fn merge_preserves_per_class_attainment_streaming() {
        // Two streaming (serving-path) recorders with different per-class
        // outcomes must merge into exact per-class counts — the sharded
        // `/metrics` fold and the cluster worker fold both ride this.
        let mut a = Recorder::streaming();
        a.record_finished(&classed_request(1, SloClass::Latency, 0.02, Some(0.05)));
        a.record_finished(&classed_request(2, SloClass::Batch, 0.40, None));
        a.preemptions = 2;
        a.qos_preemptions = 5;
        a.reconfigs = 1;
        a.role_occupancy = [10.0, 2.0, 0.0];
        let mut b = Recorder::streaming();
        b.record_finished(&classed_request(3, SloClass::Latency, 0.09, Some(0.05)));
        b.record_finished(&classed_request(4, SloClass::Standard, 0.10, None));
        b.preemptions = 1;
        b.qos_preemptions = 3;
        b.reconfigs = 2;
        b.role_occupancy = [1.0, 0.0, 4.0];
        a.merge(&b);
        a.duration = 2.0;
        assert_eq!(a.preemptions, 3);
        assert_eq!(a.qos_preemptions, 8);
        assert_eq!(a.reconfigs, 3);
        assert_eq!(a.role_occupancy, [11.0, 2.0, 4.0]);
        let rep = a.report("m");
        let lat = rep.class(SloClass::Latency);
        assert_eq!(lat.completed, 2);
        assert_eq!(lat.attained, 1);
        // The class TBT series carries both workers' gaps: p99 lands in
        // the violating worker's gap range.
        assert!(lat.tbt_p99 >= 0.08, "p99 {} lost worker B's gaps", lat.tbt_p99);
        assert_eq!(rep.class(SloClass::Standard).completed, 1);
        assert_eq!(rep.class(SloClass::Batch).completed, 1);
        assert_eq!(rep.preemptions, 3);
        assert_eq!(rep.qos_preemptions, 8);
        assert_eq!(rep.reconfigs, 3);
        assert_eq!(rep.role_occupancy, [11.0, 2.0, 4.0]);
        // Per-class completions always partition total completions.
        let sum: u64 = rep.classes.iter().map(|c| c.completed).sum();
        assert_eq!(sum, rep.completed);
    }

    #[test]
    fn report_row_width_matches_header() {
        let mut m = Recorder::new();
        m.duration = 1.0;
        let rep = m.report("x");
        assert_eq!(rep.row(1.0).len(), Report::header().len());
    }

    #[test]
    fn streaming_mode_matches_exact_counts_and_means() {
        let mut exact = Recorder::new();
        let mut stream = Recorder::streaming();
        for i in 0..50u64 {
            let mut r = Request::new(i, 0.0, 16, 3);
            r.advance_prefill(16);
            let base = 0.5 + i as f64 * 0.01;
            r.advance_decode(base);
            r.advance_decode(base + 0.1);
            r.advance_decode(base + 0.25);
            exact.record_finished(&r);
            stream.record_finished(&r);
        }
        exact.duration = 10.0;
        stream.duration = 10.0;
        let re = exact.report("e");
        let rs = stream.report("s");
        assert_eq!(re.completed, rs.completed);
        assert_eq!(re.tbt.n, rs.tbt.n);
        assert!((re.ttft.mean - rs.ttft.mean).abs() < 1e-9);
        assert!((re.tbt.mean - rs.tbt.mean).abs() < 1e-9);
        assert!((re.e2e.mean - rs.e2e.mean).abs() < 1e-9);
        assert_eq!(re.ttft.min, rs.ttft.min);
        assert_eq!(re.ttft.max, rs.ttft.max);
    }

    #[test]
    fn exact_merged_with_streaming_degrades_to_streaming_stats() {
        let mut exact = Recorder::new();
        exact.record_finished(&finished_request());
        let mut stream = Recorder::streaming();
        stream.record_finished(&finished_request());
        exact.merge(&stream);
        exact.duration = 2.0;
        // The merged recorder no longer holds exact history — and says so.
        assert_eq!(exact.mode(), RecorderMode::Streaming);
        // Counts and means still cover both sides after the mode clash.
        let rep = exact.report("mixed");
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.tbt.n, 4);
        assert!((rep.tbt.mean - 0.1).abs() < 1e-9);
    }

    #[test]
    fn set_mode_round_trip() {
        let mut m = Recorder::new();
        m.set_mode(RecorderMode::Streaming);
        assert_eq!(m.mode(), RecorderMode::Streaming);
        // Empty streaming recorder may switch back to exact.
        m.set_mode(RecorderMode::Exact);
        assert_eq!(m.mode(), RecorderMode::Exact);
        // Non-empty streaming recorder stays streaming (history is gone).
        let mut s = Recorder::streaming();
        s.record_finished(&finished_request());
        s.set_mode(RecorderMode::Exact);
        assert_eq!(s.mode(), RecorderMode::Streaming);
    }
}
