//! Serving metrics: TTFT, TBT, request throughput, GPU utilization
//! (§5.1 "Metrics").

use crate::request::Request;
use crate::util::stats::{self, Summary};

/// Per-run metrics recorder. Engines feed it finished requests and
/// iteration-level utilization samples; benches read the report.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    /// (duration-weighted) SM utilization samples: (weight_s, util).
    sm_util: Vec<(f64, f64)>,
    hbm_util: Vec<(f64, f64)>,
    /// Wall-clock duration of the run (set at finish).
    pub duration: f64,
    pub iterations: u64,
    pub spatial_iterations: u64,
    ttft: Vec<f64>,
    tbt: Vec<f64>,
    e2e: Vec<f64>,
    pub completed: u64,
    pub output_tokens: u64,
    pub total_tokens: u64,
    /// Cumulative CPU scheduling overhead, seconds (Fig. 10 claims <1ms
    /// per iteration).
    pub sched_overhead: f64,
    /// Cumulative GPU busy time, seconds (per-device sum; divide by
    /// worker count × duration for average device utilization).
    pub busy_time: f64,
    /// Inter-token gaps checked against a per-request TBT SLO
    /// (requests submitted with `SubmitOptions::slo_tbt_ms`).
    pub slo_checked: u64,
    /// Of those, gaps that exceeded the request's SLO.
    pub slo_violations: u64,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record_finished(&mut self, r: &Request) {
        if let Some(t) = r.ttft() {
            self.ttft.push(t);
        }
        self.tbt.extend(r.tbt_samples());
        if let Some(t) = r.e2e_latency() {
            self.e2e.push(t);
        }
        self.completed += 1;
        self.output_tokens += r.generated;
        self.total_tokens += r.prompt_len + r.generated;
        if let Some(slo) = r.slo_tbt {
            let gaps = r.tbt_samples();
            self.slo_checked += gaps.len() as u64;
            self.slo_violations += gaps.iter().filter(|&&g| g > slo).count() as u64;
        }
    }

    /// Merge everything another recorder accumulated — iteration-level
    /// state *and* per-request latency samples. The cluster engine folds
    /// each worker's recorder into one system-level recorder with this
    /// (`duration` is left to the caller: wall time is a max over
    /// workers, not a sum).
    ///
    /// Every counted field must be included here: a field that exists on
    /// `Recorder` but is skipped silently under-reports multi-worker
    /// runs. In particular the per-request SLO-attainment counts
    /// (`slo_checked`/`slo_violations`) are summed so
    /// [`Report::slo_attainment`] stays correct across cross-worker
    /// merges (regression-tested by `merge_preserves_slo_attainment`).
    pub fn merge(&mut self, other: &Recorder) {
        self.sm_util.extend_from_slice(&other.sm_util);
        self.hbm_util.extend_from_slice(&other.hbm_util);
        self.iterations += other.iterations;
        self.spatial_iterations += other.spatial_iterations;
        self.sched_overhead += other.sched_overhead;
        self.busy_time += other.busy_time;
        self.ttft.extend_from_slice(&other.ttft);
        self.tbt.extend_from_slice(&other.tbt);
        self.e2e.extend_from_slice(&other.e2e);
        self.completed += other.completed;
        self.output_tokens += other.output_tokens;
        self.total_tokens += other.total_tokens;
        self.slo_checked += other.slo_checked;
        self.slo_violations += other.slo_violations;
    }

    pub fn record_util(&mut self, weight_s: f64, sm: f64, hbm: f64) {
        if weight_s > 0.0 {
            self.sm_util.push((weight_s, sm.clamp(0.0, 1.0)));
            self.hbm_util.push((weight_s, hbm.clamp(0.0, 1.0)));
        }
    }

    fn weighted_mean(samples: &[(f64, f64)]) -> f64 {
        let w: f64 = samples.iter().map(|(w, _)| w).sum();
        if w == 0.0 {
            return 0.0;
        }
        samples.iter().map(|(w, v)| w * v).sum::<f64>() / w
    }

    pub fn report(&self, system: &str) -> Report {
        Report {
            system: system.to_string(),
            completed: self.completed,
            duration: self.duration,
            throughput_rps: self.completed as f64 / self.duration.max(1e-9),
            token_throughput: self.total_tokens as f64 / self.duration.max(1e-9),
            ttft: Summary::of(&self.ttft),
            tbt: Summary::of(&self.tbt),
            e2e: Summary::of(&self.e2e),
            mean_sm_util: Self::weighted_mean(&self.sm_util),
            mean_hbm_util: Self::weighted_mean(&self.hbm_util),
            iterations: self.iterations,
            spatial_iterations: self.spatial_iterations,
            sched_overhead_per_iter: self.sched_overhead / self.iterations.max(1) as f64,
            tbt_p99: stats::percentile(&self.tbt, 99.0),
            busy_frac: self.busy_time / self.duration.max(1e-9),
            slo_attainment: if self.slo_checked > 0 {
                Some(1.0 - self.slo_violations as f64 / self.slo_checked as f64)
            } else {
                None
            },
            queue_cap: None,
        }
    }
}

/// Final run report — the row a bench prints.
#[derive(Debug, Clone)]
pub struct Report {
    pub system: String,
    pub completed: u64,
    pub duration: f64,
    /// Completed requests / end-to-end duration (the paper's "output
    /// request throughput").
    pub throughput_rps: f64,
    /// Total (prompt + output) tokens / duration.
    pub token_throughput: f64,
    pub ttft: Summary,
    pub tbt: Summary,
    pub e2e: Summary,
    pub mean_sm_util: f64,
    pub mean_hbm_util: f64,
    pub iterations: u64,
    pub spatial_iterations: u64,
    pub sched_overhead_per_iter: f64,
    pub tbt_p99: f64,
    /// GPU busy time / wall time (sum across workers; divide by worker
    /// count for the average per-device utilization).
    pub busy_frac: f64,
    /// Fraction of SLO-checked inter-token gaps within their request's
    /// TBT SLO. `None` when no request declared one.
    pub slo_attainment: Option<f64>,
    /// Effective serving-front-end submission-queue bound (`--queue-cap`)
    /// for the run. `None` for batch engine runs, which have no
    /// submission queue.
    pub queue_cap: Option<usize>,
}

impl Report {
    pub fn header() -> Vec<&'static str> {
        vec![
            "system", "qps", "done", "thpt(req/s)", "tok/s", "ttft-mean(s)", "tbt-mean(ms)",
            "tbt-p99(ms)", "sm-util", "hbm-util",
        ]
    }

    pub fn row(&self, qps: f64) -> Vec<String> {
        vec![
            self.system.clone(),
            format!("{qps:.1}"),
            format!("{}", self.completed),
            format!("{:.2}", self.throughput_rps),
            format!("{:.0}", self.token_throughput),
            format!("{:.2}", self.ttft.mean),
            format!("{:.1}", self.tbt.mean * 1e3),
            format!("{:.1}", self.tbt_p99 * 1e3),
            format!("{:.2}", self.mean_sm_util),
            format!("{:.2}", self.mean_hbm_util),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Request;

    fn finished_request() -> Request {
        let mut r = Request::new(1, 0.0, 100, 3);
        r.advance_prefill(100);
        r.advance_decode(1.0);
        r.advance_decode(1.1);
        r.advance_decode(1.2);
        r
    }

    #[test]
    fn recorder_aggregates() {
        let mut m = Recorder::new();
        m.record_finished(&finished_request());
        m.duration = 2.0;
        m.iterations = 4;
        let rep = m.report("test");
        assert_eq!(rep.completed, 1);
        assert!((rep.throughput_rps - 0.5).abs() < 1e-9);
        assert!((rep.ttft.mean - 1.0).abs() < 1e-9);
        assert!((rep.tbt.mean - 0.1).abs() < 1e-6);
        assert_eq!(m.output_tokens, 3);
        assert_eq!(m.total_tokens, 103);
    }

    #[test]
    fn util_is_duration_weighted() {
        let mut m = Recorder::new();
        m.record_util(1.0, 1.0, 0.0);
        m.record_util(3.0, 0.0, 1.0);
        m.duration = 4.0;
        let rep = m.report("u");
        assert!((rep.mean_sm_util - 0.25).abs() < 1e-9);
        assert!((rep.mean_hbm_util - 0.75).abs() < 1e-9);
    }

    #[test]
    fn merge_combines_requests_and_iteration_state() {
        let mut a = Recorder::new();
        a.record_finished(&finished_request());
        a.record_util(1.0, 0.5, 0.5);
        a.iterations = 3;
        a.busy_time = 1.5;
        let mut b = Recorder::new();
        b.record_finished(&finished_request());
        b.iterations = 2;
        b.busy_time = 0.5;
        a.merge(&b);
        a.duration = 4.0;
        let rep = a.report("m");
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.iterations, 5);
        assert_eq!(a.total_tokens, 206);
        assert!((a.busy_time - 2.0).abs() < 1e-12);
        // latency samples from both recorders survive the merge
        assert_eq!(rep.tbt.n, 4);
    }

    #[test]
    fn slo_attainment_counts_violations() {
        let mut m = Recorder::new();
        let mut r = Request::new(1, 0.0, 10, 3).with_slo_tbt(0.15);
        r.advance_prefill(10);
        r.advance_decode(1.0);
        r.advance_decode(1.1); // gap 0.1: within SLO
        r.advance_decode(1.4); // gap 0.3: violation
        m.record_finished(&r);
        m.duration = 2.0;
        let rep = m.report("s");
        assert_eq!(m.slo_checked, 2);
        assert_eq!(m.slo_violations, 1);
        assert!((rep.slo_attainment.unwrap() - 0.5).abs() < 1e-9);
        // no SLO declared anywhere -> attainment is None
        let rep2 = Recorder::new().report("t");
        assert!(rep2.slo_attainment.is_none());
    }

    #[test]
    fn merge_preserves_slo_attainment() {
        // Two workers with different SLO outcomes: worker A checks 2 gaps
        // (1 violation), worker B checks 2 gaps (0 violations). The
        // merged attainment must be 3/4 — per-request attainment counts
        // survive cross-worker merges.
        let mut a = Recorder::new();
        let mut ra = Request::new(1, 0.0, 10, 3).with_slo_tbt(0.15);
        ra.advance_prefill(10);
        ra.advance_decode(1.0);
        ra.advance_decode(1.1); // gap 0.1: ok
        ra.advance_decode(1.5); // gap 0.4: violation
        a.record_finished(&ra);

        let mut b = Recorder::new();
        let mut rb = Request::new(2, 0.0, 10, 3).with_slo_tbt(0.15);
        rb.advance_prefill(10);
        rb.advance_decode(1.0);
        rb.advance_decode(1.05); // ok
        rb.advance_decode(1.1); // ok
        b.record_finished(&rb);

        a.merge(&b);
        a.duration = 2.0;
        assert_eq!(a.slo_checked, 4);
        assert_eq!(a.slo_violations, 1);
        let rep = a.report("m");
        assert!((rep.slo_attainment.unwrap() - 0.75).abs() < 1e-9);

        // Merging a no-SLO recorder must not erase the counts.
        a.merge(&Recorder::new());
        assert_eq!(a.slo_checked, 4);
        assert_eq!(a.slo_violations, 1);
    }

    #[test]
    fn report_row_width_matches_header() {
        let mut m = Recorder::new();
        m.duration = 1.0;
        let rep = m.report("x");
        assert_eq!(rep.row(1.0).len(), Report::header().len());
    }
}
