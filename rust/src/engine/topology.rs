//! The serving-topology seam: one request lifecycle over one worker or
//! many.
//!
//! [`ServingTopology`] is the contract between the serving front-end
//! ([`crate::server::ServerCore`]) and whatever executes requests under
//! it. Two implementations exist:
//!
//! - [`EngineCore`] — a single worker (one GPU group, one backend). The
//!   serving path over a sim backend is property-tested identical to
//!   [`super::SimEngine`].
//! - [`ClusterEngine`](super::ClusterEngine) — N workers behind the
//!   [`Router`](super::router::Router) seam, advanced by the min-clock
//!   discrete-event loop, fed incrementally through
//!   [`inject`](super::ClusterEngine::inject) /
//!   [`step_next`](super::ClusterEngine::step_next).
//!
//! The front-end owns submission ordering (arrival time + priority) and
//! token streams; the topology owns routing, clocks, execution, and
//! metrics. The contract that keeps live serving equal to batch replay:
//!
//! - `inject` hands over a request whose `arrival` is already due
//!   (`arrival <= clock()`); the topology routes and enqueues it exactly
//!   as the batch path would at that instant.
//! - `step` advances the topology by one event. `next_arrival` is the
//!   earliest arrival the caller has *not yet injected*, so idle workers
//!   can jump to it instead of parking — without it, a live topology
//!   would idle past future submissions that the batch loop (which holds
//!   the whole arrival stream) would have jumped to.
//! - `pump` visits every request that may carry new tokens, paired with
//!   the backend holding its token values; newly finished requests are
//!   visited exactly once with `finished = true`.

use crate::metrics::{Recorder, RecorderMode, Report};
use crate::request::{Request, RequestId};

use super::backend::ExecutionBackend;
use super::cluster::ClusterEngine;
use super::core::{CoreStep, EngineCore};

/// Clock nudge when a scheduler idles while admitted work remains (a
/// defensive should-not-happen state): keeps the clock moving so the
/// `max_engine_time` divergence guard can trip instead of the caller
/// livelocking. Matches the cluster loop's parking epsilon, and
/// [`super::SimEngine::step`] applies the identical nudge so the
/// serving-path ≡ simulation property holds even in this state.
pub(crate) const IDLE_NUDGE: f64 = 1e-3;

/// Point-in-time load signals for submit-time shard routing: what the
/// sharded front door feeds the [`Router`](super::router::Router) seam
/// as a [`RouteCandidate`](super::router::RouteCandidate), at topology
/// granularity. O(1) for a single core (the incremental counters from
/// the scheduling hot path); O(workers) for a cluster.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TopologyLoad {
    /// Requests queued but not yet admitted.
    pub queue_len: usize,
    /// Remaining prompt + output tokens across all queues.
    pub outstanding_tokens: u64,
    /// Free KV-cache tokens.
    pub kv_free_tokens: u64,
}

/// What one [`ServingTopology::step`] call did.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologyStep {
    /// An event ran (an iteration executed, or a clock advanced toward
    /// the next event); streams may carry new tokens.
    Progressed,
    /// The head waiting request can never be admitted (prompt exceeds
    /// KV) and was dropped; its stream must be closed.
    Dropped(RequestId),
    /// The epoch-local clock passed the divergence horizon
    /// (`cfg.max_engine_time`): all queued and in-flight work was
    /// drained. The ids are every request that was discarded; their
    /// streams must be closed.
    Diverged(Vec<RequestId>),
    /// No queued or running work remains and no future arrival was
    /// hinted: the topology is fully drained.
    Exhausted,
}

/// The seam [`crate::server::ServerCore`] dispatches through — submit,
/// stream, cancel and drain work identically whether the back end is one
/// worker or an N-worker cluster.
pub trait ServingTopology {
    /// Report label (policy/backend for a single core, system name for a
    /// cluster).
    fn label(&self) -> String;

    /// The arrival reference clock, *epoch-local*: requests with
    /// (epoch-local) `arrival <= clock()` are due for
    /// [`inject`](Self::inject). For a cluster this is the minimum
    /// worker clock (the time of the next event). Absolute engine time
    /// is `epoch_offset() + clock()` — callers that hold arrivals in
    /// absolute coordinates (the serving front-end) convert with
    /// [`epoch_offset`](Self::epoch_offset).
    fn clock(&self) -> f64;

    /// Engine-clock epochs completed (clock re-bases). 0 until the
    /// topology first re-bases.
    fn epoch(&self) -> u64;

    /// Engine-clock seconds accumulated in previous epochs; the base of
    /// the current epoch on the absolute timeline.
    fn epoch_offset(&self) -> f64;

    /// The per-epoch divergence horizon in effect
    /// (`cfg.max_engine_time`).
    fn max_engine_time(&self) -> f64;

    /// Re-base the virtual clock to a new epoch if the topology is fully
    /// idle (no queued, running, or in-transfer work anywhere) and the
    /// current epoch consumed enough of its divergence horizon. Re-arms
    /// the divergence guard; absolute time stays monotone via
    /// [`epoch_offset`](Self::epoch_offset). Idempotent — returns
    /// whether a re-base happened.
    fn rebase_if_idle(&mut self) -> bool;

    /// Unconditional re-base (no horizon threshold) when the topology is
    /// fully idle and any clock progress exists. The serving front-end
    /// uses this before an idle jump that would otherwise overshoot the
    /// divergence horizon — together with the submit bound
    /// (`arrival ≤ uptime + max_engine_time`) it guarantees an accepted
    /// arrival can never trip the guard by itself. Returns whether a
    /// re-base happened.
    fn rebase_now(&mut self) -> bool;

    /// Switch every recorder under this topology (and the corresponding
    /// finished-request retention) between exact per-sample history and
    /// O(1) streaming aggregates. Serving front-ends select
    /// [`RecorderMode::Streaming`] at construction.
    fn set_recorder_mode(&mut self, mode: RecorderMode);

    /// Accept one due request (route it, enqueue it).
    fn inject(&mut self, req: Request);

    /// Advance by one event; `next_arrival` hints the earliest
    /// not-yet-injected arrival so idle workers can jump to it.
    fn step(&mut self, next_arrival: Option<f64>) -> TopologyStep;

    /// Any queued or in-flight work anywhere?
    fn has_work(&self) -> bool;

    /// Accepted-but-not-yet-admitted requests (the backpressure signal).
    fn queued(&self) -> usize;

    /// Remove a request at any stage (queued, running, or in transfer
    /// between workers). Returns false when it is unknown.
    fn cancel(&mut self, id: RequestId) -> bool;

    /// Hard context bound, when every backend underneath has one.
    fn max_context(&self) -> Option<u64>;

    /// Reclaim backend-side state for `id` on every backend that might
    /// hold it (called once a stream closes).
    fn release(&mut self, id: RequestId);

    /// Account requests the *caller* discarded without injecting them
    /// (divergence drain of a front-end submission queue).
    fn add_dropped(&mut self, n: u64);

    /// Visit every request that may have produced tokens since the last
    /// call — running, in transfer, and newly finished — with the
    /// backend that holds its token values. Requests arrive in batched
    /// slices (one per worker queue), not per-request closure calls; a
    /// slice with the flag set holds newly finished requests, each
    /// visited exactly once across calls.
    fn pump(&mut self, f: &mut dyn FnMut(&[Request], &mut dyn ExecutionBackend, bool));

    /// Fold per-worker recorder state into one drain-time [`Recorder`],
    /// `duration` set to the activity horizon. Destructive — the cluster
    /// implementation retires worker history while folding; call once,
    /// at drain. [`fold_report`](Self::fold_report) renders it into a
    /// [`Report`]; a sharded front door instead merges N of these across
    /// engines (via [`Recorder::merge`]) exactly as the cluster merges
    /// its workers here.
    fn drain_recorder(&mut self) -> Recorder;

    /// Cheap submit-time load signals for shard routing.
    fn load(&self) -> TopologyLoad;

    /// Fold per-worker state into the final merged [`Report`]: the
    /// drain-time recorder rendered under this topology's label, stamped
    /// with the epoch counter and absolute engine uptime.
    fn fold_report(&mut self) -> Report {
        let label = self.label();
        let epoch = self.epoch();
        let uptime = self.epoch_offset() + self.clock();
        let mut rep = self.drain_recorder().report(&label);
        rep.engine_epoch = epoch;
        rep.engine_uptime_s = uptime;
        rep
    }

    /// Non-destructive recorder snapshot for live metrics endpoints:
    /// everything recorded so far, merged across workers, with
    /// `duration` set to the current activity horizon. Unlike
    /// [`fold_report`](Self::fold_report) this must not retire any
    /// state — it can be called repeatedly mid-run.
    fn snapshot_recorder(&self) -> Recorder;

    /// Cross-worker invariants (used on the drain path and by tests).
    fn check_invariants(&self) -> Result<(), String>;

    /// Downcast for single-core-specific inspection.
    fn as_engine(&self) -> Option<&EngineCore> {
        None
    }

    /// Downcast for cluster-specific inspection.
    fn as_cluster(&self) -> Option<&ClusterEngine> {
        None
    }
}

impl ServingTopology for EngineCore {
    fn label(&self) -> String {
        format!("{}+{}", self.policy_name(), self.backend_name())
    }

    fn clock(&self) -> f64 {
        self.clock
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn epoch_offset(&self) -> f64 {
        self.epoch_offset
    }

    fn max_engine_time(&self) -> f64 {
        self.cfg.max_engine_time
    }

    fn rebase_if_idle(&mut self) -> bool {
        self.rebase_epoch()
    }

    fn rebase_now(&mut self) -> bool {
        if self.has_local_work() || self.clock <= 0.0 {
            return false;
        }
        self.shift_clock(self.clock);
        true
    }

    fn set_recorder_mode(&mut self, mode: RecorderMode) {
        self.metrics.set_mode(mode);
        self.trim_finished = mode == RecorderMode::Streaming;
    }

    fn inject(&mut self, req: Request) {
        EngineCore::inject(self, req);
    }

    fn step(&mut self, next_arrival: Option<f64>) -> TopologyStep {
        if self.clock > self.cfg.max_engine_time {
            let mut victims: Vec<RequestId> = self.waiting.iter().map(|r| r.id).collect();
            victims.extend(self.running.iter().map(|r| r.id));
            self.drain_diverged();
            return TopologyStep::Diverged(victims);
        }
        match self.step_once(next_arrival.is_none()) {
            CoreStep::Executed => TopologyStep::Progressed,
            CoreStep::DroppedHead(id) => TopologyStep::Dropped(id),
            CoreStep::Idle => match next_arrival {
                // Nothing schedulable before the next submission: jump.
                Some(t) => {
                    self.clock = self.clock.max(t);
                    TopologyStep::Progressed
                }
                // Scheduler idled with admitted work (should not happen);
                // nudge the clock — same defence as the cluster loop — so
                // the divergence guard eventually trips instead of the
                // caller spinning forever at a frozen clock.
                None if !self.running.is_empty() => {
                    self.clock += IDLE_NUDGE;
                    TopologyStep::Progressed
                }
                None => {
                    // Fully idle with no future arrival hinted: the only
                    // safe moment to re-base the epoch clock.
                    self.rebase_epoch();
                    TopologyStep::Exhausted
                }
            },
        }
    }

    fn has_work(&self) -> bool {
        self.has_local_work()
    }

    fn queued(&self) -> usize {
        self.queue_len()
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        self.cancel_local(id)
    }

    fn max_context(&self) -> Option<u64> {
        self.backend.max_context()
    }

    fn release(&mut self, id: RequestId) {
        self.backend.release(id);
    }

    fn add_dropped(&mut self, n: u64) {
        self.dropped += n;
    }

    fn pump(&mut self, f: &mut dyn FnMut(&[Request], &mut dyn ExecutionBackend, bool)) {
        self.pump_local(f);
    }

    fn drain_recorder(&mut self) -> Recorder {
        self.metrics.duration = self.total_time();
        self.metrics.clone()
    }

    fn load(&self) -> TopologyLoad {
        // All three counters are maintained incrementally on the
        // scheduling hot path — a shard's load board can be refreshed
        // every engine-loop iteration for free.
        TopologyLoad {
            queue_len: self.queue_len(),
            outstanding_tokens: self.outstanding_tokens(),
            kv_free_tokens: self.kv_free_tokens(),
        }
    }

    fn snapshot_recorder(&self) -> Recorder {
        let mut rec = self.metrics.clone();
        rec.duration = self.total_time();
        rec
    }

    fn check_invariants(&self) -> Result<(), String> {
        EngineCore::check_invariants(self)
    }

    fn as_engine(&self) -> Option<&EngineCore> {
        Some(self)
    }
}
