//! Per-iteration event log (drives the Fig. 10 latency-breakdown bench).

/// What kind of iteration executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IterKind {
    /// Temporal sharing: one mixed batch on the full device.
    Aggregated,
    /// Spatial sharing: decode on `decode_tpcs`, prefill on
    /// `prefill_tpcs`, `k` look-ahead decode steps.
    Spatial {
        decode_tpcs: u32,
        prefill_tpcs: u32,
        k: u32,
    },
}

/// One engine iteration.
#[derive(Debug, Clone, Copy)]
pub struct IterEvent {
    pub t_start: f64,
    pub duration: f64,
    pub kind: IterKind,
    pub n_decode: u32,
    pub prefill_tokens: u64,
    /// Measured CPU scheduling time for this iteration (real wall time of
    /// the scheduler + optimizer — the paper claims <1 ms).
    pub sched_s: f64,
    pub sm_util: f64,
    pub hbm_util: f64,
}

impl IterEvent {
    pub fn describe(&self) -> String {
        match self.kind {
            IterKind::Aggregated => format!(
                "[{:8.3}s +{:6.1}ms] AGG   dec={:<4} pre_tok={:<6} sched={:.2}ms sm={:.2} hbm={:.2}",
                self.t_start,
                self.duration * 1e3,
                self.n_decode,
                self.prefill_tokens,
                self.sched_s * 1e3,
                self.sm_util,
                self.hbm_util
            ),
            IterKind::Spatial {
                decode_tpcs,
                prefill_tpcs,
                k,
            } => format!(
                "[{:8.3}s +{:6.1}ms] SPLIT dec={:<4} pre_tok={:<6} sched={:.2}ms sm={:.2} hbm={:.2} | Sd={decode_tpcs} Sp={prefill_tpcs} k={k}",
                self.t_start,
                self.duration * 1e3,
                self.n_decode,
                self.prefill_tokens,
                self.sched_s * 1e3,
                self.sm_util,
                self.hbm_util
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn describe_formats_both_kinds() {
        let agg = IterEvent {
            t_start: 1.0,
            duration: 0.05,
            kind: IterKind::Aggregated,
            n_decode: 8,
            prefill_tokens: 4096,
            sched_s: 0.0003,
            sm_util: 0.8,
            hbm_util: 0.3,
        };
        assert!(agg.describe().contains("AGG"));
        let sp = IterEvent {
            kind: IterKind::Spatial {
                decode_tpcs: 18,
                prefill_tpcs: 48,
                k: 5,
            },
            ..agg
        };
        let d = sp.describe();
        assert!(d.contains("SPLIT") && d.contains("Sd=18") && d.contains("k=5"));
    }
}
