//! PD-disaggregated serving (the Dynamo baseline).
//!
//! Topology: `P` prefill workers + `D` decode workers, each a whole GPU
//! (device-granular partitioning — the coarseness DuetServe's SM-granular
//! approach avoids). Requests are routed to a prefill worker at arrival
//! time and chunk-prefilled there through the shared core under a
//! `PrefillOnlyScheduler`; the KV cache transfers over NVLink P2P
//! (NIXL-style) through the cluster's transfer queue, and each ready
//! transfer is routed to a decode worker through the same pluggable
//! `Router` the arrivals use, joining that worker's continuous batch.
//!
//! This is a role configuration of [`ClusterEngine`] — the event loop,
//! divergence guard, transfer queue, and the optional Dynamo-planner
//! emulation (role switches that preempt in-flight requests and cost
//! `reconfig_s` of downtime, Table 3) all live there.

use std::ops::{Deref, DerefMut};

use crate::config::{GpuSpec, ServingConfig};
use crate::metrics::Report;
use crate::workload::Workload;

use super::cluster::ClusterEngine;
use super::router::LeastOutstandingRouter;

/// Disaggregated engine: prefill/decode role workers over the cluster
/// core.
pub struct DisaggEngine {
    pub cluster: ClusterEngine,
}

impl Deref for DisaggEngine {
    type Target = ClusterEngine;

    fn deref(&self) -> &ClusterEngine {
        &self.cluster
    }
}

impl DerefMut for DisaggEngine {
    fn deref_mut(&mut self) -> &mut ClusterEngine {
        &mut self.cluster
    }
}

impl DisaggEngine {
    pub fn new(cfg: ServingConfig, prefill_gpus: u32, decode_gpus: u32, seed: u64) -> DisaggEngine {
        let gpu = cfg.gpu.clone();
        Self::new_hetero(cfg, prefill_gpus, gpu.clone(), decode_gpus, gpu, seed)
    }

    /// Heterogeneous topology (Appendix B future work): prefill workers on
    /// `prefill_gpu` parts, decode workers on `decode_gpu` parts — e.g.
    /// compute-optimized prefill + memory-optimized decode.
    pub fn new_hetero(
        cfg: ServingConfig,
        prefill_gpus: u32,
        prefill_gpu: GpuSpec,
        decode_gpus: u32,
        decode_gpu: GpuSpec,
        seed: u64,
    ) -> DisaggEngine {
        DisaggEngine {
            cluster: ClusterEngine::disagg_hetero(
                cfg,
                prefill_gpus,
                prefill_gpu,
                decode_gpus,
                decode_gpu,
                seed,
                // Prefill queues are per-worker now; least-outstanding
                // routing approximates the old shared-FCFS-queue work
                // conservation.
                Box::new(LeastOutstandingRouter::new()),
            ),
        }
    }

    pub fn run(&mut self, workload: Workload) -> Report {
        self.cluster.run(workload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::workload::synthetic::fixed_workload;

    fn cfg() -> ServingConfig {
        ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        })
    }

    #[test]
    fn disagg_1p1d_completes_workload() {
        let mut e = DisaggEngine::new(cfg(), 1, 1, 1);
        let rep = e.run(fixed_workload(20, 4000, 32, 3.0, 1));
        assert_eq!(rep.completed, 20);
        assert_eq!(e.dropped, 0);
        assert!(rep.system.contains("1P1D"));
    }

    #[test]
    fn disagg_tbt_is_stable_but_throughput_suffers() {
        // Fig. 2's shape: the decode worker never sees prefill, so TBT is
        // low; but only one GPU prefills, so throughput < 2-replica agg.
        let w = fixed_workload(30, 8000, 200, 6.0, 2);
        let mut dis = DisaggEngine::new(cfg(), 1, 1, 1);
        let rd = dis.run(w.clone());

        let acfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut agg = crate::engine::ReplicatedEngine::new(acfg, 2, 1);
        let ra = agg.run(w);

        assert!(
            rd.tbt.mean < ra.tbt.mean,
            "disagg tbt {} should be below agg {}",
            rd.tbt.mean,
            ra.tbt.mean
        );
        assert!(
            ra.token_throughput > 1.2 * rd.token_throughput,
            "agg tokens/s {} should beat disagg {}",
            ra.token_throughput,
            rd.token_throughput
        );
    }

    #[test]
    fn transfers_delay_decode_start() {
        let mut e = DisaggEngine::new(cfg(), 1, 1, 1);
        let rep = e.run(fixed_workload(5, 8000, 16, 1.0, 3));
        assert_eq!(rep.completed, 5);
        // TTFT comes from prefill; with low load it should be sane.
        assert!(rep.ttft.mean > 0.05, "prefill takes real time");
    }

    #[test]
    fn reconfigurable_planner_fires_under_imbalance() {
        let mut e = DisaggEngine::new(cfg(), 2, 2, 1);
        e.reconfigurable = true;
        e.planner_interval = 10.0;
        // Prefill-heavy flood: planner should convert a decode worker.
        let rep = e.run(fixed_workload(300, 12_000, 8, 12.0, 4));
        assert!(rep.completed > 0);
        assert!(e.reconfigs > 0, "planner should reconfigure under flood");
    }

    #[test]
    fn hetero_topology_runs_distinct_gpu_parts() {
        let mut e = DisaggEngine::new_hetero(
            cfg(),
            1,
            GpuSpec::compute_optimized(),
            1,
            GpuSpec::memory_optimized(),
            1,
        );
        let rep = e.run(fixed_workload(12, 4000, 24, 2.0, 5));
        assert_eq!(rep.completed + e.dropped, 12);
        assert_eq!(e.n_workers(), 2);
        e.check_invariants().unwrap();
    }
}
