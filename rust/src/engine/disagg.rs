//! PD-disaggregated serving (the Dynamo baseline).
//!
//! Topology: `P` prefill workers + `D` decode workers, each a whole GPU
//! (device-granular partitioning — the coarseness DuetServe's SM-granular
//! approach avoids). Requests prefill FCFS on a prefill worker, the KV
//! cache transfers over NVLink P2P (NIXL-style), then the request joins a
//! decode worker's continuous batch.
//!
//! For Table 3, the engine optionally emulates Dynamo's planner: when the
//! queue imbalance persists, a worker switches roles — preempting its
//! in-flight requests and going offline for `reconfig_s` (model reload +
//! KV rebuild, ~40 s in the paper) before serving in the new role.

use std::collections::VecDeque;

use crate::config::ServingConfig;
use crate::kvcache::KvManager;
use crate::metrics::{Recorder, Report};
use crate::model::AttnShape;
use crate::request::{Phase, Request};
use crate::roofline::BatchShape;
use crate::sim::{DispatchMode, GpuExecutor};
use crate::workload::Workload;

const MAX_SIM_TIME: f64 = 3.0e4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    Prefill,
    Decode,
}

struct Worker {
    role: Role,
    clock: f64,
    executor: GpuExecutor,
    kv: KvManager,
    /// Decode-role: requests currently decoding.
    running: Vec<Request>,
    /// Offline until this time (role reconfiguration).
    offline_until: f64,
    busy: f64,
}

/// A request whose prefill finished and whose KV is in flight to a decode
/// worker.
struct Transfer {
    request: Request,
    ready_at: f64,
}

/// Disaggregated engine.
pub struct DisaggEngine {
    pub cfg: ServingConfig,
    workers: Vec<Worker>,
    /// Global prefill queue (FCFS).
    prefill_queue: VecDeque<Request>,
    pending: VecDeque<Request>,
    transfers: Vec<Transfer>,
    pub metrics: Recorder,
    pub finished: Vec<Request>,
    pub dropped: u64,
    /// Enable Dynamo-planner-style runtime reconfiguration.
    pub reconfigurable: bool,
    /// Downtime for a role switch (paper: ~40 s).
    pub reconfig_s: f64,
    /// Planner check interval.
    pub planner_interval: f64,
    next_planner_check: f64,
    pub reconfigs: u64,
}

impl DisaggEngine {
    pub fn new(cfg: ServingConfig, prefill_gpus: u32, decode_gpus: u32, seed: u64) -> DisaggEngine {
        let gpu = cfg.gpu.clone();
        Self::new_hetero(cfg, prefill_gpus, gpu.clone(), decode_gpus, gpu, seed)
    }

    /// Heterogeneous topology (Appendix B future work): prefill workers on
    /// `prefill_gpu` parts, decode workers on `decode_gpu` parts — e.g.
    /// compute-optimized prefill + memory-optimized decode.
    pub fn new_hetero(
        cfg: ServingConfig,
        prefill_gpus: u32,
        prefill_gpu: crate::config::GpuSpec,
        decode_gpus: u32,
        decode_gpu: crate::config::GpuSpec,
        seed: u64,
    ) -> DisaggEngine {
        assert!(prefill_gpus >= 1 && decode_gpus >= 1);
        let mk = |role: Role, spec: &crate::config::GpuSpec, i: u32| Worker {
            role,
            clock: 0.0,
            executor: GpuExecutor::new(cfg.model.clone(), spec.clone(), 1, seed + i as u64),
            kv: KvManager::new(
                // Each worker is a single GPU holding a full model replica.
                {
                    let mut c = cfg.clone();
                    c.tp = 1;
                    c.gpu = spec.clone();
                    c.kv_capacity_blocks()
                },
                cfg.kv_block_tokens,
            ),
            running: Vec::new(),
            offline_until: 0.0,
            busy: 0.0,
        };
        let mut workers = Vec::new();
        for i in 0..prefill_gpus {
            workers.push(mk(Role::Prefill, &prefill_gpu, i));
        }
        for i in 0..decode_gpus {
            workers.push(mk(Role::Decode, &decode_gpu, prefill_gpus + i));
        }
        DisaggEngine {
            cfg,
            workers,
            prefill_queue: VecDeque::new(),
            pending: VecDeque::new(),
            transfers: Vec::new(),
            metrics: Recorder::new(),
            finished: Vec::new(),
            dropped: 0,
            reconfigurable: false,
            reconfig_s: 40.0,
            planner_interval: 30.0,
            next_planner_check: 30.0,
            reconfigs: 0,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn run(&mut self, workload: Workload) -> Report {
        self.pending = workload.requests.into();
        loop {
            if !self.step() {
                break;
            }
        }
        let end = self
            .workers
            .iter()
            .map(|w| w.clock)
            .fold(0.0f64, f64::max);
        self.metrics.duration = end;
        for w in &self.workers {
            self.metrics.busy_time += w.busy;
        }
        let p = self.workers.iter().filter(|w| w.role == Role::Prefill).count();
        let d = self.workers.len() - p;
        self.metrics.report(&format!("Dynamo-{p}P{d}D"))
    }

    fn all_done(&self) -> bool {
        self.pending.is_empty()
            && self.prefill_queue.is_empty()
            && self.transfers.is_empty()
            && self.workers.iter().all(|w| w.running.is_empty())
    }

    /// Advance the system by one worker-iteration. Returns false if done.
    fn step(&mut self) -> bool {
        if self.all_done() {
            return false;
        }
        // The worker with the earliest clock acts next.
        let idx = self
            .workers
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.clock.partial_cmp(&b.1.clock).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let now = self.workers[idx].clock;
        if now > MAX_SIM_TIME {
            self.dropped +=
                (self.pending.len() + self.prefill_queue.len() + self.transfers.len()) as u64;
            self.pending.clear();
            self.prefill_queue.clear();
            self.transfers.clear();
            for w in &mut self.workers {
                w.running.clear();
            }
            return false;
        }

        // Pull arrivals into the global prefill queue.
        while let Some(r) = self.pending.front() {
            if r.arrival <= now {
                self.prefill_queue.push_back(self.pending.pop_front().unwrap());
            } else {
                break;
            }
        }

        if self.reconfigurable && now >= self.next_planner_check {
            self.plan_reconfig(now);
            self.next_planner_check = now + self.planner_interval;
        }

        if self.workers[idx].offline_until > now {
            self.workers[idx].clock = self.workers[idx].offline_until;
            return true;
        }

        match self.workers[idx].role {
            Role::Prefill => self.step_prefill(idx),
            Role::Decode => self.step_decode(idx),
        }
        true
    }

    /// One prefill iteration on worker `idx`: pack whole prompts up to the
    /// token budget (chunking the head if it alone exceeds the budget).
    fn step_prefill(&mut self, idx: usize) {
        let now = self.workers[idx].clock;
        if self.prefill_queue.is_empty() {
            // Idle: jump to next arrival (or just past other clocks).
            let next = self.pending.front().map(|r| r.arrival);
            match next {
                Some(t) => self.workers[idx].clock = self.workers[idx].clock.max(t),
                None => {
                    // No more arrivals: park beyond every active clock so
                    // other workers drive the system.
                    let max_other = self
                        .workers
                        .iter()
                        .map(|w| w.clock)
                        .fold(0.0f64, f64::max);
                    self.workers[idx].clock = max_other + 1e-3;
                }
            }
            return;
        }
        // Build a prefill-only batch.
        let budget = self.cfg.token_budget as u64;
        let mut tokens = 0u64;
        let mut batch: Vec<Request> = Vec::new();
        while let Some(r) = self.prefill_queue.front() {
            if batch.is_empty() {
                let r = self.prefill_queue.pop_front().unwrap();
                tokens += r.prompt_len.min(budget);
                batch.push(r);
                if tokens >= budget {
                    break;
                }
            } else if tokens + r.prompt_len <= budget {
                let r = self.prefill_queue.pop_front().unwrap();
                tokens += r.prompt_len;
                batch.push(r);
            } else {
                break;
            }
        }
        // A prompt larger than the budget runs over multiple chunked
        // iterations; model that as ceil(prompt/budget) sequential spans.
        let shapes: Vec<AttnShape> = batch
            .iter()
            .map(|r| AttnShape {
                q: r.prompt_len.min(budget),
                c: 0,
            })
            .collect();
        let bshape = BatchShape::from_shapes(shapes);
        let res = self.workers[idx]
            .executor
            .run(&bshape, self.cfg.gpu.num_sms, DispatchMode::Eager, None);
        // Extra chunks for oversized prompts.
        let mut extra = 0.0;
        for r in &batch {
            if r.prompt_len > budget {
                let n_extra = r.prompt_len.div_ceil(budget) - 1;
                let shape = BatchShape::from_shapes(vec![AttnShape {
                    q: budget.min(r.prompt_len - budget * 0),
                    c: budget,
                }]);
                let per = self.workers[idx]
                    .executor
                    .run(&shape, self.cfg.gpu.num_sms, DispatchMode::Eager, None);
                extra += n_extra as f64 * per.total();
            }
        }
        let dur = res.total() + extra;
        let t_end = now + dur;
        self.workers[idx].clock = t_end;
        self.workers[idx].busy += res.gpu_time + extra;
        self.metrics.record_util(res.gpu_time + extra, res.sm_util, res.hbm_util);
        self.metrics.iterations += 1;

        // Completed prompts: first token produced here, then KV transfer.
        for mut r in batch {
            r.advance_prefill(r.prompt_len);
            r.advance_decode(t_end); // first output token from prefill logits
            if r.phase == Phase::Finished {
                self.metrics.record_finished(&r);
                self.finished.push(r);
                continue;
            }
            let ready = t_end + self.workers[idx].executor.kv_transfer_time(r.context_len());
            self.transfers.push(Transfer { request: r, ready_at: ready });
        }
    }

    /// One decode iteration on worker `idx`: admit ready transfers, run
    /// one decode-only step over the whole running batch.
    fn step_decode(&mut self, idx: usize) {
        let now = self.workers[idx].clock;
        // Admit ready transfers targeted at the least-loaded decode worker
        // — approximate by admitting to this worker when it is the
        // least-loaded decode worker.
        let my_load = self.workers[idx].running.len();
        let am_least = self
            .workers
            .iter()
            .filter(|w| w.role == Role::Decode)
            .all(|w| w.running.len() >= my_load || std::ptr::eq(w, &self.workers[idx]));
        if am_least {
            let mut i = 0;
            while i < self.transfers.len() {
                if self.transfers[i].ready_at <= now {
                    let t = self.transfers.swap_remove(i);
                    let mut r = t.request;
                    let id = r.id;
                    self.workers[idx].kv.register(id);
                    if self.workers[idx].kv.append(id, r.context_len()).is_err() {
                        // Decode KV full: requeue the transfer for later.
                        self.transfers.push(Transfer {
                            request: r,
                            ready_at: now + 0.05,
                        });
                        let last = self.transfers.len() - 1;
                        let _ = self.workers[idx].kv.release(id);
                        let _ = last;
                        break;
                    }
                    r.phase = Phase::Decode;
                    self.workers[idx].running.push(r);
                } else {
                    i += 1;
                }
            }
        }

        if self.workers[idx].running.is_empty() {
            // Idle: jump to next transfer-ready or park.
            let next = self
                .transfers
                .iter()
                .map(|t| t.ready_at)
                .fold(f64::INFINITY, f64::min);
            if next.is_finite() {
                self.workers[idx].clock = self.workers[idx].clock.max(next);
            } else {
                let max_other = self
                    .workers
                    .iter()
                    .map(|w| w.clock)
                    .fold(0.0f64, f64::max);
                self.workers[idx].clock = max_other + 1e-3;
            }
            return;
        }

        let shapes: Vec<AttnShape> = self.workers[idx]
            .running
            .iter()
            .map(|r| AttnShape {
                q: 1,
                c: r.context_len(),
            })
            .collect();
        let bshape = BatchShape::from_shapes(shapes);
        let res = self.workers[idx]
            .executor
            .run(&bshape, self.cfg.gpu.num_sms, DispatchMode::Graph, None);
        let dur = res.total();
        let t_end = now + dur;
        self.workers[idx].clock = t_end;
        self.workers[idx].busy += res.gpu_time;
        self.metrics.record_util(res.gpu_time, res.sm_util, res.hbm_util);
        self.metrics.iterations += 1;

        let w = &mut self.workers[idx];
        let mut i = 0;
        while i < w.running.len() {
            let id = w.running[i].id;
            let _ = w.kv.append(id, 1);
            w.running[i].advance_decode(t_end);
            if w.running[i].phase == Phase::Finished {
                let r = w.running.swap_remove(i);
                let _ = w.kv.release(r.id);
                self.metrics.record_finished(&r);
                self.finished.push(r);
            } else {
                i += 1;
            }
        }
    }

    /// Dynamo-planner emulation: flip one worker's role when the phases
    /// are persistently imbalanced. Switching preempts in-flight decodes
    /// (recompute: back to the prefill queue) and takes `reconfig_s`.
    fn plan_reconfig(&mut self, now: f64) {
        let p_count = self.workers.iter().filter(|w| w.role == Role::Prefill).count();
        let d_count = self.workers.len() - p_count;
        let queue_pressure = self.prefill_queue.len();
        let decode_load: usize = self
            .workers
            .iter()
            .filter(|w| w.role == Role::Decode)
            .map(|w| w.running.len())
            .sum();

        // Prefill backlogged, decode workers light: D -> P.
        if queue_pressure > 8 * p_count && d_count > 1 && decode_load < 4 * d_count {
            if let Some(w) = self
                .workers
                .iter_mut()
                .filter(|w| w.role == Role::Decode)
                .min_by_key(|w| w.running.len())
            {
                for r in w.running.drain(..) {
                    // Preempted decodes restart from scratch.
                    let fresh = Request::new(r.id, r.arrival, r.prompt_len, r.output_len);
                    let _ = w.kv.release(r.id);
                    self.prefill_queue.push_front(fresh);
                }
                w.role = Role::Prefill;
                w.offline_until = now + self.reconfig_s;
                self.reconfigs += 1;
            }
        // Decode overloaded, prefill side keeping up: P -> D.
        } else if queue_pressure < 4 * p_count && decode_load > 8 * d_count.max(1) && p_count > 1 {
            if let Some(w) = self
                .workers
                .iter_mut()
                .find(|w| w.role == Role::Prefill)
            {
                w.role = Role::Decode;
                w.offline_until = now + self.reconfig_s;
                self.reconfigs += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::workload::synthetic::fixed_workload;

    fn cfg() -> ServingConfig {
        ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        })
    }

    #[test]
    fn disagg_1p1d_completes_workload() {
        let mut e = DisaggEngine::new(cfg(), 1, 1, 1);
        let rep = e.run(fixed_workload(20, 4000, 32, 3.0, 1));
        assert_eq!(rep.completed, 20);
        assert_eq!(e.dropped, 0);
        assert!(rep.system.contains("1P1D"));
    }

    #[test]
    fn disagg_tbt_is_stable_but_throughput_suffers() {
        // Fig. 2's shape: the decode worker never sees prefill, so TBT is
        // low; but only one GPU prefills, so throughput < 2-replica agg.
        let w = fixed_workload(30, 8000, 200, 6.0, 2);
        let mut dis = DisaggEngine::new(cfg(), 1, 1, 1);
        let rd = dis.run(w.clone());

        let acfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut agg = crate::engine::ReplicatedEngine::new(acfg, 2, 1);
        let ra = agg.run(w);

        assert!(
            rd.tbt.mean < ra.tbt.mean,
            "disagg tbt {} should be below agg {}",
            rd.tbt.mean,
            ra.tbt.mean
        );
        assert!(
            ra.token_throughput > 1.2 * rd.token_throughput,
            "agg tokens/s {} should beat disagg {}",
            ra.token_throughput,
            rd.token_throughput
        );
    }

    #[test]
    fn transfers_delay_decode_start() {
        let mut e = DisaggEngine::new(cfg(), 1, 1, 1);
        let rep = e.run(fixed_workload(5, 8000, 16, 1.0, 3));
        assert_eq!(rep.completed, 5);
        // TTFT comes from prefill; with low load it should be sane.
        assert!(rep.ttft.mean > 0.05, "prefill takes real time");
    }

    #[test]
    fn reconfigurable_planner_fires_under_imbalance() {
        let mut e = DisaggEngine::new(cfg(), 2, 2, 1);
        e.reconfigurable = true;
        e.planner_interval = 10.0;
        // Prefill-heavy flood: planner should convert a decode worker.
        let rep = e.run(fixed_workload(300, 12_000, 8, 12.0, 4));
        assert!(rep.completed > 0);
        assert!(e.reconfigs > 0, "planner should reconfigure under flood");
    }
}
