//! Elastic role planning: the online half of conditional disaggregation.
//!
//! The cluster's legacy planner (`plan_reconfig`) flips one worker per
//! tick from fixed queue-pressure thresholds. The [`ElasticPlanner`]
//! replaces that with a goodput forecast over candidate role assignments
//! `(unified, prefill, decode)` of the same fleet size, spanning the
//! unified/disaggregated spectrum DynaServe maps out:
//!
//! - **Attainment forecast** — the roofline iteration model predicts the
//!   TBT a decode sees on a unified worker that co-schedules full-budget
//!   prefill chunks, weighted by the fraction of time that worker spends
//!   on prefill backlog. Splitting roles isolates decodes from long
//!   prompts exactly when that fraction (and so the forecast violation
//!   rate) is high — the paper's conditional-disaggregation bet.
//! - **Backlog makespan** — per-role token capacities (prefill workers at
//!   full budget rate, unified workers discounted by the spare headroom
//!   their schedulers advertise) turn the observed backlog into a drain
//!   time; a candidate that starves either phase scores zero.
//! - **Hysteresis** — a flip only happens outside a minimum dwell time,
//!   when the candidate beats staying put by a relative margin, after a
//!   reconfiguration-cost amortization, and through a per-pair
//!   disaggregation tax that pulls the fleet back toward unified when
//!   isolation buys nothing. An SLO-violation window overrides the margin
//!   (not the dwell) so a fleet that is actively missing SLOs reacts on
//!   the next tick.
//!
//! The planner is a pure decision function over [`FleetSignals`]; the
//! cluster gathers signals, applies the returned target through its
//! re-entrant loop (draining in-flight KV transfers first), and reports
//! the flip back via [`ElasticPlanner::committed`].

use crate::model::AttnShape;
use crate::roofline::{BatchShape, Predictor};

pub use super::router::LONG_PROMPT_TOKENS;

/// Which planner runs at the cluster's planner tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlannerMode {
    /// No planner: roles are fixed for the run (the historical default).
    #[default]
    Off,
    /// The legacy schedule-driven threshold planner (`plan_reconfig`) —
    /// what `reconfigurable: true` has always meant.
    Static,
    /// Goodput-forecast elastic planner (this module).
    Elastic,
}

impl PlannerMode {
    pub fn from_name(name: &str) -> Option<PlannerMode> {
        match name {
            "off" | "none" => Some(PlannerMode::Off),
            "static" => Some(PlannerMode::Static),
            "elastic" => Some(PlannerMode::Elastic),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlannerMode::Off => "off",
            PlannerMode::Static => "static",
            PlannerMode::Elastic => "elastic",
        }
    }
}

/// Live load digest the cluster hands the planner each tick. Queued
/// (not-yet-arrived) workload is excluded — the planner sees exactly what
/// a live serving front-end would, keeping batch and live planning
/// decisions identical.
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetSignals {
    /// Worker counts by current role.
    pub unified: usize,
    pub prefill: usize,
    pub decode: usize,
    /// Un-prefilled prompt tokens across all worker queues.
    pub pre_backlog_tokens: u64,
    /// Of those, tokens belonging to long requests (prompt length ≥
    /// [`LONG_PROMPT_TOKENS`]) — the share the conditional router steers
    /// to prefill-role workers when any exist.
    pub long_backlog_tokens: u64,
    /// Un-generated output tokens across all worker queues.
    pub dec_backlog_tokens: u64,
    /// In-flight (queued or running, not finished) requests.
    pub backlog_reqs: u64,
    /// Mean context length of in-flight requests (decode shape input).
    pub mean_ctx: u64,
    /// Mean spare prefill fraction unified workers' schedulers advertise
    /// ([`crate::sched::Scheduler::prefill_headroom`]); 1.0 when the
    /// fleet has no unified worker.
    pub unified_headroom: f64,
    /// Cumulative SLO-checked inter-token gaps across worker recorders.
    pub slo_checked: u64,
    /// Cumulative SLO violations across worker recorders.
    pub slo_violations: u64,
    /// Prefill→decode KV transfers not yet admitted by a decode worker.
    pub transfers_in_flight: usize,
}

/// Relative goodput penalty per disaggregated worker pair — the standing
/// cost of KV-transfer hops and capacity fragmentation that makes unified
/// the default whenever isolation is not forecast to pay.
const DISAGG_TAX: f64 = 0.1;

/// Decode batch size cap in the forecast shapes (matches typical
/// max-batch pressure without letting a deep backlog explode the model).
const MAX_FORECAST_DECODE: usize = 64;

/// SLO-violation fraction (over the inter-tick window) above which the
/// improvement margin is waived: an actively-failing fleet reconfigures
/// on any forecast win.
const PRESSURE_OVERRIDE: f64 = 0.10;

/// Scores candidate role assignments by forecast goodput and decides
/// role flips with hysteresis. Owned by the cluster when `--planner
/// elastic` is selected.
#[derive(Debug, Clone)]
pub struct ElasticPlanner {
    predictor: Predictor,
    token_budget: u64,
    tbt_slo: f64,
    /// Seconds a flipped worker is offline (kept in sync with the
    /// cluster's `reconfig_s` each tick).
    pub reconfig_s: f64,
    /// Minimum seconds between flips (hysteresis dwell).
    pub min_dwell_s: f64,
    /// Relative forecast-goodput improvement required to move (waived
    /// under SLO pressure). Must sit below [`DISAGG_TAX`] so the idle
    /// collapse back to unified is reachable.
    pub margin: f64,
    /// Absolute engine time of the last committed flip.
    last_flip_at: f64,
    /// SLO counters at the previous tick (violation-window baseline).
    last_checked: u64,
    last_violations: u64,
    /// Telemetry: decide() calls and committed worker flips.
    pub evals: u64,
    pub flips: u64,
}

impl ElasticPlanner {
    pub fn new(
        predictor: Predictor,
        token_budget: u64,
        tbt_slo: f64,
        reconfig_s: f64,
    ) -> ElasticPlanner {
        ElasticPlanner {
            predictor,
            token_budget: token_budget.max(1),
            tbt_slo: tbt_slo.max(1e-6),
            reconfig_s,
            min_dwell_s: 45.0,
            margin: 0.05,
            last_flip_at: f64::NEG_INFINITY,
            last_checked: 0,
            last_violations: 0,
            evals: 0,
            flips: 0,
        }
    }

    /// Role flips needed to move between two assignments (each flip
    /// changes one worker's role, so the L1 distance double-counts).
    pub fn flips_needed(from: (usize, usize, usize), to: (usize, usize, usize)) -> usize {
        (from.0.abs_diff(to.0) + from.1.abs_diff(to.1) + from.2.abs_diff(to.2)) / 2
    }

    /// The cluster reports a committed reconfiguration; starts the dwell
    /// window. Only called when at least one worker actually flipped.
    pub fn committed(&mut self, now: f64, flips: usize) {
        self.last_flip_at = now;
        self.flips += flips as u64;
    }

    /// Pick the next role assignment, or `None` to keep the current one.
    /// `now` is absolute engine time (`epoch_offset + clock`), which is
    /// invariant across the cluster's idle re-basing.
    pub fn decide(
        &mut self,
        now: f64,
        s: &FleetSignals,
    ) -> Option<(usize, usize, usize)> {
        self.evals += 1;
        // Violation window: fraction of SLO-checked gaps missed since the
        // previous tick. Consumed even on early return so the window
        // always spans exactly one tick.
        let checked = s.slo_checked.saturating_sub(self.last_checked);
        let violated = s.slo_violations.saturating_sub(self.last_violations);
        self.last_checked = s.slo_checked;
        self.last_violations = s.slo_violations;
        let pressure = if checked > 0 {
            violated as f64 / checked as f64
        } else {
            0.0
        };

        let cur = (s.unified, s.prefill, s.decode);
        let n = s.unified + s.prefill + s.decode;
        if n < 2 {
            return None;
        }
        if now - self.last_flip_at < self.min_dwell_s {
            return None;
        }
        // Idle fleet: collapse to all-unified — isolation is pure tax
        // with nothing in flight, and a unified fleet accepts whatever
        // arrives next everywhere.
        if s.backlog_reqs == 0 && s.transfers_in_flight == 0 {
            return if cur == (n, 0, 0) { None } else { Some((n, 0, 0)) };
        }

        let margin = if pressure > PRESSURE_OVERRIDE {
            0.0
        } else {
            self.margin
        };
        let stay = self.score(cur, s, 0);
        let mut best = cur;
        let mut best_score = stay;
        for cand in candidate_assignments(cur) {
            let flips = ElasticPlanner::flips_needed(cur, cand);
            let sc = self.score(cand, s, flips);
            if sc > best_score {
                best = cand;
                best_score = sc;
            }
        }
        if best != cur && best_score > stay * (1.0 + margin) {
            Some(best)
        } else {
            None
        }
    }

    /// Forecast goodput of one role assignment: TBT-attainment forecast ×
    /// backlog drain rate, discounted by the flip amortization and the
    /// per-pair disaggregation tax. Pure in the planner state.
    fn score(&self, cand: (usize, usize, usize), s: &FleetSignals, flips: usize) -> f64 {
        let (u, p, d) = cand;
        let budget = self.token_budget;
        let ctx = s.mean_ctx.max(1);

        // Per-worker phase rates from the roofline model.
        let t_pre = self
            .predictor
            .predict_full(&BatchShape::from_shapes(vec![AttnShape { q: budget, c: 0 }]))
            .max(1e-9);
        let pre_rate = budget as f64 / t_pre; // prompt tokens/s
        let dec_slots = (u + d).max(1);
        let dec_b = ((s.backlog_reqs as usize / dec_slots).max(1)).min(MAX_FORECAST_DECODE);
        let dec_shapes = vec![AttnShape { q: 1, c: ctx }; dec_b];
        let t_dec = self
            .predictor
            .predict_full(&BatchShape::from_shapes(dec_shapes.clone()))
            .max(1e-9);
        let dec_rate = dec_b as f64 / t_dec; // output tokens/s

        // TBT attainment forecast. A unified worker's prefill share is
        // what the conditional router leaves it: everything when the
        // fleet has no prefill worker, the short tail otherwise. While
        // that share lasts, the chunked scheduler packs full-budget
        // prefill chunks into decode iterations — so attainment blends
        // the mixed-iteration TBT with the pure-decode TBT by the
        // fraction of *time* the worker owes to prefill.
        let att = if u == 0 {
            (self.tbt_slo / t_dec).min(1.0)
        } else {
            let share = if p > 0 {
                s.pre_backlog_tokens.saturating_sub(s.long_backlog_tokens)
            } else {
                s.pre_backlog_tokens
            } as f64
                / u as f64;
            let time_pre = share / pre_rate;
            let dec_iters =
                s.dec_backlog_tokens as f64 / (dec_slots as f64 * dec_b as f64);
            let time_dec = dec_iters * t_dec;
            let frac = if time_pre + time_dec > 0.0 {
                time_pre / (time_pre + time_dec)
            } else {
                0.0
            };
            let mut mixed = dec_shapes;
            mixed.push(AttnShape { q: budget, c: 0 });
            let t_mixed = self
                .predictor
                .predict_full(&BatchShape::from_shapes(mixed))
                .max(1e-9);
            let att_mixed = (self.tbt_slo / t_mixed).min(1.0);
            let att_pure = (self.tbt_slo / t_dec).min(1.0);
            frac * att_mixed + (1.0 - frac) * att_pure
        };

        // Backlog makespan from per-role capacities. Unified prefill
        // capacity is discounted by the headroom its schedulers
        // advertise (the rest is spoken for by decode work).
        let pre_cap = (p as f64 + u as f64 * s.unified_headroom.clamp(0.0, 1.0)) * pre_rate;
        let dec_cap = (u + d) as f64 * dec_rate;
        let mut drain = 0.0f64;
        for (demand, cap) in [
            (s.pre_backlog_tokens, pre_cap),
            (s.dec_backlog_tokens, dec_cap),
        ] {
            if demand == 0 {
                continue;
            }
            if cap <= 0.0 {
                return 0.0; // starves a phase with demand
            }
            drain = drain.max(demand as f64 / cap);
        }

        // Reconfiguration cost, amortized over the larger of the dwell
        // window and the drain horizon (a flip is paid once per dwell,
        // not once per backlog).
        let horizon = self.min_dwell_s.max(drain).max(1e-3);
        let amort = (horizon + flips as f64 * self.reconfig_s) / horizon;
        let tax = 1.0 + DISAGG_TAX * (p + d) as f64;
        let rate = (s.backlog_reqs + 1) as f64 / (drain + 1e-3);
        att * rate / (amort * tax)
    }
}

/// Neighboring role assignments of the same fleet size: single-worker
/// adjustments, prefill/decode pair splits and collapses (one and two
/// pairs), and rebalances between the disaggregated roles. Every
/// candidate keeps at least one arrival-accepting worker (`u + p ≥ 1`)
/// and pairs the roles (`p == 0 ⇔ d == 0` — a prefill tier without a
/// decode tier deadlocks transfers, and vice versa).
fn candidate_assignments(cur: (usize, usize, usize)) -> Vec<(usize, usize, usize)> {
    let (u, p, d) = cur;
    let mut out = Vec::new();
    let mut push = |c: (usize, usize, usize)| {
        let (cu, cp, cd) = c;
        if cu + cp >= 1 && (cp == 0) == (cd == 0) && c != cur {
            out.push(c);
        }
    };
    if u >= 2 {
        push((u - 2, p + 1, d + 1)); // split one pair
    }
    if u >= 4 {
        push((u - 4, p + 2, d + 2)); // split two pairs
    }
    if p >= 1 && d >= 1 {
        push((u + 2, p - 1, d - 1)); // collapse one pair
    }
    if p >= 2 && d >= 2 {
        push((u + 4, p - 2, d - 2)); // collapse two pairs
    }
    if u >= 1 && d >= 1 {
        push((u - 1, p + 1, d)); // grow prefill tier
        push((u - 1, p, d + 1)); // grow decode tier
    }
    if p >= 2 {
        push((u + 1, p - 1, d)); // shrink prefill tier
    }
    if d >= 2 {
        push((u + 1, p, d - 1)); // shrink decode tier
    }
    if p >= 2 && d >= 1 {
        push((u, p - 1, d + 1)); // rebalance toward decode
    }
    if d >= 2 && p >= 1 {
        push((u, p + 1, d - 1)); // rebalance toward prefill
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};

    fn planner() -> ElasticPlanner {
        let pred = Predictor::new(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1);
        ElasticPlanner::new(pred, 8192, 0.05, 5.0)
    }

    fn quiet(u: usize, p: usize, d: usize) -> FleetSignals {
        FleetSignals {
            unified: u,
            prefill: p,
            decode: d,
            unified_headroom: 0.5,
            ..FleetSignals::default()
        }
    }

    /// A long-prompt burst concentrated on the fleet: huge un-prefilled
    /// long backlog, modest decode backlog.
    fn burst(u: usize, p: usize, d: usize) -> FleetSignals {
        FleetSignals {
            unified: u,
            prefill: p,
            decode: d,
            pre_backlog_tokens: 4_000_000,
            long_backlog_tokens: 3_990_000,
            dec_backlog_tokens: 2_000,
            backlog_reqs: 64,
            mean_ctx: 8192,
            unified_headroom: 0.5,
            slo_checked: 0,
            slo_violations: 0,
            transfers_in_flight: 0,
        }
    }

    #[test]
    fn mode_names_round_trip() {
        for m in [PlannerMode::Off, PlannerMode::Static, PlannerMode::Elastic] {
            assert_eq!(PlannerMode::from_name(m.name()), Some(m));
        }
        assert_eq!(PlannerMode::from_name("none"), Some(PlannerMode::Off));
        assert_eq!(PlannerMode::from_name("nope"), None);
        assert_eq!(PlannerMode::default(), PlannerMode::Off);
    }

    #[test]
    fn candidates_stay_valid() {
        for cur in [(4, 0, 0), (2, 1, 1), (0, 2, 2), (1, 2, 1), (0, 1, 3)] {
            for c in candidate_assignments(cur) {
                assert_eq!(c.0 + c.1 + c.2, cur.0 + cur.1 + cur.2, "{cur:?}->{c:?}");
                assert!(c.0 + c.1 >= 1, "{c:?} accepts no arrivals");
                assert_eq!(c.1 == 0, c.2 == 0, "{c:?} unpaired roles");
                assert_ne!(c, cur);
            }
        }
        assert!(candidate_assignments((1, 0, 0)).is_empty());
    }

    #[test]
    fn splits_under_long_prompt_pressure() {
        let mut pl = planner();
        let target = pl.decide(1000.0, &burst(4, 0, 0));
        let (u, p, d) = target.expect("burst should trigger a split");
        assert_eq!(u + p + d, 4);
        assert!(p >= 1 && d >= 1, "expected disaggregation, got {target:?}");
    }

    #[test]
    fn dwell_blocks_back_to_back_flips() {
        let mut pl = planner();
        assert!(pl.decide(1000.0, &burst(4, 0, 0)).is_some());
        pl.committed(1000.0, 2);
        assert_eq!(pl.flips, 2);
        // Inside the dwell window: no decision, however strong the signal.
        assert!(pl.decide(1000.0 + pl.min_dwell_s / 2.0, &burst(4, 0, 0)).is_none());
        // Outside it, the (already split) fleet never un-splits while the
        // burst holds — it either stays or shifts deeper into
        // disaggregation, but a collapse to unified (the thrash path)
        // is forecast-dominated.
        if let Some((_, p, d)) = pl.decide(1100.0, &burst(2, 1, 1)) {
            assert!(p >= 1 && d >= 1, "collapsed mid-burst");
        }
    }

    #[test]
    fn idle_fleet_collapses_to_unified() {
        let mut pl = planner();
        assert_eq!(pl.decide(1000.0, &quiet(2, 1, 1)), Some((4, 0, 0)));
        // Already all-unified: nothing to do.
        assert!(pl.decide(2000.0, &quiet(4, 0, 0)).is_none());
        // In-flight transfers defer the collapse.
        let mut s = quiet(2, 1, 1);
        s.transfers_in_flight = 1;
        assert!(pl.decide(3000.0, &s).is_none());
    }

    #[test]
    fn calm_load_converges_without_oscillating() {
        // Light, short-prompt load. An all-unified fleet stays put; a
        // split fleet may collapse toward unified (isolation is pure tax
        // here) but must then be stable — constant signals never produce
        // a flip-back (the no-thrash property).
        let light = |u, p, d| FleetSignals {
            unified: u,
            prefill: p,
            decode: d,
            pre_backlog_tokens: 2_000,
            long_backlog_tokens: 0,
            dec_backlog_tokens: 400,
            backlog_reqs: 4,
            mean_ctx: 512,
            unified_headroom: 0.8,
            ..FleetSignals::default()
        };
        let mut pl = planner();
        assert!(pl.decide(1000.0, &light(4, 0, 0)).is_none());

        let mut pl = planner();
        let mut state = (2usize, 1usize, 1usize);
        let mut flips = 0;
        for i in 0..10 {
            let now = 1000.0 + i as f64 * 100.0; // every tick clears dwell
            if let Some(next) = pl.decide(now, &light(state.0, state.1, state.2)) {
                pl.committed(now, ElasticPlanner::flips_needed(state, next));
                state = next;
                flips += 1;
            }
        }
        assert!(flips <= 1, "oscillated under constant load: {flips} moves");
    }

    #[test]
    fn slo_pressure_waives_margin_only() {
        let mut pl = planner();
        // Register a violation-heavy window, then confirm decide still
        // respects the dwell gate.
        let mut s = burst(4, 0, 0);
        s.slo_checked = 1000;
        s.slo_violations = 500;
        assert!(pl.decide(1000.0, &s).is_some());
        pl.committed(1000.0, 2);
        let mut s2 = burst(2, 1, 1);
        s2.slo_checked = 2000;
        s2.slo_violations = 1500;
        assert!(pl.decide(1001.0, &s2).is_none(), "dwell still applies");
    }

    #[test]
    fn tiny_fleet_never_plans() {
        let mut pl = planner();
        assert!(pl.decide(1000.0, &burst(1, 0, 0)).is_none());
    }
}
