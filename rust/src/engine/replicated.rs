//! N-replica aggregated serving under round-robin dispatch (the Fig. 2
//! "Agg-vLLM on two GPUs" setup: both GPUs host identical model replicas).

use crate::config::ServingConfig;
use crate::metrics::{Recorder, Report};
use crate::workload::Workload;

use super::{engine_for, SimEngine};

/// Round-robin front-end over N independent single-GPU engines.
pub struct ReplicatedEngine {
    pub engines: Vec<SimEngine>,
}

impl ReplicatedEngine {
    pub fn new(cfg: ServingConfig, replicas: u32, seed: u64) -> ReplicatedEngine {
        let engines = (0..replicas)
            .map(|i| engine_for(cfg.clone(), seed + i as u64))
            .collect();
        ReplicatedEngine { engines }
    }

    /// Dispatch round-robin, run every replica to completion, merge
    /// metrics. The end-to-end duration is the slowest replica's (the
    /// system is done when all replicas drain).
    pub fn run(&mut self, workload: Workload) -> Report {
        let n = self.engines.len();
        let mut shards: Vec<Vec<crate::request::Request>> = vec![Vec::new(); n];
        for (i, r) in workload.requests.into_iter().enumerate() {
            shards[i % n].push(r);
        }
        let mut merged = Recorder::new();
        let mut max_dur = 0.0f64;
        let mut name = String::new();
        for (e, shard) in self.engines.iter_mut().zip(shards) {
            let rep = e.run(Workload {
                name: workload.name.clone(),
                requests: shard,
            });
            name = format!("{}x{}", rep.system, n);
            max_dur = max_dur.max(rep.duration);
            for r in &e.finished {
                merged.record_finished(r);
            }
            merged.merge_iteration_state(&e.metrics);
        }
        merged.duration = max_dur;
        merged.report(&name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::workload::synthetic::fixed_workload;

    #[test]
    fn two_replicas_complete_everything() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut e = ReplicatedEngine::new(cfg, 2, 1);
        let rep = e.run(fixed_workload(20, 2000, 16, 6.0, 1));
        assert_eq!(rep.completed, 20);
        assert!(rep.system.contains("x2"));
    }

    #[test]
    fn two_replicas_roughly_double_throughput() {
        let w = fixed_workload(40, 8000, 32, 20.0, 2);
        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut e1 = ReplicatedEngine::new(cfg.clone(), 1, 1);
        let r1 = e1.run(w.clone());
        let mut e2 = ReplicatedEngine::new(cfg, 2, 1);
        let r2 = e2.run(w);
        let speedup = r2.throughput_rps / r1.throughput_rps;
        assert!(
            speedup > 1.5,
            "2 replicas should be ~2x at saturation, got {speedup}"
        );
    }
}
