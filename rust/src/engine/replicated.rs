//! N-replica aggregated serving (the Fig. 2 "Agg" setup: every GPU hosts
//! an identical model replica).
//!
//! This is a thin topology over [`ClusterEngine`]: `N` unified workers
//! share one arrival stream and a pluggable [`Router`] dispatches each
//! request *at its arrival time* — replicas are time-interleaved, unlike
//! the static index-sharding this module used to implement. The default
//! router is round-robin (the classic replica front-end); swap in
//! least-outstanding or KV-pressure routing with [`with_router`].
//!
//! [`with_router`]: ReplicatedEngine::with_router

use std::ops::{Deref, DerefMut};

use crate::config::ServingConfig;
use crate::metrics::Report;
use crate::workload::Workload;

use super::cluster::ClusterEngine;
use super::router::{RoundRobinRouter, Router};

/// Router-fronted cluster of N identical single-GPU engines.
pub struct ReplicatedEngine {
    pub cluster: ClusterEngine,
}

impl Deref for ReplicatedEngine {
    type Target = ClusterEngine;

    fn deref(&self) -> &ClusterEngine {
        &self.cluster
    }
}

impl DerefMut for ReplicatedEngine {
    fn deref_mut(&mut self) -> &mut ClusterEngine {
        &mut self.cluster
    }
}

impl ReplicatedEngine {
    /// N replicas behind round-robin dispatch.
    pub fn new(cfg: ServingConfig, replicas: u32, seed: u64) -> ReplicatedEngine {
        ReplicatedEngine {
            cluster: ClusterEngine::replicated(
                cfg,
                replicas,
                seed,
                Box::new(RoundRobinRouter::new()),
            ),
        }
    }

    /// Swap the routing policy (builder-style, before `run`).
    pub fn with_router(mut self, router: Box<dyn Router>) -> ReplicatedEngine {
        self.cluster.set_router(router);
        self
    }

    /// Serve the shared workload to completion; metrics are merged across
    /// replicas and the end-to-end duration is the last worker's final
    /// iteration.
    pub fn run(&mut self, workload: Workload) -> Report {
        self.cluster.run(workload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::engine::engine_for;
    use crate::engine::router::LeastOutstandingRouter;
    use crate::metrics::Recorder;
    use crate::workload::synthetic::fixed_workload;

    #[test]
    fn two_replicas_complete_everything() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut e = ReplicatedEngine::new(cfg, 2, 1);
        let rep = e.run(fixed_workload(20, 2000, 16, 6.0, 1));
        assert_eq!(rep.completed, 20);
        assert!(rep.system.contains("x2"));
    }

    #[test]
    fn two_replicas_roughly_double_throughput() {
        let w = fixed_workload(40, 8000, 32, 20.0, 2);
        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut e1 = ReplicatedEngine::new(cfg.clone(), 1, 1);
        let r1 = e1.run(w.clone());
        let mut e2 = ReplicatedEngine::new(cfg, 2, 1);
        let r2 = e2.run(w);
        let speedup = r2.throughput_rps / r1.throughput_rps;
        assert!(
            speedup > 1.5,
            "2 replicas should be ~2x at saturation, got {speedup}"
        );
    }

    /// The acceptance check for the cluster refactor: two time-interleaved
    /// replicas with per-arrival routing must complete a shared workload
    /// with throughput at least matching the legacy static-shard
    /// implementation (requests pre-split by index parity, each shard run
    /// on an isolated engine).
    #[test]
    fn interleaved_routing_beats_or_matches_static_sharding() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let w = fixed_workload(60, 8000, 64, 14.0, 7);

        // Legacy behaviour, reproduced inline: static round-robin shards,
        // each replica drains its shard independently.
        let n = 2usize;
        let mut shards: Vec<Vec<crate::request::Request>> = vec![Vec::new(); n];
        for (i, r) in w.requests.iter().cloned().enumerate() {
            shards[i % n].push(r);
        }
        let mut merged = Recorder::new();
        let mut max_dur = 0.0f64;
        for (i, shard) in shards.into_iter().enumerate() {
            let mut e = engine_for(cfg.clone(), 1 + i as u64);
            let rep = e.run(Workload {
                name: w.name.clone(),
                requests: shard,
            });
            max_dur = max_dur.max(rep.duration);
            for r in &e.finished {
                merged.record_finished(r);
            }
        }
        merged.duration = max_dur;
        let static_rep = merged.report("static-shard-x2");

        // New cluster: shared stream, dispatch at arrival time.
        let mut e = ReplicatedEngine::new(cfg, 2, 1);
        let cluster_rep = e.run(w);

        assert_eq!(cluster_rep.completed, 60);
        assert_eq!(static_rep.completed, 60);
        assert!(
            cluster_rep.throughput_rps >= static_rep.throughput_rps * 0.999,
            "interleaved {} req/s must not lose to static sharding {} req/s",
            cluster_rep.throughput_rps,
            static_rep.throughput_rps
        );
    }

    #[test]
    fn least_outstanding_router_balances_heterogeneous_prompts() {
        // Alternating huge/small prompts: static parity sharding piles all
        // huge prompts on one replica; per-arrival least-outstanding
        // routing spreads them and must not be slower.
        let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
        let mut requests = Vec::new();
        for i in 0..40u64 {
            let (isl, osl) = if i % 2 == 0 { (12_000, 16) } else { (256, 16) };
            requests.push(crate::request::Request::new(i, i as f64 * 0.05, isl, osl));
        }
        let w = Workload {
            name: "alternating".into(),
            requests,
        };

        let mut rr = ReplicatedEngine::new(cfg.clone(), 2, 3);
        let r_rr = rr.run(w.clone());
        let mut ll = ReplicatedEngine::new(cfg, 2, 3)
            .with_router(Box::new(LeastOutstandingRouter::new()));
        let r_ll = ll.run(w);

        assert_eq!(r_rr.completed, 40);
        assert_eq!(r_ll.completed, 40);
        assert!(
            r_ll.duration <= r_rr.duration * 1.05,
            "least-outstanding ({:.2}s) should not trail round-robin ({:.2}s)",
            r_ll.duration,
            r_rr.duration
        );
    }
}
