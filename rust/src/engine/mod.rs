//! Serving engines: the iteration loop tying scheduler, KV cache,
//! executor and metrics together.
//!
//! [`SimEngine`] is the single-GPU-group engine (policy-generic via the
//! [`Scheduler`] trait) used for vLLM / SGLang / DuetServe / static-split
//! configurations. [`replicated::ReplicatedEngine`] runs N independent
//! replicas under round-robin dispatch (the Fig. 2 "Agg" setup), and
//! [`disagg::DisaggEngine`] implements Dynamo-style PD disaggregation
//! with NVLink KV transfers (Fig. 2/7, Table 3).

pub mod disagg;
pub mod events;
pub mod replicated;

pub use disagg::DisaggEngine;
pub use events::{IterEvent, IterKind};
pub use replicated::ReplicatedEngine;

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::ServingConfig;
use crate::kvcache::KvManager;
use crate::metrics::{Recorder, Report};
use crate::model::AttnShape;
use crate::request::{Phase, Request, RequestId};
use crate::roofline::BatchShape;
use crate::sched::{IterationPlan, SchedInput, Scheduler};
use crate::sim::{DispatchMode, GpuExecutor};
use crate::workload::Workload;

/// Hard cap on simulated time — a run that exceeds this has diverged
/// (arrival rate above capacity with an unbounded queue).
const MAX_SIM_TIME: f64 = 3.0e4;

/// Single GPU-group serving engine over the simulated executor.
pub struct SimEngine {
    pub cfg: ServingConfig,
    scheduler: Box<dyn Scheduler>,
    executor: GpuExecutor,
    kv: KvManager,
    clock: f64,
    /// Not yet arrived (sorted by arrival).
    pending: VecDeque<Request>,
    /// Arrived, not admitted.
    waiting: VecDeque<Request>,
    running: Vec<Request>,
    pub finished: Vec<Request>,
    pub metrics: Recorder,
    /// Requests dropped because their prompt can never fit in KV.
    pub dropped: u64,
    /// Requests preempted (recompute-style) due to KV exhaustion.
    pub preemptions: u64,
    /// Detailed per-iteration log (Fig. 10); disabled by default.
    pub log_events: bool,
    pub events: Vec<IterEvent>,
}

impl SimEngine {
    pub fn new(cfg: ServingConfig, scheduler: Box<dyn Scheduler>, seed: u64) -> SimEngine {
        let kv = KvManager::new(cfg.kv_capacity_blocks(), cfg.kv_block_tokens);
        let executor = GpuExecutor::new(cfg.model.clone(), cfg.gpu.clone(), cfg.tp, seed);
        SimEngine {
            cfg,
            scheduler,
            executor,
            kv,
            clock: 0.0,
            pending: VecDeque::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            metrics: Recorder::new(),
            dropped: 0,
            preemptions: 0,
            log_events: false,
            events: Vec::new(),
        }
    }

    pub fn policy_name(&self) -> String {
        self.scheduler.name()
    }

    /// Run the whole workload to completion; returns the report.
    pub fn run(&mut self, workload: Workload) -> Report {
        self.pending = workload.requests.into();
        while self.step() {}
        self.metrics.duration = self.clock;
        self.metrics.report(&self.scheduler.name())
    }

    /// One iteration. Returns false when all work is done.
    pub fn step(&mut self) -> bool {
        self.admit_arrivals();
        if self.pending.is_empty() && self.waiting.is_empty() && self.running.is_empty() {
            return false;
        }
        if self.clock > MAX_SIM_TIME {
            // Diverged: drain bookkeeping and stop.
            self.dropped += (self.pending.len() + self.waiting.len()) as u64;
            self.pending.clear();
            self.waiting.clear();
            self.running.clear();
            return false;
        }

        let sched_start = Instant::now();
        let input = SchedInput {
            running: &self.running,
            waiting: self.waiting.make_contiguous(),
            kv_free_tokens: self.kv.free_blocks() * self.kv.block_tokens() as u64,
            kv_total_tokens: self.kv.total_blocks() * self.kv.block_tokens() as u64,
        };
        let plan = self.scheduler.plan(&input);
        let sched_s = sched_start.elapsed().as_secs_f64();
        self.metrics.sched_overhead += sched_s;

        match plan {
            IterationPlan::Idle => {
                // Nothing schedulable now.
                if let Some(next) = self.pending.front() {
                    self.clock = self.clock.max(next.arrival);
                    return true;
                }
                if !self.waiting.is_empty() && self.running.is_empty() {
                    // Head request can never fit: drop it or we deadlock.
                    let r = self.waiting.pop_front().unwrap();
                    let _ = self.kv.release(r.id);
                    self.dropped += 1;
                    return true;
                }
                // Running exists but scheduler idles — should not happen;
                // advance past to avoid livelock.
                !self.running.is_empty()
            }
            IterationPlan::Aggregated { decode, prefill } => {
                self.exec_aggregated(decode, prefill, sched_s);
                true
            }
            IterationPlan::Spatial {
                decode,
                prefill,
                plan,
            } => {
                self.exec_spatial(decode, prefill, plan, sched_s);
                true
            }
        }
    }

    fn admit_arrivals(&mut self) {
        while let Some(r) = self.pending.front() {
            if r.arrival <= self.clock {
                let mut r = self.pending.pop_front().unwrap();
                r.phase = Phase::Waiting;
                self.kv.register(r.id);
                self.waiting.push_back(r);
            } else {
                break;
            }
        }
        // If totally idle, jump to the next arrival.
        if self.running.is_empty() && self.waiting.is_empty() {
            if let Some(r) = self.pending.front() {
                self.clock = self.clock.max(r.arrival);
                let mut r = self.pending.pop_front().unwrap();
                r.phase = Phase::Waiting;
                self.kv.register(r.id);
                self.waiting.push_back(r);
            }
        }
    }

    /// Move scheduled waiting requests into running (admission).
    fn admit_scheduled(&mut self, prefill: &[crate::sched::PrefillChunk]) {
        for c in prefill.iter().filter(|c| c.admit) {
            if let Some(pos) = self.waiting.iter().position(|r| r.id == c.id) {
                let r = self.waiting.remove(pos).unwrap();
                self.running.push(r);
            }
        }
    }

    fn batch_shapes(
        &self,
        decode: &[RequestId],
        prefill: &[crate::sched::PrefillChunk],
    ) -> (BatchShape, BatchShape) {
        let find = |id: RequestId| self.running.iter().find(|r| r.id == id);
        let dec = decode
            .iter()
            .filter_map(|&id| find(id))
            .map(|r| AttnShape {
                q: 1,
                c: r.context_len(),
            })
            .collect();
        let pre = prefill
            .iter()
            .filter_map(|c| find(c.id).map(|r| (r, c.tokens)))
            .map(|(r, q)| AttnShape {
                q,
                c: r.context_len(),
            })
            .collect();
        (
            BatchShape::from_shapes(dec),
            BatchShape::from_shapes(pre),
        )
    }

    /// KV-append with recompute-preemption on exhaustion: the most
    /// recently admitted running request is evicted, reset, and requeued
    /// (vLLM's recompute preemption policy).
    fn kv_append_or_preempt(&mut self, id: RequestId, tokens: u64) -> bool {
        loop {
            match self.kv.append(id, tokens) {
                Ok(()) => return true,
                Err(_) => {
                    // Evict the newest running request that is not `id`.
                    let victim = self
                        .running
                        .iter()
                        .rposition(|r| r.id != id && r.phase != Phase::Finished);
                    match victim {
                        Some(pos) => {
                            let mut v = self.running.remove(pos);
                            let _ = self.kv.release(v.id);
                            self.preemptions += 1;
                            // Recompute preemption: progress is lost.
                            let fresh = Request::new(v.id, v.arrival, v.prompt_len, v.output_len);
                            v = fresh;
                            self.kv.register(v.id);
                            self.waiting.push_front(v);
                        }
                        None => return false, // single request larger than KV
                    }
                }
            }
        }
    }

    fn exec_aggregated(
        &mut self,
        decode: Vec<RequestId>,
        prefill: Vec<crate::sched::PrefillChunk>,
        sched_s: f64,
    ) {
        self.admit_scheduled(&prefill);
        let (dec_shape, pre_shape) = self.batch_shapes(&decode, &prefill);
        let mut all = dec_shape.shapes.clone();
        all.extend(pre_shape.shapes.iter().copied());
        let batch = BatchShape::from_shapes(all);
        // Decode-only batches replay captured graphs; any prefill in the
        // batch forces eager dispatch (dynamic shapes — §4.3).
        let mode = if pre_shape.is_empty() {
            DispatchMode::Graph
        } else {
            DispatchMode::Eager
        };
        let res = self.executor.run(&batch, self.cfg.gpu.num_sms, mode, None);
        // The virtual clock stays deterministic: measured CPU scheduling
        // time is *reported* (metrics/events) but not added to simulated
        // time — it is µs against ~100 ms iterations (Fig. 10).
        let dur = res.total();
        let t_end = self.clock + dur;

        // KV appends + request state updates.
        for &id in &decode {
            if self.kv_append_or_preempt(id, 1) {
                if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                    if r.phase == Phase::Decode {
                        r.advance_decode(t_end);
                    }
                }
            }
        }
        for c in &prefill {
            if self.kv_append_or_preempt(c.id, c.tokens) {
                if let Some(pos) = self.running.iter().position(|r| r.id == c.id) {
                    let r = &mut self.running[pos];
                    r.advance_prefill(c.tokens);
                    if r.phase == Phase::Decode {
                        // Prompt completed: this forward's logits produce
                        // the first output token.
                        let id = r.id;
                        if self.kv_append_or_preempt(id, 1) {
                            if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                                r.advance_decode(t_end);
                            }
                        }
                    }
                }
            }
        }

        self.metrics
            .record_util(res.gpu_time, res.sm_util, res.hbm_util);
        self.metrics.busy_time += res.gpu_time;
        self.metrics.iterations += 1;
        if self.log_events {
            self.events.push(IterEvent {
                t_start: self.clock,
                duration: dur,
                kind: IterKind::Aggregated,
                n_decode: decode.len() as u32,
                prefill_tokens: pre_shape.n_tokens,
                sched_s,
                sm_util: res.sm_util,
                hbm_util: res.hbm_util,
            });
        }
        self.clock = t_end;
        self.retire_finished();
    }

    fn exec_spatial(
        &mut self,
        decode: Vec<RequestId>,
        prefill: Vec<crate::sched::PrefillChunk>,
        plan: crate::hw::PartitionPlan,
        sched_s: f64,
    ) {
        self.admit_scheduled(&prefill);
        let (dec_shape, pre_shape) = self.batch_shapes(&decode, &prefill);
        let res = self.executor.run_spatial(&dec_shape, &pre_shape, &plan);
        let dur = res.span;
        let t_end = self.clock + dur;
        let k = plan.k.max(1);

        // Look-ahead decode: reserve k slots per request up front (§4.3),
        // then run k uninterrupted steps; step i completes at
        // t0 + dispatch + (i+1)·t_step.
        for &id in &decode {
            let _ = self.kv.reserve(id, k as u64); // best-effort; append below enforces
        }
        let t0 = self.clock;
        for i in 0..k {
            let t_tok = t0 + res.dec.dispatch_time + (i + 1) as f64 * res.t_decode_step;
            for &id in &decode {
                let done = self
                    .running
                    .iter()
                    .find(|r| r.id == id)
                    .map(|r| r.phase != Phase::Decode)
                    .unwrap_or(true);
                if done {
                    continue; // finished mid-look-ahead: slot wasted
                }
                if self.kv_append_or_preempt(id, 1) {
                    if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                        r.advance_decode(t_tok.min(t_end));
                    }
                }
            }
        }

        // Prefill side advances at the synchronization point.
        for c in &prefill {
            if self.kv_append_or_preempt(c.id, c.tokens) {
                if let Some(pos) = self.running.iter().position(|r| r.id == c.id) {
                    let r = &mut self.running[pos];
                    r.advance_prefill(c.tokens);
                    if r.phase == Phase::Decode {
                        let id = r.id;
                        if self.kv_append_or_preempt(id, 1) {
                            if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                                r.advance_decode(t_end);
                            }
                        }
                    }
                }
            }
        }

        // Utilization: weight each side by its busy time over its SM share.
        let f_dec = plan.decode.fraction(&self.cfg.gpu);
        let f_pre = plan.prefill.fraction(&self.cfg.gpu);
        let busy_dec = (k as f64 * res.t_decode_step).min(res.span);
        let busy_pre = res.t_prefill.min(res.span);
        let sm = f_dec * res.dec.sm_util * busy_dec / res.span
            + f_pre * res.pre.sm_util * busy_pre / res.span;
        let hbm = res.dec.hbm_util * busy_dec / res.span
            + res.pre.hbm_util * busy_pre / res.span;
        self.metrics.record_util(res.span, sm, hbm);
        self.metrics.busy_time += res.span;
        self.metrics.iterations += 1;
        self.metrics.spatial_iterations += 1;
        if self.log_events {
            self.events.push(IterEvent {
                t_start: self.clock,
                duration: dur,
                kind: IterKind::Spatial {
                    decode_tpcs: plan.decode.n_tpcs,
                    prefill_tpcs: plan.prefill.n_tpcs,
                    k,
                },
                n_decode: decode.len() as u32,
                prefill_tokens: pre_shape.n_tokens,
                sched_s,
                sm_util: sm,
                hbm_util: hbm,
            });
        }
        self.clock = t_end;
        self.retire_finished();
    }

    fn retire_finished(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].phase == Phase::Finished {
                let r = self.running.swap_remove(i);
                let _ = self.kv.release(r.id);
                self.metrics.record_finished(&r);
                self.finished.push(r);
            } else {
                i += 1;
            }
        }
    }

    /// Engine-level invariants, used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        for r in &self.running {
            if r.phase == Phase::Finished {
                return Err(format!("finished request {} still running", r.id));
            }
            if r.generated > r.output_len {
                return Err(format!("request {} over-generated", r.id));
            }
        }
        for r in &self.finished {
            if r.generated != r.output_len || r.phase != Phase::Finished {
                return Err(format!("request {} retired unfinished", r.id));
            }
            let mut times = r.token_times.clone();
            let mut sorted = times.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if times != sorted {
                return Err(format!("request {} token times not monotone", r.id));
            }
            times.dedup();
            let _ = times;
        }
        Ok(())
    }
}

/// Convenience: build an engine for a config (maps `cfg.policy` to a
/// scheduler). Disaggregated policies must use [`DisaggEngine`] instead.
pub fn engine_for(cfg: ServingConfig, seed: u64) -> SimEngine {
    use crate::config::Policy;
    use crate::roofline::Predictor;
    use crate::sched::{ChunkedScheduler, DuetScheduler, SglangDefaultScheduler,
        StaticPartitionScheduler};

    let pred = Predictor::new(cfg.model.clone(), cfg.gpu.clone(), cfg.tp);
    let sched: Box<dyn Scheduler> = match &cfg.policy {
        Policy::VllmChunked => Box::new(
            ChunkedScheduler::new(cfg.token_budget as u64, cfg.max_batch as usize, cfg.kv_watermark)
                .labeled("vLLM"),
        ),
        Policy::SglangChunked => Box::new(
            ChunkedScheduler::new(cfg.token_budget as u64, cfg.max_batch as usize, cfg.kv_watermark)
                .labeled("SGLang-Chunked"),
        ),
        Policy::SglangDefault => Box::new(SglangDefaultScheduler::new(
            2 * cfg.token_budget as u64,
            cfg.max_batch as usize,
        )),
        Policy::Duet => Box::new(DuetScheduler::new(
            pred,
            cfg.token_budget as u64,
            cfg.max_batch as usize,
            cfg.kv_watermark,
            cfg.tbt_slo,
            cfg.max_lookahead,
        )),
        Policy::StaticPartition {
            decode_tpcs,
            prefill_tpcs,
        } => Box::new(StaticPartitionScheduler::new(
            pred,
            cfg.token_budget as u64,
            cfg.max_batch as usize,
            *decode_tpcs,
            *prefill_tpcs,
        )),
        Policy::DisaggPD { .. } => panic!("use DisaggEngine for disaggregated policies"),
    };
    SimEngine::new(cfg, sched, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::workload::synthetic::fixed_workload;

    fn small_cfg(policy: Policy) -> ServingConfig {
        ServingConfig::default_8b().with_policy(policy)
    }

    #[test]
    fn vllm_engine_completes_workload() {
        let mut e = engine_for(small_cfg(Policy::VllmChunked), 1);
        let w = fixed_workload(20, 2048, 16, 4.0, 1);
        let rep = e.run(w);
        assert_eq!(rep.completed, 20);
        assert_eq!(e.dropped, 0);
        assert!(rep.ttft.mean > 0.0);
        assert!(rep.tbt.mean > 0.0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn duet_engine_completes_and_goes_spatial_under_load() {
        let mut e = engine_for(small_cfg(Policy::Duet), 1);
        // Long prompts + long-ish outputs at high rate: mixed batches
        // will threaten the 100ms TBT SLO.
        let w = fixed_workload(30, 8000, 64, 8.0, 2);
        let rep = e.run(w);
        assert_eq!(rep.completed, 30);
        assert!(
            rep.spatial_iterations > 0,
            "duet should trigger spatial multiplexing under this load"
        );
        e.check_invariants().unwrap();
    }

    #[test]
    fn duet_tbt_beats_vllm_under_contention() {
        // The paper's headline behaviour: under prefill pressure Duet's
        // decode TBT stays bounded while vLLM's inflates.
        let w = fixed_workload(40, 8000, 128, 6.0, 3);
        let mut ev = engine_for(small_cfg(Policy::VllmChunked), 1);
        let rv = ev.run(w.clone());
        let mut ed = engine_for(small_cfg(Policy::Duet), 1);
        let rd = ed.run(w);
        assert!(
            rd.tbt.mean < rv.tbt.mean,
            "duet tbt {} should beat vllm {}",
            rd.tbt.mean,
            rv.tbt.mean
        );
    }

    #[test]
    fn finished_requests_have_full_output() {
        let mut e = engine_for(small_cfg(Policy::VllmChunked), 5);
        let w = fixed_workload(10, 500, 20, 10.0, 5);
        e.run(w);
        for r in &e.finished {
            assert_eq!(r.generated, r.output_len);
            assert_eq!(r.token_times.len(), r.output_len as usize);
        }
    }

    #[test]
    fn sglang_default_inflates_tbt() {
        let w = fixed_workload(40, 4000, 128, 8.0, 4);
        let mut es = engine_for(small_cfg(Policy::SglangDefault), 1);
        let rs = es.run(w.clone());
        let mut ed = engine_for(small_cfg(Policy::Duet), 1);
        let rd = ed.run(w);
        assert!(rs.completed == 40 && rd.completed == 40);
        assert!(
            rs.tbt.max > rd.tbt.max,
            "sglang-default max tbt {} should exceed duet {}",
            rs.tbt.max,
            rd.tbt.max
        );
    }

    #[test]
    fn oversized_prompt_is_dropped_not_deadlocked() {
        let mut cfg = small_cfg(Policy::VllmChunked);
        cfg.gpu_mem_util = 0.25; // tiny KV space
        let mut e = engine_for(cfg, 1);
        // One prompt far larger than KV capacity.
        let kv_tokens = e.cfg.kv_capacity_tokens();
        let w = fixed_workload(1, kv_tokens * 2, 4, 1.0, 1);
        let rep = e.run(w);
        assert_eq!(rep.completed, 0);
        assert_eq!(e.dropped, 1);
    }

    #[test]
    fn events_logged_when_enabled() {
        let mut e = engine_for(small_cfg(Policy::Duet), 1);
        e.log_events = true;
        let w = fixed_workload(10, 4000, 16, 8.0, 1);
        e.run(w);
        assert!(!e.events.is_empty());
        // events must tile the timeline monotonically
        assert!(e
            .events
            .windows(2)
            .all(|w| w[1].t_start >= w[0].t_start));
    }
}
