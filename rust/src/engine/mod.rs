//! Serving engines: scheduler, KV cache, executor and metrics tied into
//! topologies over one shared iteration core.
//!
//! # Architecture
//!
//! The engine layer is three stacked seams:
//!
//! 1. **Core** ([`EngineCore`]) — the per-iteration serving step
//!    every worker runs: scheduler plan → KV admit/allocate/preempt →
//!    executor dispatch → metrics/events. One local virtual clock per
//!    core; no knowledge of arrivals or other workers. The divergence
//!    guard ([`MAX_SIM_TIME`] + drain bookkeeping) lives here, in
//!    exactly one place.
//! 2. **Topology** — how cores are composed:
//!    - [`SimEngine`]: one unified worker fed directly by the workload
//!      (vLLM / SGLang / DuetServe / static-split policies);
//!    - [`ClusterEngine`]: N workers advanced by a re-entrant,
//!      incrementally fed discrete-event loop (smallest local clock acts
//!      next; `inject`/`step_next`/`drain`) with a shared arrival stream
//!      and a prefill→decode KV-transfer queue — the batch
//!      `run(workload)` is a thin replay over the same loop. The loop is
//!      driven by an **event queue** ([`clockheap::MinClockHeap`]): an
//!      indexed binary min-heap over per-worker clocks, updated on every
//!      clock mutation (step, park, offline jump, epoch re-base), so the
//!      next-event pick is O(1) and each event O(log N) instead of an
//!      O(N) fleet scan. Worker load signals (queue depth, outstanding
//!      tokens, free KV) are maintained incrementally on a per-worker
//!      candidate board, refreshed only for the worker an event touched,
//!      so routing decisions stop rebuilding O(N) snapshots per arrival;
//!    - [`ReplicatedEngine`]: cluster of unified replicas (Fig. 2 "Agg");
//!    - [`DisaggEngine`]: cluster of role-tagged prefill/decode workers
//!      with NVLink transfers and the optional Dynamo-style
//!      reconfiguration planner (Fig. 2/7, Table 3).
//! 3. **Routing** ([`router::Router`]) — pluggable per-arrival dispatch
//!    (round-robin, least-outstanding-tokens, KV-pressure-aware).
//!    Requests are routed when they arrive, against live load signals;
//!    replicated serving is time-interleaved rather than statically
//!    sharded. Prefill→decode KV transfers go through the same seam:
//!    each finished prompt is routed to a decode worker at
//!    transfer-ready time.
//! 4. **Execution** ([`backend::ExecutionBackend`]) — *how* a planned
//!    iteration runs: [`backend::SimBackend`] models latencies with the
//!    roofline-calibrated executor, while
//!    [`PjrtBackend`](crate::runtime::PjrtBackend) measures real
//!    wall-clock over the AOT-compiled runtime.
//! 5. **Serving** ([`topology::ServingTopology`]) — the seam the unified
//!    serving front-end ([`crate::server`]) dispatches through: live
//!    submit/stream/cancel/drain work identically over a single
//!    [`EngineCore`] or an N-worker [`ClusterEngine`] routed through the
//!    [`router::Router`] seam at submit time.

pub mod backend;
pub mod clockheap;
pub mod cluster;
pub mod core;
pub mod disagg;
pub mod elastic;
pub mod events;
pub mod replicated;
pub mod router;
pub mod topology;

pub use self::core::{CoreStep, EngineCore, MAX_SIM_TIME, REBASE_FRACTION};
pub use backend::{DecodeSlot, ExecutionBackend, IterationBatch, PrefillSlice, SimBackend};
pub use clockheap::MinClockHeap;
pub use cluster::{ClusterEngine, Worker, WorkerRole};
pub use disagg::DisaggEngine;
pub use elastic::{ElasticPlanner, FleetSignals, PlannerMode};
pub use events::{IterEvent, IterKind};
pub use replicated::ReplicatedEngine;
pub use router::{
    router_by_name, ConditionalRouter, KvOverlapRouter, KvPressureRouter,
    LeastOutstandingRouter, RouteCandidate, RoundRobinRouter, Router, LONG_PROMPT_TOKENS,
};
pub use topology::{ServingTopology, TopologyLoad, TopologyStep};

use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};

use crate::config::ServingConfig;
use crate::metrics::Report;
use crate::request::Request;
use crate::sched::{scheduler_for, Scheduler};
use crate::workload::Workload;

/// Single GPU-group serving engine: one [`EngineCore`] fed straight from
/// the workload's arrival stream.
///
/// Derefs to its core, so per-worker state (`metrics`, `finished`,
/// `dropped`, `events`, …) reads exactly as it did when this struct owned
/// the loop itself.
pub struct SimEngine {
    core: EngineCore,
    /// Not yet arrived (sorted by arrival).
    pending: VecDeque<Request>,
}

impl Deref for SimEngine {
    type Target = EngineCore;

    fn deref(&self) -> &EngineCore {
        &self.core
    }
}

impl DerefMut for SimEngine {
    fn deref_mut(&mut self) -> &mut EngineCore {
        &mut self.core
    }
}

impl SimEngine {
    pub fn new(cfg: ServingConfig, scheduler: Box<dyn Scheduler>, seed: u64) -> SimEngine {
        SimEngine {
            core: EngineCore::new(cfg, scheduler, seed),
            pending: VecDeque::new(),
        }
    }

    /// Run the whole workload to completion; returns the report.
    pub fn run(&mut self, workload: Workload) -> Report {
        self.pending = workload.sorted_by_arrival().requests.into();
        while self.step() {}
        self.core.metrics.duration = self.core.clock;
        self.core.metrics.report(&self.core.policy_name())
    }

    /// One iteration. Returns false when all work is done.
    ///
    /// `server::ServerCore::step` mirrors this loop so the serving path
    /// and the simulation produce identical metrics; changes here must
    /// keep the `server_path_matches_sim_engine_metrics` property green.
    pub fn step(&mut self) -> bool {
        self.admit_arrivals();
        if self.pending.is_empty() && !self.core.has_local_work() {
            return false;
        }
        if self.core.clock > self.core.cfg.max_engine_time {
            // Diverged: drain bookkeeping and stop.
            self.core.dropped += self.pending.len() as u64;
            self.pending.clear();
            self.core.drain_diverged();
            return false;
        }

        match self.core.step_once(self.pending.is_empty()) {
            CoreStep::Executed | CoreStep::DroppedHead(_) => true,
            CoreStep::Idle => {
                // Nothing schedulable now: jump to the next arrival, or
                // keep stepping while admitted work remains.
                if let Some(next) = self.pending.front() {
                    self.core.clock = self.core.clock.max(next.arrival);
                    return true;
                }
                if self.core.running.is_empty() {
                    return false;
                }
                // Scheduler idled with admitted work (should not happen);
                // nudge — identically to the serving path — so the
                // divergence guard trips rather than livelocking.
                self.core.clock += topology::IDLE_NUDGE;
                true
            }
        }
    }

    fn admit_arrivals(&mut self) {
        while let Some(r) = self.pending.front() {
            if r.arrival <= self.core.clock {
                let r = self.pending.pop_front().unwrap();
                self.core.inject(r);
            } else {
                break;
            }
        }
        // If totally idle, jump to the next arrival.
        if !self.core.has_local_work() {
            if let Some(r) = self.pending.front() {
                self.core.clock = self.core.clock.max(r.arrival);
                let r = self.pending.pop_front().unwrap();
                self.core.inject(r);
            }
        }
    }
}

/// Convenience: build an engine for a config (maps `cfg.policy` to a
/// scheduler via [`scheduler_for`]). Disaggregated policies must use
/// [`DisaggEngine`] instead.
pub fn engine_for(cfg: ServingConfig, seed: u64) -> SimEngine {
    let sched = scheduler_for(&cfg);
    SimEngine::new(cfg, sched, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::workload::synthetic::fixed_workload;

    fn small_cfg(policy: Policy) -> ServingConfig {
        ServingConfig::default_8b().with_policy(policy)
    }

    #[test]
    fn vllm_engine_completes_workload() {
        let mut e = engine_for(small_cfg(Policy::VllmChunked), 1);
        let w = fixed_workload(20, 2048, 16, 4.0, 1);
        let rep = e.run(w);
        assert_eq!(rep.completed, 20);
        assert_eq!(e.dropped, 0);
        assert!(rep.ttft.mean > 0.0);
        assert!(rep.tbt.mean > 0.0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn duet_engine_completes_and_goes_spatial_under_load() {
        let mut e = engine_for(small_cfg(Policy::Duet), 1);
        // Long prompts + long-ish outputs at high rate: mixed batches
        // will threaten the 100ms TBT SLO.
        let w = fixed_workload(30, 8000, 64, 8.0, 2);
        let rep = e.run(w);
        assert_eq!(rep.completed, 30);
        assert!(
            rep.spatial_iterations > 0,
            "duet should trigger spatial multiplexing under this load"
        );
        e.check_invariants().unwrap();
    }

    #[test]
    fn duet_tbt_beats_vllm_under_contention() {
        // The paper's headline behaviour: under prefill pressure Duet's
        // decode TBT stays bounded while vLLM's inflates.
        let w = fixed_workload(40, 8000, 128, 6.0, 3);
        let mut ev = engine_for(small_cfg(Policy::VllmChunked), 1);
        let rv = ev.run(w.clone());
        let mut ed = engine_for(small_cfg(Policy::Duet), 1);
        let rd = ed.run(w);
        assert!(
            rd.tbt.mean < rv.tbt.mean,
            "duet tbt {} should beat vllm {}",
            rd.tbt.mean,
            rv.tbt.mean
        );
    }

    #[test]
    fn finished_requests_have_full_output() {
        let mut e = engine_for(small_cfg(Policy::VllmChunked), 5);
        let w = fixed_workload(10, 500, 20, 10.0, 5);
        e.run(w);
        for r in &e.finished {
            assert_eq!(r.generated, r.output_len);
            assert_eq!(r.token_times.len(), r.output_len as usize);
        }
    }

    #[test]
    fn sglang_default_inflates_tbt() {
        let w = fixed_workload(40, 4000, 128, 8.0, 4);
        let mut es = engine_for(small_cfg(Policy::SglangDefault), 1);
        let rs = es.run(w.clone());
        let mut ed = engine_for(small_cfg(Policy::Duet), 1);
        let rd = ed.run(w);
        assert!(rs.completed == 40 && rd.completed == 40);
        assert!(
            rs.tbt.max > rd.tbt.max,
            "sglang-default max tbt {} should exceed duet {}",
            rs.tbt.max,
            rd.tbt.max
        );
    }

    #[test]
    fn oversized_prompt_is_dropped_not_deadlocked() {
        let mut cfg = small_cfg(Policy::VllmChunked);
        cfg.gpu_mem_util = 0.25; // tiny KV space
        let mut e = engine_for(cfg, 1);
        // One prompt far larger than KV capacity.
        let kv_tokens = e.cfg.kv_capacity_tokens();
        let w = fixed_workload(1, kv_tokens * 2, 4, 1.0, 1);
        let rep = e.run(w);
        assert_eq!(rep.completed, 0);
        assert_eq!(e.dropped, 1);
    }

    #[test]
    fn events_logged_when_enabled() {
        let mut e = engine_for(small_cfg(Policy::Duet), 1);
        e.log_events = true;
        let w = fixed_workload(10, 4000, 16, 8.0, 1);
        e.run(w);
        assert!(!e.events.is_empty());
        // events must tile the timeline monotonically
        assert!(e.events.windows(2).all(|w| w[1].t_start >= w[0].t_start));
    }
}
