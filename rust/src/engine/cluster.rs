//! The multi-worker discrete-event cluster loop.
//!
//! One loop serves every multi-GPU topology: [`ClusterEngine`] owns a set
//! of [`Worker`]s (each an [`EngineCore`] plus a [`WorkerRole`]), a global
//! arrival stream, a pluggable [`Router`], and a prefill→decode KV
//! [`Transfer`] queue.
//!
//! The loop is *re-entrant and incrementally fed*: [`inject`] accepts one
//! request at a time (sorted into the arrival stream), [`step_next`]
//! advances the cluster by exactly one worker event, and [`drain`] runs
//! the loop dry and folds every worker's recorder into one merged
//! [`Report`]. The batch entry point [`run`] is a thin replay over that
//! incremental API — inject the whole workload, then drain — so there is
//! exactly one cluster event loop in the crate, and the same loop serves
//! *live* traffic: the cluster implements
//! [`ServingTopology`](super::ServingTopology), which is how
//! [`crate::server::ServerCore`] routes live submissions (with streaming,
//! cancel, backpressure and graceful drain) across N workers.
//!
//! Each [`step_next`] advances whichever worker has the smallest local
//! clock, picked O(1) from an indexed min-heap event queue
//! ([`MinClockHeap`]) that is updated on every clock mutation (step,
//! park, offline jump, epoch re-base) — the retained naive O(N) scan
//! stays behind [`set_naive_scan`](ClusterEngine::set_naive_scan) as the
//! property-tested reference and bench baseline:
//!
//! [`inject`]: ClusterEngine::inject
//! [`step_next`]: ClusterEngine::step_next
//! [`drain`]: ClusterEngine::drain
//! [`run`]: ClusterEngine::run
//!
//! - arrivals with `arrival ≤ now` are routed to a worker *at arrival
//!   time* (no static sharding — replicas are genuinely
//!   time-interleaved);
//! - `Unified` workers run the shared per-iteration step
//!   ([`EngineCore::step_once`]);
//! - `Prefill` workers run the same shared step under a
//!   [`PrefillOnlyScheduler`]; each step, requests whose prompt completed
//!   are extracted and their KV emitted as transfers;
//! - ready transfers are routed to a decode worker through the same
//!   [`Router`] seam arrivals use (at transfer-ready time, against live
//!   decode-side load);
//! - `Decode` workers admit the transfers routed to them and run
//!   decode-only batches;
//! - an optional Dynamo-style planner flips worker roles under sustained
//!   imbalance (role switch preempts in-flight work and costs
//!   `reconfig_s` of downtime).
//!
//! Replication and disaggregation are just worker/role configurations of
//! this one loop — see [`super::ReplicatedEngine`] and
//! [`super::DisaggEngine`].

use std::collections::VecDeque;

use crate::config::{GpuSpec, Policy, ServingConfig};
use crate::metrics::{Recorder, RecorderMode, Report};
use crate::request::{Phase, Request, RequestId};
use crate::sched::{
    scheduler_for, IterationPlan, PrefillOnlyScheduler, SchedInput, Scheduler,
};
use crate::workload::Workload;

use super::backend::ExecutionBackend;
use super::clockheap::MinClockHeap;
use super::core::{CoreStep, EngineCore, REBASE_FRACTION};
use super::elastic::{ElasticPlanner, FleetSignals, PlannerMode, LONG_PROMPT_TOKENS};
use super::router::{RouteCandidate, Router};
use super::topology::{ServingTopology, TopologyLoad, TopologyStep};

/// Clock nudge when a worker parks with nothing to do, so the min-clock
/// selection always makes progress.
const PARK_EPS: f64 = 1e-3;

/// What a worker does with the requests routed to it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerRole {
    /// Full serving loop (scheduler-driven prefill + decode).
    Unified,
    /// Prompt processing only; finished prompts hand their KV to a decode
    /// worker via the transfer queue.
    Prefill,
    /// Continuous decode batching over transferred KV.
    Decode,
}

impl WorkerRole {
    /// Index into per-role arrays (the [`crate::metrics::ROLE_NAMES`]
    /// order: unified, prefill, decode).
    pub fn index(&self) -> usize {
        match self {
            WorkerRole::Unified => 0,
            WorkerRole::Prefill => 1,
            WorkerRole::Decode => 2,
        }
    }

    pub fn role_name(&self) -> &'static str {
        match self {
            WorkerRole::Unified => "unified",
            WorkerRole::Prefill => "prefill",
            WorkerRole::Decode => "decode",
        }
    }
}

/// One GPU group inside the cluster.
pub struct Worker {
    pub core: EngineCore,
    pub role: WorkerRole,
    /// Worker is reconfiguring (role switch) until this time.
    pub offline_until: f64,
    /// Absolute engine time (`epoch_offset + clock`) the worker entered
    /// its current role — per-role occupancy accounting.
    pub role_since: f64,
}

impl Worker {
    fn accepts_arrivals(&self) -> bool {
        matches!(self.role, WorkerRole::Unified | WorkerRole::Prefill)
    }
}

/// A request whose prefill finished and whose KV is in flight to a decode
/// worker.
struct Transfer {
    request: Request,
    ready_at: f64,
    /// Destination decode worker, routed at transfer-ready time through
    /// the cluster's [`Router`]. `None` until routed (or after a KV-full
    /// bounce / role flip invalidated the assignment).
    assigned: Option<usize>,
}

/// Placeholder scheduler for decode-role workers: their decode-only
/// batches are packed by [`ClusterEngine::step_decode`] over transferred
/// KV, never planned by `EngineCore::step_once`.
struct RoleScheduler;

impl Scheduler for RoleScheduler {
    fn plan(&mut self, _input: &SchedInput<'_>) -> IterationPlan {
        IterationPlan::Idle
    }

    fn name(&self) -> String {
        "role-worker".to_string()
    }

    /// A decode-role worker has no prompt capacity to spare.
    fn prefill_headroom(&self) -> f64 {
        0.0
    }
}

/// The event-driven cluster core.
pub struct ClusterEngine {
    pub cfg: ServingConfig,
    pub workers: Vec<Worker>,
    router: Box<dyn Router>,
    /// Not yet arrived, sorted by arrival time.
    pending: VecDeque<Request>,
    transfers: Vec<Transfer>,
    /// System-level metrics, folded from the workers at the end of `run`.
    pub metrics: Recorder,
    /// Finished requests from all workers (moved here at the end of `run`).
    pub finished: Vec<Request>,
    /// Requests dropped (divergence drain + per-worker drops, folded at
    /// the end of `run`).
    pub dropped: u64,
    /// Enable Dynamo-planner-style runtime role reconfiguration.
    pub reconfigurable: bool,
    /// Downtime for a role switch (paper: ~40 s).
    pub reconfig_s: f64,
    /// Planner check interval.
    pub planner_interval: f64,
    next_planner_check: f64,
    pub reconfigs: u64,
    /// Planner mode. [`PlannerMode::Off`] preserves the legacy behaviour
    /// (the `reconfigurable` flag alone selects the static Dynamo-style
    /// planner); `Static`/`Elastic` select a planner explicitly.
    planner: PlannerMode,
    /// Goodput-forecast planner state, built lazily by
    /// [`set_planner`](ClusterEngine::set_planner).
    elastic: Option<ElasticPlanner>,
    /// Completed per-role occupancy seconds (unified/prefill/decode),
    /// accumulated at each role change; live intervals are added by
    /// [`role_occupancy`](ClusterEngine::role_occupancy).
    role_occupancy_acc: [f64; 3],
    /// Report label for homogeneous (all-unified) clusters.
    name: String,
    /// Worker state was already folded into `metrics`/`finished`
    /// ([`drain`](ClusterEngine::drain) ran); folding twice would double
    /// count.
    folded: bool,
    /// The worker the last [`step_next`](ClusterEngine::step_next)
    /// advanced — only it can carry new tokens, so the live-serving pump
    /// visits just that worker instead of rescanning the fleet.
    stepped_worker: Option<usize>,
    /// Engine-clock epochs completed (cluster-wide clock re-bases).
    pub epoch: u64,
    /// Engine-clock seconds accumulated in previous epochs. All workers
    /// are shifted by a *common* delta at re-base, so one offset is the
    /// cluster's absolute time base (worker clocks keep their relative
    /// stagger).
    pub epoch_offset: f64,
    /// Event queue: indexed min-heap over worker clocks, kept in sync
    /// with every clock mutation, so the next-event pick is O(1) and each
    /// event O(log N) instead of an O(N) fleet scan. Selection order is
    /// bit-identical to the naive scan (total order on clock, ties to the
    /// lowest worker index).
    clocks: MinClockHeap,
    /// Running maximum worker clock. Valid as a scalar because worker
    /// clocks are monotone non-decreasing except for the common-delta
    /// epoch re-base shift (which subtracts the same delta here).
    max_clock: f64,
    /// Incrementally maintained per-worker load board, in worker-index
    /// order: `loads[i]` always equals a fresh [`RouteCandidate`]
    /// snapshot of worker `i` (re-synced after every event that touches
    /// the worker), so routing no longer recomputes O(queue) load sums
    /// across the fleet per arrival.
    loads: Vec<RouteCandidate>,
    /// `busy[i]` == `workers[i].core.has_local_work()`, with the count of
    /// `true` entries in `busy_count` — O(1) `all_done`.
    busy: Vec<bool>,
    busy_count: usize,
    /// Sum of worker waiting-queue lengths — O(1) `queued()`.
    total_queue: usize,
    /// Scratch: router candidates for the current decision (reused).
    cand_scratch: Vec<RouteCandidate>,
    /// Scratch: per-decision overlaid copy of `cand_scratch`.
    cand_overlay: Vec<RouteCandidate>,
    /// Scratch: in-flight transfer-assignment overlays, indexed by worker.
    extra_queue: Vec<usize>,
    extra_tokens: Vec<u64>,
    extra_kv: Vec<u64>,
    /// Scratch: (request, transfer-duration) pairs extracted from a
    /// prefill worker per event.
    extract_scratch: Vec<(Request, f64)>,
    /// Pin the retained O(N)-scan reference implementation (naive
    /// min-clock selection, per-decision candidate rebuilds with
    /// recomputed load sums, allocating transfer routing). Trajectories
    /// must be byte-identical to the fast path — property-tested in
    /// `tests/fleet_hotpath.rs` — and it is the bench baseline.
    naive_scan: bool,
}

impl ClusterEngine {
    /// N identical unified workers (model replicas) behind `router`.
    pub fn replicated(
        cfg: ServingConfig,
        replicas: u32,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ClusterEngine {
        assert!(replicas >= 1, "need at least one replica");
        let workers: Vec<Worker> = (0..replicas)
            .map(|i| Worker {
                core: EngineCore::new(cfg.clone(), scheduler_for(&cfg), seed + i as u64),
                role: WorkerRole::Unified,
                offline_until: 0.0,
                role_since: 0.0,
            })
            .collect();
        let name = format!("{}x{}", workers[0].core.policy_name(), replicas);
        ClusterEngine::assemble(cfg, workers, router, name)
    }

    /// PD-disaggregated topology: `prefill_gpus` + `decode_gpus` workers
    /// on identical GPUs.
    pub fn disagg(
        cfg: ServingConfig,
        prefill_gpus: u32,
        decode_gpus: u32,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ClusterEngine {
        let gpu = cfg.gpu.clone();
        ClusterEngine::disagg_hetero(cfg, prefill_gpus, gpu.clone(), decode_gpus, gpu, seed, router)
    }

    /// Heterogeneous topology (Appendix B future work): prefill workers on
    /// `prefill_gpu` parts, decode workers on `decode_gpu` parts — e.g.
    /// compute-optimized prefill + memory-optimized decode.
    #[allow(clippy::too_many_arguments)]
    pub fn disagg_hetero(
        cfg: ServingConfig,
        prefill_gpus: u32,
        prefill_gpu: GpuSpec,
        decode_gpus: u32,
        decode_gpu: GpuSpec,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ClusterEngine {
        assert!(prefill_gpus >= 1 && decode_gpus >= 1);
        let mk = |role: WorkerRole, spec: &GpuSpec, i: u32| {
            // Each worker is a single GPU holding a full model replica.
            let mut wcfg = cfg.clone();
            wcfg.tp = 1;
            wcfg.gpu = spec.clone();
            // Prefill workers run the shared per-iteration step under a
            // prefill-only policy; decode batches are packed by the
            // cluster from transferred KV.
            let sched: Box<dyn Scheduler> = match role {
                WorkerRole::Prefill => Box::new(PrefillOnlyScheduler::new(
                    wcfg.token_budget as u64,
                    wcfg.max_batch as usize,
                    wcfg.kv_watermark,
                )),
                _ => Box::new(RoleScheduler),
            };
            Worker {
                core: EngineCore::new(wcfg, sched, seed + i as u64),
                role,
                offline_until: 0.0,
                role_since: 0.0,
            }
        };
        let mut workers = Vec::new();
        for i in 0..prefill_gpus {
            workers.push(mk(WorkerRole::Prefill, &prefill_gpu, i));
        }
        for i in 0..decode_gpus {
            workers.push(mk(WorkerRole::Decode, &decode_gpu, prefill_gpus + i));
        }
        ClusterEngine::assemble(cfg, workers, router, String::new())
    }

    fn assemble(
        cfg: ServingConfig,
        workers: Vec<Worker>,
        router: Box<dyn Router>,
        name: String,
    ) -> ClusterEngine {
        assert!(!workers.is_empty(), "cluster has no workers");
        let n = workers.len();
        let loads: Vec<RouteCandidate> = workers
            .iter()
            .enumerate()
            .map(|(i, w)| RouteCandidate {
                worker: i,
                queue_len: 0,
                outstanding_tokens: 0,
                kv_free_tokens: w.core.kv_free_tokens(),
                prefix_resident_tokens: w.core.prefix_resident_tokens(),
                prefix_overlap_tokens: 0,
                prefill_only: w.role == WorkerRole::Prefill,
            })
            .collect();
        ClusterEngine {
            cfg,
            workers,
            router,
            pending: VecDeque::new(),
            transfers: Vec::new(),
            metrics: Recorder::new(),
            finished: Vec::new(),
            dropped: 0,
            reconfigurable: false,
            reconfig_s: 40.0,
            planner_interval: 30.0,
            next_planner_check: 30.0,
            reconfigs: 0,
            planner: PlannerMode::Off,
            elastic: None,
            role_occupancy_acc: [0.0; 3],
            name,
            folded: false,
            stepped_worker: None,
            epoch: 0,
            epoch_offset: 0.0,
            clocks: MinClockHeap::new(n),
            max_clock: 0.0,
            loads,
            busy: vec![false; n],
            busy_count: 0,
            total_queue: 0,
            cand_scratch: Vec::new(),
            cand_overlay: Vec::new(),
            extra_queue: Vec::new(),
            extra_tokens: Vec::new(),
            extra_kv: Vec::new(),
            extract_scratch: Vec::new(),
            naive_scan: false,
        }
    }

    /// Switch to (or away from) the retained naive-scan reference path:
    /// O(N) min-clock scans, per-decision candidate snapshots with
    /// recomputed O(queue) load sums, and allocating transfer routing.
    /// Event trajectories are identical either way (property-tested);
    /// this exists as the comparison baseline for benches and tests.
    pub fn set_naive_scan(&mut self, on: bool) {
        self.naive_scan = on;
    }

    /// The worker the last [`step_next`](ClusterEngine::step_next)
    /// advanced (None after Exhausted/Diverged).
    pub fn last_stepped(&self) -> Option<usize> {
        self.stepped_worker
    }

    /// Re-sync worker `i`'s entry on the incremental load board and the
    /// busy/queue counters after an event touched it.
    fn sync_worker(&mut self, i: usize) {
        let prefill_only = self.workers[i].role == WorkerRole::Prefill;
        let core = &self.workers[i].core;
        let q = core.queue_len();
        self.total_queue = self.total_queue + q - self.loads[i].queue_len;
        self.loads[i] = RouteCandidate {
            worker: i,
            queue_len: q,
            outstanding_tokens: core.outstanding_tokens(),
            kv_free_tokens: core.kv_free_tokens(),
            prefix_resident_tokens: core.prefix_resident_tokens(),
            // Per-request overlap is a dispatch-time signal, filled into
            // the per-decision candidate copies, never the board.
            prefix_overlap_tokens: 0,
            prefill_only,
        };
        let b = core.has_local_work();
        if b != self.busy[i] {
            self.busy[i] = b;
            if b {
                self.busy_count += 1;
            } else {
                self.busy_count -= 1;
            }
        }
    }

    fn sync_all(&mut self) {
        for i in 0..self.workers.len() {
            self.sync_worker(i);
        }
    }

    /// Post-event bookkeeping for worker `idx`: publish its (possibly
    /// advanced) clock to the event queue, fold it into the running max,
    /// and re-sync its load-board entry.
    fn finish_event(&mut self, idx: usize) {
        let c = self.workers[idx].core.clock;
        self.clocks.update(idx, c);
        if c > self.max_clock {
            self.max_clock = c;
        }
        self.sync_worker(idx);
    }

    /// Swap the routing policy (builder-style, before `run`). The router
    /// dispatches both arrivals (to prefill/unified workers) and ready KV
    /// transfers (to decode workers).
    pub fn set_router(&mut self, router: Box<dyn Router>) {
        self.router = router;
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// (unified, prefill, decode) worker counts.
    pub fn role_counts(&self) -> (usize, usize, usize) {
        let count = |role| self.workers.iter().filter(|w| w.role == role).count();
        (
            count(WorkerRole::Unified),
            count(WorkerRole::Prefill),
            count(WorkerRole::Decode),
        )
    }

    fn system_name(&self) -> String {
        let (_, p, d) = self.role_counts();
        if p + d > 0 {
            format!("Dynamo-{p}P{d}D")
        } else if self.name.is_empty() {
            // A disagg-born cluster the elastic planner collapsed to
            // all-unified has no prebuilt label.
            format!("{}x{}", self.workers[0].core.policy_name(), self.workers.len())
        } else {
            self.name.clone()
        }
    }

    /// Run the whole workload to completion; returns the merged report.
    ///
    /// This is a thin batch replay over the incremental loop: inject
    /// every request, then [`drain`](ClusterEngine::drain).
    pub fn run(&mut self, workload: Workload) -> Report {
        for r in workload.sorted_by_arrival().requests {
            self.inject(r);
        }
        self.drain()
    }

    /// Feed one request into the shared arrival stream. Sorted insert by
    /// arrival time; equal arrivals keep injection order, so a caller
    /// that feeds an ordered stream reproduces the batch path exactly.
    pub fn inject(&mut self, r: Request) {
        // A drained cluster already folded its workers' recorders; work
        // injected after that would run but vanish from every later
        // report. Fail loudly instead.
        assert!(
            !self.folded,
            "cluster already drained; build a new engine for another run"
        );
        let pos = self.pending.partition_point(|q| q.arrival <= r.arrival);
        self.pending.insert(pos, r);
    }

    /// The cluster's arrival reference clock (epoch-local): the smallest
    /// worker clock, i.e. the time of the next event. O(1) off the event
    /// queue (the naive reference folds over the fleet).
    pub fn clock(&self) -> f64 {
        if self.naive_scan {
            return self
                .workers
                .iter()
                .map(|w| w.core.clock)
                .fold(f64::INFINITY, f64::min);
        }
        self.clocks.min_key()
    }

    /// Re-base the cluster clock to a new epoch when *every* queue is
    /// empty — no pending arrivals, no in-flight KV transfers, no queued
    /// or running work on any worker — and the epoch has consumed enough
    /// of the divergence horizon. All workers shift by one **common**
    /// delta (the minimum worker clock), preserving their relative
    /// stagger so the next epoch's min-clock event order is exactly the
    /// shifted continuation of this one; per-worker `max_engine_time`
    /// guards re-arm because local clocks drop toward 0. Returns whether
    /// a re-base happened.
    pub fn rebase_epoch(&mut self) -> bool {
        if !self.all_done() {
            return false;
        }
        let delta = self.clock();
        if !delta.is_finite() || delta <= REBASE_FRACTION * self.cfg.max_engine_time {
            return false;
        }
        self.shift_all(delta);
        true
    }

    /// The cluster-wide shift primitive shared by the threshold re-base
    /// and the forced pre-jump re-base: one common delta for every
    /// worker plus the cluster-level schedules.
    fn shift_all(&mut self, delta: f64) {
        for w in &mut self.workers {
            w.core.shift_clock(delta);
            w.offline_until -= delta;
        }
        // One common delta is monotone under IEEE-754 subtraction, so the
        // event queue keeps its order bit-exactly without re-sifting.
        self.clocks.shift_all(delta);
        self.max_clock -= delta;
        self.next_planner_check -= delta;
        self.epoch_offset += delta;
        self.epoch += 1;
    }

    /// Run the event loop until no work remains, then fold every worker's
    /// recorder/finished list into the merged system-level report.
    pub fn drain(&mut self) -> Report {
        loop {
            match self.step_next(None) {
                TopologyStep::Exhausted | TopologyStep::Diverged(_) => break,
                _ => {}
            }
        }
        ServingTopology::fold_report(self)
    }

    /// Merge per-worker metrics, drop counts and finished requests into
    /// the cluster-level recorder (idempotent; runs once).
    fn fold_workers(&mut self) {
        if self.folded {
            return;
        }
        self.folded = true;
        self.metrics.reconfigs = self.reconfigs;
        self.metrics.role_occupancy = self.role_occupancy();
        let mut duration = 0.0f64;
        for w in &mut self.workers {
            self.metrics.merge(&w.core.metrics);
            self.dropped += w.core.dropped;
            self.finished.append(&mut w.core.finished);
            w.core.pumped_finished = 0;
            // Absolute last-active time: invariant across epoch re-bases
            // (a worker idle since epoch 0 still contributes 0).
            duration = duration.max(w.core.total_active());
        }
        self.metrics.duration = duration;
    }

    /// Cross-worker invariants, for property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, w) in self.workers.iter().enumerate() {
            w.core
                .check_invariants()
                .map_err(|e| format!("worker {i}: {e}"))?;
        }
        for r in &self.finished {
            if r.generated != r.output_len || r.phase != Phase::Finished {
                return Err(format!("request {} retired unfinished", r.id));
            }
            if r.finished_at.unwrap_or(f64::NEG_INFINITY) < r.arrival {
                return Err(format!("request {} finished before arrival", r.id));
            }
            if r.first_token_at.unwrap_or(f64::NEG_INFINITY) < r.arrival {
                return Err(format!("request {} produced a token before arrival", r.id));
            }
        }
        // The incremental structures must equal recomputed-from-scratch
        // state at every quiescent point, in both scan modes (they are
        // maintained unconditionally; `naive_scan` only changes reads).
        let mut queue_sum = 0;
        let mut busy_sum = 0;
        for (i, w) in self.workers.iter().enumerate() {
            let fresh = RouteCandidate {
                worker: i,
                queue_len: w.core.queue_len(),
                outstanding_tokens: w.core.recompute_outstanding(),
                kv_free_tokens: w.core.kv_free_tokens(),
                prefix_resident_tokens: w.core.prefix_resident_tokens(),
                prefix_overlap_tokens: 0,
                prefill_only: w.role == WorkerRole::Prefill,
            };
            if self.loads[i] != fresh {
                return Err(format!(
                    "load board stale for worker {i}: {:?} != fresh {:?}",
                    self.loads[i], fresh
                ));
            }
            if self.busy[i] != w.core.has_local_work() {
                return Err(format!("busy flag stale for worker {i}"));
            }
            if self.clocks.key(i).to_bits() != w.core.clock.to_bits() {
                return Err(format!(
                    "event queue stale for worker {i}: key {} != clock {}",
                    self.clocks.key(i),
                    w.core.clock
                ));
            }
            queue_sum += fresh.queue_len;
            busy_sum += usize::from(self.busy[i]);
        }
        if self.total_queue != queue_sum {
            return Err(format!(
                "total_queue {} != recomputed {queue_sum}",
                self.total_queue
            ));
        }
        if self.busy_count != busy_sum {
            return Err(format!(
                "busy_count {} != recomputed {busy_sum}",
                self.busy_count
            ));
        }
        if self.min_clock_worker()
            != self
                .workers
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.core.clock.total_cmp(&b.1.core.clock))
                .map(|(i, _)| i)
                .expect("cluster has no workers")
        {
            return Err("event queue min pick != naive scan pick".into());
        }
        if self.max_clock.to_bits() != self.max_clock_scan().to_bits() {
            return Err(format!(
                "running max clock {} != fleet scan {}",
                self.max_clock,
                self.max_clock_scan()
            ));
        }
        Ok(())
    }

    fn all_done(&self) -> bool {
        if self.naive_scan {
            return self.pending.is_empty()
                && self.transfers.is_empty()
                && self.workers.iter().all(|w| !w.core.has_local_work());
        }
        self.pending.is_empty() && self.transfers.is_empty() && self.busy_count == 0
    }

    /// The next-event worker. O(1) off the event queue; the naive
    /// reference scans (`min_by` keeps the first of equal minimums —
    /// exactly the heap's total-order-then-lowest-index tie-break, so the
    /// two paths pick identically).
    fn min_clock_worker(&self) -> usize {
        if self.naive_scan {
            return self
                .workers
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.core.clock.total_cmp(&b.1.core.clock))
                .map(|(i, _)| i)
                .expect("cluster has no workers");
        }
        self.clocks.peek()
    }

    fn max_clock_scan(&self) -> f64 {
        self.workers
            .iter()
            .map(|w| w.core.clock)
            .fold(0.0f64, f64::max)
    }

    /// Advance an idle worker's clock to its next event, or park it just
    /// past the rest of the fleet so min-clock selection keeps moving.
    fn idle_advance(&mut self, idx: usize, next_event: Option<f64>) {
        match next_event {
            Some(t) => {
                let core = &mut self.workers[idx].core;
                core.clock = core.clock.max(t);
            }
            None => {
                // Clocks are monotone outside the common re-base shift,
                // so the running max equals the fleet scan.
                let max_all = if self.naive_scan {
                    self.max_clock_scan()
                } else {
                    self.max_clock
                };
                self.workers[idx].core.clock = max_all + PARK_EPS;
            }
        }
    }

    /// The earliest known future arrival: the head of the internal
    /// arrival stream (batch path) or the caller's hint about the next
    /// not-yet-injected submission (live path), whichever comes first.
    fn next_arrival(&self, hint: Option<f64>) -> Option<f64> {
        let internal = self.pending.front().map(|r| r.arrival);
        match (internal, hint) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Advance the cluster by one worker-event (the min-clock loop).
    ///
    /// `next_arrival` hints the earliest arrival the caller has not yet
    /// [`inject`](ClusterEngine::inject)ed, so idle workers advance to it
    /// instead of parking — this is what makes a live caller (the serving
    /// front-end feeding submissions as they become due) take exactly the
    /// same event trajectory as the batch replay that holds the whole
    /// stream up front.
    pub fn step_next(&mut self, next_arrival: Option<f64>) -> TopologyStep {
        if self.all_done() && next_arrival.is_none() {
            // Fully idle with no future arrival hinted: the only safe
            // moment to re-base the epoch clock.
            self.rebase_epoch();
            self.stepped_worker = None;
            return TopologyStep::Exhausted;
        }
        let idx = self.min_clock_worker();
        self.stepped_worker = Some(idx);
        let now = self.workers[idx].core.clock;
        if now > self.cfg.max_engine_time {
            // Diverged: drain bookkeeping everywhere and report every
            // request that was discarded so streams can be closed.
            let mut victims: Vec<RequestId> = self.pending.iter().map(|r| r.id).collect();
            victims.extend(self.transfers.iter().map(|t| t.request.id));
            for w in &self.workers {
                victims.extend(w.core.waiting.iter().map(|r| r.id));
                victims.extend(w.core.running.iter().map(|r| r.id));
            }
            self.dropped += (self.pending.len() + self.transfers.len()) as u64;
            self.pending.clear();
            self.transfers.clear();
            for w in &mut self.workers {
                w.core.drain_diverged();
            }
            self.sync_all();
            self.stepped_worker = None;
            return TopologyStep::Diverged(victims);
        }

        self.dispatch_arrivals(now);
        self.route_transfers(now);

        let planner = self.effective_planner();
        if planner != PlannerMode::Off && now >= self.next_planner_check {
            match planner {
                PlannerMode::Static => self.plan_reconfig(now),
                PlannerMode::Elastic => self.plan_elastic(now),
                PlannerMode::Off => unreachable!(),
            }
            self.next_planner_check = now + self.planner_interval;
        }

        if self.workers[idx].offline_until > now {
            self.workers[idx].core.clock = self.workers[idx].offline_until;
            self.finish_event(idx);
            return TopologyStep::Progressed;
        }

        let dropped = match self.workers[idx].role {
            WorkerRole::Unified => self.step_unified(idx, next_arrival),
            WorkerRole::Prefill => self.step_prefill(idx, next_arrival),
            WorkerRole::Decode => {
                self.step_decode(idx);
                None
            }
        };
        self.finish_event(idx);
        match dropped {
            Some(id) => TopologyStep::Dropped(id),
            None => TopologyStep::Progressed,
        }
    }

    /// Fill `cand_scratch` with the load-board entries of the workers
    /// satisfying `eligible`, in worker order — an allocation-free copy
    /// of already-maintained O(1) signals (the load board is re-synced
    /// after every event, so these equal fresh snapshots). Offline
    /// workers are excluded unless *every* eligible worker is offline
    /// (then the request must queue somewhere).
    fn fill_candidates(&mut self, now: f64, eligible: impl Fn(&Worker) -> bool) {
        self.cand_scratch.clear();
        for (i, w) in self.workers.iter().enumerate() {
            if eligible(w) && w.offline_until <= now {
                self.cand_scratch.push(self.loads[i]);
            }
        }
        if self.cand_scratch.is_empty() {
            for (i, w) in self.workers.iter().enumerate() {
                if eligible(w) {
                    self.cand_scratch.push(self.loads[i]);
                }
            }
        }
    }

    /// The naive reference: rebuild candidate snapshots from worker state
    /// per decision, recomputing each load sum in O(queue) — the
    /// per-arrival cost profile the load board replaced.
    fn candidates_where_naive(
        &self,
        now: f64,
        eligible: impl Fn(&Worker) -> bool,
    ) -> Vec<RouteCandidate> {
        let snapshot = |(i, w): (usize, &Worker)| RouteCandidate {
            worker: i,
            queue_len: w.core.queue_len(),
            outstanding_tokens: w.core.recompute_outstanding(),
            kv_free_tokens: w.core.kv_free_tokens(),
            prefix_resident_tokens: w.core.prefix_resident_tokens(),
            prefix_overlap_tokens: 0,
            prefill_only: w.role == WorkerRole::Prefill,
        };
        let online: Vec<RouteCandidate> = self
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| eligible(w) && w.offline_until <= now)
            .map(snapshot)
            .collect();
        if !online.is_empty() {
            return online;
        }
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, w)| eligible(w))
            .map(snapshot)
            .collect()
    }

    /// Route every arrival with `arrival ≤ now` to a worker, at arrival
    /// time, through the pluggable router.
    fn dispatch_arrivals(&mut self, now: f64) {
        let due = self.pending.partition_point(|r| r.arrival <= now);
        if due == 0 {
            return;
        }
        let mut batch: Vec<Request> = self.pending.drain(..due).collect();
        // Class-aware dispatch: more urgent classes route first within the
        // due cohort. The sort is stable, so single-class traffic keeps
        // pure arrival order and the legacy trajectory is unchanged.
        batch.sort_by_key(|r| r.class);
        for req in batch {
            // Cache-aware dispatch signal: with prefix caching on, probe
            // every eligible worker's index for this prompt once and fill
            // the per-decision candidate copies (the board keeps overlap
            // at 0 — it is request-specific). Identical on both scan
            // paths, preserving the fast ≡ naive trajectory property.
            let keys = if self.cfg.prefix_cache {
                crate::kvcache::block_keys(&req, self.cfg.kv_block_tokens)
            } else {
                Vec::new()
            };
            let choice = if self.naive_scan {
                let mut candidates = self.candidates_where_naive(now, Worker::accepts_arrivals);
                assert!(
                    !candidates.is_empty(),
                    "no worker accepts arrivals (topology without prefill/unified workers)"
                );
                if !keys.is_empty() {
                    for c in &mut candidates {
                        c.prefix_overlap_tokens =
                            self.workers[c.worker].core.prefix_overlap_tokens(&keys);
                    }
                }
                let c = self.router.route(&req, &candidates);
                assert!(
                    candidates.iter().any(|x| x.worker == c),
                    "router `{}` dispatched to ineligible worker {c}",
                    self.router.name()
                );
                c
            } else {
                self.fill_candidates(now, Worker::accepts_arrivals);
                assert!(
                    !self.cand_scratch.is_empty(),
                    "no worker accepts arrivals (topology without prefill/unified workers)"
                );
                if !keys.is_empty() {
                    for c in &mut self.cand_scratch {
                        c.prefix_overlap_tokens =
                            self.workers[c.worker].core.prefix_overlap_tokens(&keys);
                    }
                }
                let c = self.router.route(&req, &self.cand_scratch);
                assert!(
                    self.cand_scratch.iter().any(|x| x.worker == c),
                    "router `{}` dispatched to ineligible worker {c}",
                    self.router.name()
                );
                c
            };
            self.workers[choice].core.inject(req);
            self.sync_worker(choice);
        }
    }

    /// Route every ready, unrouted transfer to a decode worker through
    /// the pluggable router (the second ROADMAP routing seam: transfers
    /// are no longer hard-wired to the least-loaded decode worker).
    /// In-flight assignments are folded into the candidates' load signals
    /// so a burst of simultaneous transfers spreads across workers.
    /// Allocation-free: overlays and candidate copies live in reused
    /// scratch buffers, and the common no-routable-transfer tick returns
    /// before touching any of them.
    fn route_transfers(&mut self, now: f64) {
        if self.naive_scan {
            return self.route_transfers_naive(now);
        }
        if !self
            .transfers
            .iter()
            .any(|t| t.assigned.is_none() && t.ready_at <= now)
        {
            return;
        }
        let n = self.workers.len();
        self.extra_queue.clear();
        self.extra_queue.resize(n, 0);
        self.extra_tokens.clear();
        self.extra_tokens.resize(n, 0);
        self.extra_kv.clear();
        self.extra_kv.resize(n, 0);
        for t in &self.transfers {
            if let Some(w) = t.assigned {
                self.extra_queue[w] += 1;
                self.extra_tokens[w] += t.request.output_len - t.request.generated;
                self.extra_kv[w] += t.request.context_len();
            }
        }
        // Worker state cannot change inside this loop; fill the base
        // candidates once and re-apply only the in-flight-assignment
        // overlay per decision.
        self.fill_candidates(now, |w| w.role == WorkerRole::Decode);
        if self.cand_scratch.is_empty() {
            return; // topology without decode workers
        }
        let mut i = 0;
        while i < self.transfers.len() {
            if self.transfers[i].assigned.is_none() && self.transfers[i].ready_at <= now {
                self.cand_overlay.clear();
                self.cand_overlay.extend_from_slice(&self.cand_scratch);
                for c in &mut self.cand_overlay {
                    c.queue_len += self.extra_queue[c.worker];
                    c.outstanding_tokens += self.extra_tokens[c.worker];
                    c.kv_free_tokens = c.kv_free_tokens.saturating_sub(self.extra_kv[c.worker]);
                }
                let choice = self.router.route(&self.transfers[i].request, &self.cand_overlay);
                assert!(
                    self.cand_overlay.iter().any(|c| c.worker == choice),
                    "router `{}` routed a transfer to ineligible worker {choice}",
                    self.router.name()
                );
                self.transfers[i].assigned = Some(choice);
                self.extra_queue[choice] += 1;
                self.extra_tokens[choice] +=
                    self.transfers[i].request.output_len - self.transfers[i].request.generated;
                self.extra_kv[choice] += self.transfers[i].request.context_len();
            }
            i += 1;
        }
    }

    /// The naive transfer-routing reference: the pre-event-queue body,
    /// with its three per-call overlay allocations and per-decision
    /// snapshot rebuild.
    fn route_transfers_naive(&mut self, now: f64) {
        let n = self.workers.len();
        let mut extra_queue = vec![0usize; n];
        let mut extra_tokens = vec![0u64; n];
        let mut extra_kv = vec![0u64; n];
        for t in &self.transfers {
            if let Some(w) = t.assigned {
                extra_queue[w] += 1;
                extra_tokens[w] += t.request.output_len - t.request.generated;
                extra_kv[w] += t.request.context_len();
            }
        }
        let mut base: Option<Vec<RouteCandidate>> = None;
        let mut i = 0;
        while i < self.transfers.len() {
            if self.transfers[i].assigned.is_none() && self.transfers[i].ready_at <= now {
                let base = base.get_or_insert_with(|| {
                    self.candidates_where_naive(now, |w| w.role == WorkerRole::Decode)
                });
                if base.is_empty() {
                    return; // topology without decode workers
                }
                let mut candidates = base.clone();
                for c in &mut candidates {
                    c.queue_len += extra_queue[c.worker];
                    c.outstanding_tokens += extra_tokens[c.worker];
                    c.kv_free_tokens = c.kv_free_tokens.saturating_sub(extra_kv[c.worker]);
                }
                let choice = self.router.route(&self.transfers[i].request, &candidates);
                assert!(
                    candidates.iter().any(|c| c.worker == choice),
                    "router `{}` routed a transfer to ineligible worker {choice}",
                    self.router.name()
                );
                self.transfers[i].assigned = Some(choice);
                extra_queue[choice] += 1;
                extra_tokens[choice] +=
                    self.transfers[i].request.output_len - self.transfers[i].request.generated;
                extra_kv[choice] += self.transfers[i].request.context_len();
            }
            i += 1;
        }
    }

    /// One shared-core iteration on a unified worker; on idle, advance
    /// its clock to the next event (arrival or park behind the fleet).
    /// Returns the id of a dropped never-fits request, if any.
    fn step_unified(&mut self, idx: usize, hint: Option<f64>) -> Option<RequestId> {
        let allow_drop = self.pending.is_empty() && hint.is_none();
        match self.workers[idx].core.step_once(allow_drop) {
            CoreStep::Executed => None,
            CoreStep::DroppedHead(id) => Some(id),
            CoreStep::Idle => {
                // Next event: the next arrival, which dispatch guarantees
                // is strictly in the future (everything ≤ now was
                // delivered).
                let next_arrival = self.next_arrival(hint);
                if next_arrival.is_none() && self.workers[idx].core.has_local_work() {
                    // Scheduler idled with admitted work (should not
                    // happen); nudge so the min-clock loop cannot
                    // livelock.
                    self.workers[idx].core.clock += PARK_EPS;
                } else {
                    self.idle_advance(idx, next_arrival);
                }
                None
            }
        }
    }

    /// One shared-core iteration on a prefill worker (prefill-only
    /// scheduler), then extract completed prompts into the transfer
    /// queue: a request whose phase reached `Decode` produced its first
    /// output token from the prefill logits and its KV now moves to a
    /// decode worker.
    fn step_prefill(&mut self, idx: usize, hint: Option<f64>) -> Option<RequestId> {
        let allow_drop = self.pending.is_empty() && hint.is_none();
        match self.workers[idx].core.step_once(allow_drop) {
            CoreStep::Executed => {
                // The prefill worker holds no paged KV for a request once
                // its cache leaves for decode; the extraction reuses one
                // cluster-level scratch vec instead of allocating per
                // event.
                let mut out = std::mem::take(&mut self.extract_scratch);
                out.clear();
                let core = &mut self.workers[idx].core;
                let t_end = core.clock;
                core.extract_decode_ready(&mut out);
                for (r, dt) in out.drain(..) {
                    self.transfers.push(Transfer {
                        request: r,
                        ready_at: t_end + dt,
                        assigned: None,
                    });
                }
                self.extract_scratch = out;
                None
            }
            CoreStep::DroppedHead(id) => Some(id),
            CoreStep::Idle => {
                let next_arrival = self.next_arrival(hint);
                if next_arrival.is_none() && self.workers[idx].core.has_local_work() {
                    self.workers[idx].core.clock += PARK_EPS;
                } else {
                    self.idle_advance(idx, next_arrival);
                }
                None
            }
        }
    }

    /// One decode iteration on worker `idx`: admit the transfers the
    /// router assigned here, then run one decode-only step over the whole
    /// running batch.
    fn step_decode(&mut self, idx: usize) {
        let now = self.workers[idx].core.clock;
        let mut i = 0;
        while i < self.transfers.len() {
            if self.transfers[i].assigned == Some(idx) && self.transfers[i].ready_at <= now {
                let t = self.transfers.swap_remove(i);
                match self.workers[idx].core.admit_transferred(t.request) {
                    Ok(()) => {}
                    Err(r) => {
                        // Decode KV full: bounce the transfer back for
                        // re-routing (possibly to another worker) later.
                        self.transfers.push(Transfer {
                            request: r,
                            ready_at: now + 0.05,
                            assigned: None,
                        });
                        break;
                    }
                }
            } else {
                i += 1;
            }
        }

        if self.workers[idx].core.running_len() == 0 {
            // Idle: jump to the next transfer-ready time or park.
            let next = self
                .transfers
                .iter()
                .map(|t| t.ready_at)
                .fold(f64::INFINITY, f64::min);
            self.idle_advance(idx, next.is_finite().then_some(next));
            return;
        }

        self.workers[idx].core.decode_step_transferred();
    }

    /// Dynamo-planner emulation: flip one worker's role when the phases
    /// are persistently imbalanced. Switching preempts in-flight work
    /// (recompute: back to a prefill worker) and takes `reconfig_s`.
    fn plan_reconfig(&mut self, now: f64) {
        let (_, p_count, d_count) = self.role_counts();
        let queue_pressure: usize = self
            .workers
            .iter()
            .filter(|w| w.role == WorkerRole::Prefill)
            .map(|w| w.core.queue_len())
            .sum();
        let decode_load: usize = self
            .workers
            .iter()
            .filter(|w| w.role == WorkerRole::Decode)
            .map(|w| w.core.running_len())
            .sum();

        // Prefill backlogged, decode workers light: D -> P.
        if queue_pressure > 8 * p_count && d_count > 1 && decode_load < 4 * d_count {
            let victim = self
                .workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.role == WorkerRole::Decode)
                .min_by_key(|(_, w)| w.core.running_len())
                .map(|(i, _)| i);
            if let Some(v) = victim {
                // Decode workers queue nothing (transfers go straight to
                // running), so displacing everything drains exactly the
                // running set the old role held.
                let mut drained: Vec<Request> = Vec::new();
                self.workers[v].core.displace_all(&mut drained);
                // Transfers already routed to this worker must be
                // re-routed: it no longer decodes.
                for t in &mut self.transfers {
                    if t.assigned == Some(v) {
                        t.assigned = None;
                    }
                }
                self.note_role_change(v, WorkerRole::Prefill);
                self.workers[v].offline_until = now + self.reconfig_s;
                self.reconfigs += 1;
                for r in drained {
                    // Preempted decodes restart from scratch.
                    let fresh = r.reset_for_retry();
                    let tgt = self.lightest_prefill_worker(now);
                    self.workers[tgt].core.inject_front(fresh);
                }
                self.sync_all();
            }
        // Decode overloaded, prefill side keeping up: P -> D.
        } else if queue_pressure < 4 * p_count && decode_load > 8 * d_count.max(1) && p_count > 1 {
            let victim = self
                .workers
                .iter()
                .position(|w| w.role == WorkerRole::Prefill);
            if let Some(v) = victim {
                // Displace both the queued prompts and the in-flight
                // (partially prefilled) ones — prefill progress is lost.
                let mut moved: Vec<Request> = Vec::new();
                self.workers[v].core.displace_all(&mut moved);
                self.note_role_change(v, WorkerRole::Decode);
                self.workers[v].offline_until = now + self.reconfig_s;
                self.reconfigs += 1;
                for r in moved {
                    // Re-route the displaced queue to the surviving
                    // prefill workers.
                    let tgt = self.lightest_prefill_worker(now);
                    self.workers[tgt].core.inject(r.reset_for_retry());
                }
                self.sync_all();
            }
        }
    }

    /// The prefill worker with the shortest queue, preferring online ones.
    fn lightest_prefill_worker(&self, now: f64) -> usize {
        let pick = |require_online: bool| {
            self.workers
                .iter()
                .enumerate()
                .filter(|(_, w)| {
                    w.role == WorkerRole::Prefill && (!require_online || w.offline_until <= now)
                })
                .min_by_key(|(i, w)| (w.core.queue_len(), *i))
                .map(|(i, _)| i)
        };
        pick(true)
            .or_else(|| pick(false))
            .expect("topology lost its last prefill worker")
    }

    /// Select the planner. [`PlannerMode::Elastic`] lazily builds the
    /// goodput-forecast planner from this cluster's serving config.
    pub fn set_planner(&mut self, mode: PlannerMode) {
        self.planner = mode;
        if mode == PlannerMode::Elastic && self.elastic.is_none() {
            let predictor = crate::roofline::Predictor::new(
                self.cfg.model.clone(),
                self.cfg.gpu.clone(),
                self.cfg.tp,
            );
            self.elastic = Some(ElasticPlanner::new(
                predictor,
                self.cfg.token_budget as u64,
                self.cfg.tbt_slo,
                self.reconfig_s,
            ));
        }
    }

    pub fn planner_mode(&self) -> PlannerMode {
        self.planner
    }

    /// Mutable access to the elastic planner's knobs (hysteresis dwell,
    /// margin), once [`set_planner`](ClusterEngine::set_planner) built it.
    pub fn elastic_planner_mut(&mut self) -> Option<&mut ElasticPlanner> {
        self.elastic.as_mut()
    }

    /// Change the planner check interval and pull the next check forward
    /// if it is already scheduled further out than one new interval.
    pub fn set_planner_interval(&mut self, s: f64) {
        self.planner_interval = s;
        self.next_planner_check = self.next_planner_check.min(s);
    }

    /// The planner that actually runs each tick: an explicit mode wins;
    /// with the mode [`PlannerMode::Off`] the legacy `reconfigurable`
    /// flag still selects the static Dynamo-style planner, so existing
    /// callers keep their exact trajectories.
    fn effective_planner(&self) -> PlannerMode {
        if self.planner == PlannerMode::Off && self.reconfigurable {
            PlannerMode::Static
        } else {
            self.planner
        }
    }

    /// Fleet-wide load snapshot for the elastic planner. Reads only
    /// dispatched state (worker queues, running sets, in-flight
    /// transfers) — never `pending` — so a live caller that injects
    /// submissions as they become due sees the same signals as the batch
    /// replay (the live ≡ batch trajectory property).
    fn gather_signals(&self) -> FleetSignals {
        let (u, p, d) = self.role_counts();
        let mut s = FleetSignals {
            unified: u,
            prefill: p,
            decode: d,
            ..Default::default()
        };
        let mut ctx_sum = 0u64;
        let mut headroom_sum = 0.0f64;
        for w in &self.workers {
            if w.role == WorkerRole::Unified {
                headroom_sum += w.core.prefill_headroom();
            }
            s.slo_checked += w.core.metrics.slo_checked;
            s.slo_violations += w.core.metrics.slo_violations;
            for r in w.core.waiting.iter().chain(w.core.running.iter()) {
                s.backlog_reqs += 1;
                s.pre_backlog_tokens += r.remaining_prompt();
                if r.prompt_len >= LONG_PROMPT_TOKENS {
                    s.long_backlog_tokens += r.remaining_prompt();
                }
                s.dec_backlog_tokens += r.output_len.saturating_sub(r.generated);
                ctx_sum += r.context_len().max(r.prompt_len);
            }
        }
        for t in &self.transfers {
            s.backlog_reqs += 1;
            s.dec_backlog_tokens += t.request.output_len.saturating_sub(t.request.generated);
            ctx_sum += t.request.context_len();
        }
        s.transfers_in_flight = self.transfers.len();
        s.mean_ctx = if s.backlog_reqs > 0 {
            ctx_sum / s.backlog_reqs
        } else {
            0
        };
        s.unified_headroom = if u > 0 { headroom_sum / u as f64 } else { 1.0 };
        s
    }

    /// One elastic-planner tick: snapshot the fleet, ask the planner for
    /// a role target, and move workers toward it (decode workers drain
    /// their assigned KV transfers before they flip).
    fn plan_elastic(&mut self, now: f64) {
        let Some(mut planner) = self.elastic.take() else {
            return;
        };
        planner.reconfig_s = self.reconfig_s;
        let signals = self.gather_signals();
        if let Some(target) = planner.decide(self.epoch_offset + now, &signals) {
            let flips = self.apply_role_target(now, target);
            if flips > 0 {
                planner.committed(self.epoch_offset + now, flips);
            }
        }
        self.elastic = Some(planner);
    }

    /// Flip workers one at a time from surplus roles to deficit roles
    /// until the fleet matches `target` (unified, prefill, decode) or no
    /// safe victim remains. Returns the number of flips performed.
    fn apply_role_target(&mut self, now: f64, target: (usize, usize, usize)) -> usize {
        let (tu, tp, td) = target;
        let mut flips = 0;
        loop {
            let (u, p, d) = self.role_counts();
            let from = if u > tu {
                Some(WorkerRole::Unified)
            } else if p > tp {
                Some(WorkerRole::Prefill)
            } else if d > td {
                Some(WorkerRole::Decode)
            } else {
                None
            };
            // Fill decode deficits before prefill deficits: if the flip
            // sequence stops early (no safe victim), the fleet must never
            // hold prefill workers without a decode worker to stream
            // their KV transfers to.
            let to = if u < tu {
                Some(WorkerRole::Unified)
            } else if d < td {
                Some(WorkerRole::Decode)
            } else if p < tp {
                Some(WorkerRole::Prefill)
            } else {
                None
            };
            let (Some(from), Some(to)) = (from, to) else {
                break;
            };
            // Never flip the last decode worker while KV transfers are in
            // flight — they would have nowhere to land.
            if from == WorkerRole::Decode && d == 1 && !self.transfers.is_empty() {
                break;
            }
            let Some(v) = self.flip_victim(from, now) else {
                break;
            };
            self.flip_role(v, to, now);
            flips += 1;
        }
        if flips > 0 {
            self.sync_all();
        }
        flips
    }

    /// The lightest-loaded online worker of role `from` that is safe to
    /// flip. Decode workers with KV transfers assigned to them are never
    /// victims: the transfer drains first, the planner retries next tick.
    fn flip_victim(&self, from: WorkerRole, now: f64) -> Option<usize> {
        self.workers
            .iter()
            .enumerate()
            .filter(|(i, w)| {
                w.role == from
                    && w.offline_until <= now
                    && !(from == WorkerRole::Decode
                        && self.transfers.iter().any(|t| t.assigned == Some(*i)))
            })
            .min_by_key(|(i, w)| (w.core.running_len() + w.core.queue_len(), *i))
            .map(|(i, _)| i)
    }

    /// Re-role worker `v`: displace its in-flight work, swap in the
    /// scheduler matching the new role, take `reconfig_s` of downtime,
    /// and re-inject the displaced requests (recomputed from scratch)
    /// into the lightest arrival-accepting worker.
    fn flip_role(&mut self, v: usize, to: WorkerRole, now: f64) {
        let mut drained: Vec<Request> = Vec::new();
        self.workers[v].core.displace_all(&mut drained);
        // Victim selection skips decode workers with assigned transfers,
        // but invalidate any assignment defensively (e.g. a transfer
        // routed between selection and flip).
        for t in &mut self.transfers {
            if t.assigned == Some(v) {
                t.assigned = None;
            }
        }
        self.note_role_change(v, to);
        let wcfg = &self.workers[v].core.cfg;
        let sched: Box<dyn Scheduler> = match to {
            WorkerRole::Prefill => Box::new(PrefillOnlyScheduler::new(
                wcfg.token_budget as u64,
                wcfg.max_batch as usize,
                wcfg.kv_watermark,
            )),
            WorkerRole::Decode => Box::new(RoleScheduler),
            WorkerRole::Unified => {
                // Workers born into a disagg topology carry the disagg
                // policy in their config; a unified role needs a real
                // iteration scheduler.
                let mut ucfg = wcfg.clone();
                if matches!(ucfg.policy, Policy::DisaggPD { .. }) {
                    ucfg.policy = Policy::VllmChunked;
                }
                scheduler_for(&ucfg)
            }
        };
        self.workers[v].core.set_scheduler(sched);
        self.workers[v].offline_until = now + self.reconfig_s;
        self.reconfigs += 1;
        for r in drained {
            let tgt = self.lightest_ingest_worker(now);
            self.workers[tgt].core.inject(r.reset_for_retry());
        }
    }

    /// Record a role change for per-role occupancy accounting, then
    /// apply it. Metrics-only bookkeeping: trajectories are unchanged.
    fn note_role_change(&mut self, v: usize, to: WorkerRole) {
        let t = self.epoch_offset + self.workers[v].core.clock;
        let w = &mut self.workers[v];
        self.role_occupancy_acc[w.role.index()] += (t - w.role_since).max(0.0);
        w.role_since = t;
        w.role = to;
    }

    /// Per-role occupancy seconds (unified, prefill, decode): completed
    /// intervals plus each worker's live interval in its current role.
    /// Absolute-time based, so epoch re-bases do not distort it.
    pub fn role_occupancy(&self) -> [f64; 3] {
        let mut acc = self.role_occupancy_acc;
        for w in &self.workers {
            let t = self.epoch_offset + w.core.clock;
            acc[w.role.index()] += (t - w.role_since).max(0.0);
        }
        acc
    }

    /// The arrival-accepting (unified or prefill) worker with the
    /// shortest queue, preferring online ones. Role-target validity
    /// guarantees at least one exists at every point of a flip sequence.
    fn lightest_ingest_worker(&self, now: f64) -> usize {
        let pick = |require_online: bool| {
            self.workers
                .iter()
                .enumerate()
                .filter(|(_, w)| {
                    w.accepts_arrivals() && (!require_online || w.offline_until <= now)
                })
                .min_by_key(|(i, w)| (w.core.queue_len(), *i))
                .map(|(i, _)| i)
        };
        pick(true)
            .or_else(|| pick(false))
            .expect("topology lost every arrival-accepting worker")
    }
}

/// Live serving across the cluster: [`crate::server::ServerCore`] feeds
/// due submissions through [`inject`](ClusterEngine::inject), advances
/// the min-clock loop via [`step_next`](ClusterEngine::step_next), and
/// streams tokens out of every worker through `pump` — the identical
/// event trajectory the batch [`run`](ClusterEngine::run) replays
/// (property-tested).
impl ServingTopology for ClusterEngine {
    fn label(&self) -> String {
        self.system_name()
    }

    fn clock(&self) -> f64 {
        ClusterEngine::clock(self)
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn epoch_offset(&self) -> f64 {
        self.epoch_offset
    }

    fn max_engine_time(&self) -> f64 {
        self.cfg.max_engine_time
    }

    fn rebase_if_idle(&mut self) -> bool {
        self.rebase_epoch()
    }

    fn rebase_now(&mut self) -> bool {
        if !self.all_done() {
            return false;
        }
        let delta = self.clock();
        if !delta.is_finite() || delta <= 0.0 {
            return false;
        }
        self.shift_all(delta);
        true
    }

    fn set_recorder_mode(&mut self, mode: RecorderMode) {
        self.metrics.set_mode(mode);
        for w in &mut self.workers {
            w.core.metrics.set_mode(mode);
            w.core.trim_finished = mode == RecorderMode::Streaming;
        }
    }

    fn inject(&mut self, req: Request) {
        ClusterEngine::inject(self, req);
    }

    fn step(&mut self, next_arrival: Option<f64>) -> TopologyStep {
        self.step_next(next_arrival)
    }

    fn has_work(&self) -> bool {
        !self.all_done()
    }

    fn queued(&self) -> usize {
        if self.naive_scan {
            return self.pending.len()
                + self
                    .workers
                    .iter()
                    .map(|w| w.core.queue_len())
                    .sum::<usize>();
        }
        self.pending.len() + self.total_queue
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        // Not yet dispatched: no worker ever saw it.
        if let Some(pos) = self.pending.iter().position(|r| r.id == id) {
            self.pending.remove(pos);
            return true;
        }
        // In flight between a prefill and a decode worker: the prefill
        // side already released its KV, the decode side never admitted
        // it, so dropping the transfer is the whole cancellation.
        if let Some(pos) = self.transfers.iter().position(|t| t.request.id == id) {
            self.transfers.remove(pos);
            return true;
        }
        for i in 0..self.workers.len() {
            if self.workers[i].core.cancel_local(id) {
                self.sync_worker(i);
                return true;
            }
        }
        false
    }

    fn max_context(&self) -> Option<u64> {
        // Submissions are routed at arrival time, so the tightest bound
        // of any worker's backend governs every request.
        self.workers
            .iter()
            .filter_map(|w| w.core.backend.max_context())
            .min()
    }

    fn release(&mut self, id: RequestId) {
        for w in &mut self.workers {
            w.core.backend.release(id);
        }
    }

    fn add_dropped(&mut self, n: u64) {
        self.dropped += n;
    }

    fn pump(&mut self, f: &mut dyn FnMut(&[Request], &mut dyn ExecutionBackend, bool)) {
        let stepped = self.stepped_worker;
        let (workers, transfers) = (&mut self.workers, &self.transfers);
        // Tokens only appear on the worker an event just advanced; pump
        // that one instead of rescanning the fleet per event (watermarks
        // make re-pumping idempotent, so the fallback visits everyone).
        match stepped {
            Some(i) => workers[i].core.pump_local(f),
            None => {
                for w in workers.iter_mut() {
                    w.core.pump_local(f);
                }
            }
        }
        // Requests in flight between workers carry their first output
        // token (produced by the prefill forward), but the producing
        // worker already released them — the lookup goes through a
        // stand-in backend, which is only sound when token values are a
        // pure function of (id, index) (`deterministic_tokens`).
        if let Some(w0) = workers.first_mut() {
            if !transfers.is_empty() {
                assert!(
                    w0.core.backend.deterministic_tokens(),
                    "cluster streaming of in-transfer requests requires \
                     position-deterministic tokens; backend `{}` queues \
                     device-resident values",
                    w0.core.backend.name()
                );
            }
            for t in transfers.iter() {
                f(
                    std::slice::from_ref(&t.request),
                    &mut *w0.core.backend,
                    false,
                );
            }
        }
    }

    fn drain_recorder(&mut self) -> Recorder {
        self.fold_workers();
        self.metrics.clone()
    }

    fn load(&self) -> TopologyLoad {
        // The queue aggregate is maintained incrementally; the token/KV
        // sums are O(workers), read once per shard submission.
        TopologyLoad {
            queue_len: ServingTopology::queued(self),
            outstanding_tokens: self
                .workers
                .iter()
                .map(|w| w.core.outstanding_tokens())
                .sum(),
            kv_free_tokens: self.workers.iter().map(|w| w.core.kv_free_tokens()).sum(),
        }
    }

    fn snapshot_recorder(&self) -> Recorder {
        // The non-destructive sibling of `fold_workers`: merge what every
        // worker has recorded so far without retiring any state, with the
        // wall clock as the max worker activity horizon (absolute time,
        // invariant across epoch re-bases).
        let mut rec = self.metrics.clone();
        let mut duration = rec.duration;
        for w in &self.workers {
            rec.merge(&w.core.metrics);
            duration = duration.max(w.core.total_active());
        }
        rec.duration = duration;
        rec.reconfigs = self.reconfigs;
        rec.role_occupancy = self.role_occupancy();
        rec
    }

    fn check_invariants(&self) -> Result<(), String> {
        ClusterEngine::check_invariants(self)
    }

    fn as_cluster(&self) -> Option<&ClusterEngine> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::engine::router::{LeastOutstandingRouter, RoundRobinRouter};
    use crate::workload::synthetic::fixed_workload;

    fn unified_cfg() -> ServingConfig {
        ServingConfig::default_8b().with_policy(Policy::VllmChunked)
    }

    #[test]
    fn single_unified_worker_matches_sim_engine() {
        let w = fixed_workload(20, 2048, 16, 4.0, 1);
        let mut cluster =
            ClusterEngine::replicated(unified_cfg(), 1, 1, Box::new(RoundRobinRouter::new()));
        let rc = cluster.run(w.clone());
        let mut sim = crate::engine::engine_for(unified_cfg(), 1);
        let rs = sim.run(w);
        assert_eq!(rc.completed, rs.completed);
        assert_eq!(rc.iterations, rs.iterations);
        assert!(
            (rc.duration - rs.duration).abs() < 1e-9,
            "cluster {} vs sim {}",
            rc.duration,
            rs.duration
        );
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn arrivals_are_dispatched_per_request_not_sharded() {
        // With a shared stream and a least-outstanding router, both
        // workers must receive work (static index sharding is gone).
        let mut cluster =
            ClusterEngine::replicated(unified_cfg(), 2, 1, Box::new(LeastOutstandingRouter::new()));
        let rep = cluster.run(fixed_workload(30, 4000, 32, 10.0, 2));
        assert_eq!(rep.completed, 30);
        for (i, w) in cluster.workers.iter().enumerate() {
            assert!(
                w.core.metrics.completed > 0,
                "worker {i} never completed a request"
            );
        }
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn dispatch_skips_offline_workers() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 2,
            decode_gpus: 1,
        });
        let mut cluster =
            ClusterEngine::disagg(cfg, 2, 1, 1, Box::new(LeastOutstandingRouter::new()));
        cluster.workers[0].offline_until = 100.0; // reconfiguring
        cluster.pending.push_back(Request::new(0, 0.0, 512, 4));
        cluster.dispatch_arrivals(0.0);
        assert_eq!(cluster.workers[0].core.queue_len(), 0, "offline worker got work");
        assert_eq!(cluster.workers[1].core.queue_len(), 1);
    }

    #[test]
    fn transfer_queue_feeds_decode_workers() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        });
        let mut cluster =
            ClusterEngine::disagg(cfg, 1, 1, 1, Box::new(LeastOutstandingRouter::new()));
        let rep = cluster.run(fixed_workload(10, 4000, 16, 2.0, 3));
        assert_eq!(rep.completed, 10);
        // Decode worker must have executed iterations (fed by transfers).
        let (_, p, d) = cluster.role_counts();
        assert_eq!((p, d), (1, 1));
        assert!(cluster.workers[1].core.metrics.iterations > 0);
        assert!(cluster.transfers.is_empty());
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn prefill_workers_use_the_scheduler_seam() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        });
        let cluster =
            ClusterEngine::disagg(cfg, 1, 1, 1, Box::new(LeastOutstandingRouter::new()));
        assert_eq!(cluster.workers[0].core.policy_name(), "prefill-only");
        assert_eq!(cluster.workers[1].core.policy_name(), "role-worker");
    }

    #[test]
    fn transfers_spread_across_decode_workers() {
        // 1 prefill + 2 decode workers: router-dispatched transfers must
        // reach both decode workers under sustained load.
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 2,
        });
        let mut cluster =
            ClusterEngine::disagg(cfg, 1, 2, 1, Box::new(LeastOutstandingRouter::new()));
        let rep = cluster.run(fixed_workload(24, 2000, 64, 6.0, 7));
        assert_eq!(rep.completed, 24);
        for i in [1usize, 2] {
            assert!(
                cluster.workers[i].core.metrics.completed > 0,
                "decode worker {i} never served a transferred request"
            );
        }
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn elastic_planner_splits_roles_under_long_prompt_flood() {
        let mut cfg = unified_cfg();
        // Tight decode SLO: mixed prefill+decode batches forecast badly,
        // so the goodput model favors isolating the long prompts.
        cfg.tbt_slo = 0.04;
        let mut cluster = ClusterEngine::replicated(
            cfg,
            4,
            1,
            Box::new(crate::engine::router::ConditionalRouter::default()),
        );
        cluster.reconfig_s = 1.0;
        cluster.set_planner(PlannerMode::Elastic);
        cluster.set_planner_interval(5.0);
        let rep = cluster.run(fixed_workload(60, 12_000, 8, 12.0, 4));
        assert_eq!(rep.completed, 60);
        assert!(
            cluster.reconfigs > 0,
            "elastic planner never re-roled a worker under a long-prompt flood"
        );
        let occ = cluster.role_occupancy();
        assert!(
            occ[1] > 0.0 && occ[2] > 0.0,
            "both disagg roles should accrue occupancy: {occ:?}"
        );
        assert_eq!(rep.reconfigs, cluster.reconfigs);
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn planner_off_is_byte_identical_to_legacy() {
        // `set_planner(Off)` must not perturb the event trajectory of a
        // cluster that never had a planner.
        let w = fixed_workload(40, 4000, 32, 8.0, 9);
        let mut base =
            ClusterEngine::replicated(unified_cfg(), 3, 1, Box::new(RoundRobinRouter::new()));
        let rb = base.run(w.clone());
        let mut off =
            ClusterEngine::replicated(unified_cfg(), 3, 1, Box::new(RoundRobinRouter::new()));
        off.set_planner(PlannerMode::Off);
        let ro = off.run(w);
        assert_eq!(rb.completed, ro.completed);
        assert_eq!(rb.iterations, ro.iterations);
        assert_eq!(rb.duration.to_bits(), ro.duration.to_bits());
        assert_eq!(rb.reconfigs, 0);
    }

    #[test]
    fn static_mode_matches_reconfigurable_flag() {
        // `set_planner(Static)` is the explicit spelling of the legacy
        // `reconfigurable = true` flag: identical trajectories.
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 2,
            decode_gpus: 2,
        });
        let w = fixed_workload(200, 12_000, 8, 12.0, 4);
        let mut legacy =
            ClusterEngine::disagg(cfg.clone(), 2, 2, 1, Box::new(LeastOutstandingRouter::new()));
        legacy.reconfigurable = true;
        legacy.planner_interval = 10.0;
        legacy.next_planner_check = 10.0;
        let rl = legacy.run(w.clone());
        let mut explicit =
            ClusterEngine::disagg(cfg, 2, 2, 1, Box::new(LeastOutstandingRouter::new()));
        explicit.set_planner(PlannerMode::Static);
        explicit.planner_interval = 10.0;
        explicit.next_planner_check = 10.0;
        let re = explicit.run(w);
        assert_eq!(rl.completed, re.completed);
        assert_eq!(rl.iterations, re.iterations);
        assert_eq!(rl.duration.to_bits(), re.duration.to_bits());
        assert_eq!(legacy.reconfigs, explicit.reconfigs);
    }

    #[test]
    fn flip_skips_decode_workers_with_assigned_transfers() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 2,
        });
        let mut cluster =
            ClusterEngine::disagg(cfg.clone(), 1, 2, 1, Box::new(LeastOutstandingRouter::new()));
        // KV in flight to decode worker 1 (not ready yet): it must not be
        // flipped out from under the transfer.
        cluster.transfers.push(Transfer {
            request: Request::new(0, 0.0, 512, 8),
            ready_at: 1e9,
            assigned: Some(1),
        });
        let flips = cluster.apply_role_target(0.0, (0, 2, 1));
        assert_eq!(flips, 1);
        assert_eq!(cluster.workers[1].role, WorkerRole::Decode);
        assert_eq!(cluster.workers[2].role, WorkerRole::Prefill);

        // Both decode workers guarded: the planner must do nothing and
        // retry after the transfers drain.
        let mut stuck =
            ClusterEngine::disagg(cfg, 1, 2, 1, Box::new(LeastOutstandingRouter::new()));
        for w in [1usize, 2] {
            stuck.transfers.push(Transfer {
                request: Request::new(w as u64, 0.0, 512, 8),
                ready_at: 1e9,
                assigned: Some(w),
            });
        }
        let flips = stuck.apply_role_target(0.0, (0, 2, 1));
        assert_eq!(flips, 0);
        assert_eq!(stuck.workers[1].role, WorkerRole::Decode);
        assert_eq!(stuck.workers[2].role, WorkerRole::Decode);
    }

    #[test]
    fn role_occupancy_tracks_flips() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        });
        let mut cluster =
            ClusterEngine::disagg(cfg, 1, 1, 1, Box::new(LeastOutstandingRouter::new()));
        // Advance both workers' clocks, then flip the decode worker to
        // prefill: its decode occupancy must equal time spent in role.
        cluster.workers[0].core.clock = 10.0;
        cluster.workers[1].core.clock = 10.0;
        cluster.note_role_change(1, WorkerRole::Prefill);
        let occ = cluster.role_occupancy();
        assert!((occ[2] - 10.0).abs() < 1e-9, "decode occupancy: {occ:?}");
        // Live interval: both workers now prefill from t=10 to t=25.
        cluster.workers[0].core.clock = 25.0;
        cluster.workers[1].core.clock = 25.0;
        let occ = cluster.role_occupancy();
        assert!((occ[1] - 40.0).abs() < 1e-9, "prefill occupancy: {occ:?}");
        assert_eq!(occ[0], 0.0);
    }
}
