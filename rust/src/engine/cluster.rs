//! The multi-worker discrete-event cluster loop.
//!
//! One loop serves every multi-GPU topology: [`ClusterEngine`] owns a set
//! of [`Worker`]s (each an [`EngineCore`] plus a [`WorkerRole`]), a global
//! arrival stream, a pluggable [`Router`], and a prefill→decode KV
//! [`Transfer`] queue. Each step advances whichever worker has the
//! smallest local clock:
//!
//! - arrivals with `arrival ≤ now` are routed to a worker *at arrival
//!   time* (no static sharding — replicas are genuinely
//!   time-interleaved);
//! - `Unified` workers run the shared per-iteration step
//!   ([`EngineCore::step_once`]);
//! - `Prefill` workers pack prompt-only batches and emit KV transfers;
//! - `Decode` workers admit ready transfers and run decode-only batches;
//! - an optional Dynamo-style planner flips worker roles under sustained
//!   imbalance (role switch preempts in-flight work and costs
//!   `reconfig_s` of downtime).
//!
//! Replication and disaggregation are just worker/role configurations of
//! this one loop — see [`super::ReplicatedEngine`] and
//! [`super::DisaggEngine`].

use std::collections::VecDeque;

use crate::config::{GpuSpec, ServingConfig};
use crate::metrics::{Recorder, Report};
use crate::model::AttnShape;
use crate::request::{Phase, Request};
use crate::roofline::BatchShape;
use crate::sched::{scheduler_for, IterationPlan, SchedInput, Scheduler};
use crate::sim::DispatchMode;
use crate::workload::Workload;

use super::core::{CoreStep, EngineCore, MAX_SIM_TIME};
use super::router::{RouteCandidate, Router};

/// Clock nudge when a worker parks with nothing to do, so the min-clock
/// selection always makes progress.
const PARK_EPS: f64 = 1e-3;

/// What a worker does with the requests routed to it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerRole {
    /// Full serving loop (scheduler-driven prefill + decode).
    Unified,
    /// Prompt processing only; finished prompts hand their KV to a decode
    /// worker via the transfer queue.
    Prefill,
    /// Continuous decode batching over transferred KV.
    Decode,
}

/// One GPU group inside the cluster.
pub struct Worker {
    pub core: EngineCore,
    pub role: WorkerRole,
    /// Worker is reconfiguring (role switch) until this time.
    pub offline_until: f64,
}

impl Worker {
    fn accepts_arrivals(&self) -> bool {
        matches!(self.role, WorkerRole::Unified | WorkerRole::Prefill)
    }
}

/// A request whose prefill finished and whose KV is in flight to a decode
/// worker.
struct Transfer {
    request: Request,
    ready_at: f64,
}

/// Placeholder scheduler for role-tagged workers: their iterations are
/// built by the cluster's role steps, never by `EngineCore::step_once`.
struct RoleScheduler;

impl Scheduler for RoleScheduler {
    fn plan(&mut self, _input: &SchedInput<'_>) -> IterationPlan {
        IterationPlan::Idle
    }

    fn name(&self) -> String {
        "role-worker".to_string()
    }
}

/// The event-driven cluster core.
pub struct ClusterEngine {
    pub cfg: ServingConfig,
    pub workers: Vec<Worker>,
    router: Box<dyn Router>,
    /// Not yet arrived, sorted by arrival time.
    pending: VecDeque<Request>,
    transfers: Vec<Transfer>,
    /// System-level metrics, folded from the workers at the end of `run`.
    pub metrics: Recorder,
    /// Finished requests from all workers (moved here at the end of `run`).
    pub finished: Vec<Request>,
    /// Requests dropped (divergence drain + per-worker drops, folded at
    /// the end of `run`).
    pub dropped: u64,
    /// Enable Dynamo-planner-style runtime role reconfiguration.
    pub reconfigurable: bool,
    /// Downtime for a role switch (paper: ~40 s).
    pub reconfig_s: f64,
    /// Planner check interval.
    pub planner_interval: f64,
    next_planner_check: f64,
    pub reconfigs: u64,
    /// Report label for homogeneous (all-unified) clusters.
    name: String,
}

impl ClusterEngine {
    /// N identical unified workers (model replicas) behind `router`.
    pub fn replicated(
        cfg: ServingConfig,
        replicas: u32,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ClusterEngine {
        assert!(replicas >= 1, "need at least one replica");
        let workers: Vec<Worker> = (0..replicas)
            .map(|i| Worker {
                core: EngineCore::new(cfg.clone(), scheduler_for(&cfg), seed + i as u64),
                role: WorkerRole::Unified,
                offline_until: 0.0,
            })
            .collect();
        let name = format!("{}x{}", workers[0].core.policy_name(), replicas);
        ClusterEngine::assemble(cfg, workers, router, name)
    }

    /// PD-disaggregated topology: `prefill_gpus` + `decode_gpus` workers
    /// on identical GPUs.
    pub fn disagg(
        cfg: ServingConfig,
        prefill_gpus: u32,
        decode_gpus: u32,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ClusterEngine {
        let gpu = cfg.gpu.clone();
        ClusterEngine::disagg_hetero(cfg, prefill_gpus, gpu.clone(), decode_gpus, gpu, seed, router)
    }

    /// Heterogeneous topology (Appendix B future work): prefill workers on
    /// `prefill_gpu` parts, decode workers on `decode_gpu` parts — e.g.
    /// compute-optimized prefill + memory-optimized decode.
    #[allow(clippy::too_many_arguments)]
    pub fn disagg_hetero(
        cfg: ServingConfig,
        prefill_gpus: u32,
        prefill_gpu: GpuSpec,
        decode_gpus: u32,
        decode_gpu: GpuSpec,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ClusterEngine {
        assert!(prefill_gpus >= 1 && decode_gpus >= 1);
        let mk = |role: WorkerRole, spec: &GpuSpec, i: u32| {
            // Each worker is a single GPU holding a full model replica.
            let mut wcfg = cfg.clone();
            wcfg.tp = 1;
            wcfg.gpu = spec.clone();
            Worker {
                core: EngineCore::new(wcfg, Box::new(RoleScheduler), seed + i as u64),
                role,
                offline_until: 0.0,
            }
        };
        let mut workers = Vec::new();
        for i in 0..prefill_gpus {
            workers.push(mk(WorkerRole::Prefill, &prefill_gpu, i));
        }
        for i in 0..decode_gpus {
            workers.push(mk(WorkerRole::Decode, &decode_gpu, prefill_gpus + i));
        }
        ClusterEngine::assemble(cfg, workers, router, String::new())
    }

    fn assemble(
        cfg: ServingConfig,
        workers: Vec<Worker>,
        router: Box<dyn Router>,
        name: String,
    ) -> ClusterEngine {
        ClusterEngine {
            cfg,
            workers,
            router,
            pending: VecDeque::new(),
            transfers: Vec::new(),
            metrics: Recorder::new(),
            finished: Vec::new(),
            dropped: 0,
            reconfigurable: false,
            reconfig_s: 40.0,
            planner_interval: 30.0,
            next_planner_check: 30.0,
            reconfigs: 0,
            name,
        }
    }

    /// Swap the routing policy (builder-style, before `run`).
    pub fn set_router(&mut self, router: Box<dyn Router>) {
        self.router = router;
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// (unified, prefill, decode) worker counts.
    pub fn role_counts(&self) -> (usize, usize, usize) {
        let count = |role| self.workers.iter().filter(|w| w.role == role).count();
        (
            count(WorkerRole::Unified),
            count(WorkerRole::Prefill),
            count(WorkerRole::Decode),
        )
    }

    fn system_name(&self) -> String {
        let (_, p, d) = self.role_counts();
        if p + d > 0 {
            format!("Dynamo-{p}P{d}D")
        } else {
            self.name.clone()
        }
    }

    /// Run the whole workload to completion; returns the merged report.
    pub fn run(&mut self, workload: Workload) -> Report {
        self.pending = workload.sorted_by_arrival().requests.into();
        while self.step() {}
        let mut duration = 0.0f64;
        for w in &mut self.workers {
            self.metrics.merge(&w.core.metrics);
            self.dropped += w.core.dropped;
            self.finished.append(&mut w.core.finished);
            duration = duration.max(w.core.last_active);
        }
        self.metrics.duration = duration;
        self.metrics.report(&self.system_name())
    }

    /// Cross-worker invariants, for property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, w) in self.workers.iter().enumerate() {
            w.core
                .check_invariants()
                .map_err(|e| format!("worker {i}: {e}"))?;
        }
        for r in &self.finished {
            if r.generated != r.output_len || r.phase != Phase::Finished {
                return Err(format!("request {} retired unfinished", r.id));
            }
            if r.finished_at.unwrap_or(f64::NEG_INFINITY) < r.arrival {
                return Err(format!("request {} finished before arrival", r.id));
            }
            if r.first_token_at.unwrap_or(f64::NEG_INFINITY) < r.arrival {
                return Err(format!("request {} produced a token before arrival", r.id));
            }
        }
        Ok(())
    }

    fn all_done(&self) -> bool {
        self.pending.is_empty()
            && self.transfers.is_empty()
            && self.workers.iter().all(|w| !w.core.has_local_work())
    }

    fn min_clock_worker(&self) -> usize {
        self.workers
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.core.clock.partial_cmp(&b.1.core.clock).unwrap())
            .map(|(i, _)| i)
            .expect("cluster has no workers")
    }

    fn max_clock(&self) -> f64 {
        self.workers
            .iter()
            .map(|w| w.core.clock)
            .fold(0.0f64, f64::max)
    }

    /// Advance an idle worker's clock to its next event, or park it just
    /// past the rest of the fleet so min-clock selection keeps moving.
    fn idle_advance(&mut self, idx: usize, next_event: Option<f64>) {
        match next_event {
            Some(t) => {
                let core = &mut self.workers[idx].core;
                core.clock = core.clock.max(t);
            }
            None => {
                let max_all = self.max_clock();
                self.workers[idx].core.clock = max_all + PARK_EPS;
            }
        }
    }

    /// Advance the cluster by one worker-event. Returns false when done.
    fn step(&mut self) -> bool {
        if self.all_done() {
            return false;
        }
        let idx = self.min_clock_worker();
        let now = self.workers[idx].core.clock;
        if now > MAX_SIM_TIME {
            // Diverged: drain bookkeeping everywhere and stop.
            self.dropped += (self.pending.len() + self.transfers.len()) as u64;
            self.pending.clear();
            self.transfers.clear();
            for w in &mut self.workers {
                w.core.drain_diverged();
            }
            return false;
        }

        self.dispatch_arrivals(now);

        if self.reconfigurable && now >= self.next_planner_check {
            self.plan_reconfig(now);
            self.next_planner_check = now + self.planner_interval;
        }

        if self.workers[idx].offline_until > now {
            self.workers[idx].core.clock = self.workers[idx].offline_until;
            return true;
        }

        match self.workers[idx].role {
            WorkerRole::Unified => self.step_unified(idx),
            WorkerRole::Prefill => self.step_prefill(idx),
            WorkerRole::Decode => self.step_decode(idx),
        }
        true
    }

    /// Snapshot the workers a router may pick from. Offline workers are
    /// excluded unless *every* arrival-taking worker is offline (then the
    /// request must queue somewhere).
    fn route_candidates(&self, now: f64) -> Vec<RouteCandidate> {
        let snapshot = |(i, w): (usize, &Worker)| RouteCandidate {
            worker: i,
            queue_len: w.core.queue_len(),
            outstanding_tokens: w.core.outstanding_tokens(),
            kv_free_tokens: w.core.kv_free_tokens(),
        };
        let online: Vec<RouteCandidate> = self
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.accepts_arrivals() && w.offline_until <= now)
            .map(snapshot)
            .collect();
        if !online.is_empty() {
            return online;
        }
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.accepts_arrivals())
            .map(snapshot)
            .collect()
    }

    /// Route every arrival with `arrival ≤ now` to a worker, at arrival
    /// time, through the pluggable router.
    fn dispatch_arrivals(&mut self, now: f64) {
        while self.pending.front().is_some_and(|r| r.arrival <= now) {
            let req = self.pending.pop_front().unwrap();
            let candidates = self.route_candidates(now);
            assert!(
                !candidates.is_empty(),
                "no worker accepts arrivals (topology without prefill/unified workers)"
            );
            let choice = self.router.route(&req, &candidates);
            assert!(
                candidates.iter().any(|c| c.worker == choice),
                "router `{}` dispatched to ineligible worker {choice}",
                self.router.name()
            );
            self.workers[choice].core.inject(req);
        }
    }

    /// One shared-core iteration on a unified worker; on idle, advance
    /// its clock to the next event (arrival or park behind the fleet).
    fn step_unified(&mut self, idx: usize) {
        let allow_drop = self.pending.is_empty();
        let outcome = self.workers[idx].core.step_once(allow_drop);
        if outcome == CoreStep::Idle {
            // Next event: the next arrival, which dispatch guarantees is
            // strictly in the future (everything ≤ now was delivered).
            let next_arrival = self.pending.front().map(|r| r.arrival);
            if next_arrival.is_none() && self.workers[idx].core.has_local_work() {
                // Scheduler idled with admitted work (should not happen);
                // nudge so the min-clock loop cannot livelock.
                self.workers[idx].core.clock += PARK_EPS;
            } else {
                self.idle_advance(idx, next_arrival);
            }
        }
    }

    /// One prefill iteration on worker `idx`: pack whole prompts up to the
    /// token budget (chunking the head if it alone exceeds the budget).
    fn step_prefill(&mut self, idx: usize) {
        let now = self.workers[idx].core.clock;
        if self.workers[idx].core.queue_len() == 0 {
            // Idle: jump to the next arrival, or park behind the fleet so
            // the rest of the cluster drives the system.
            let next_arrival = self.pending.front().map(|r| r.arrival);
            self.idle_advance(idx, next_arrival);
            return;
        }
        // Build a prefill-only batch from this worker's queue.
        let budget = self.cfg.token_budget as u64;
        let mut tokens = 0u64;
        let mut batch: Vec<Request> = Vec::new();
        {
            let core = &mut self.workers[idx].core;
            while let Some(r) = core.waiting.front() {
                if batch.is_empty() {
                    let r = core.waiting.pop_front().unwrap();
                    tokens += r.prompt_len.min(budget);
                    batch.push(r);
                    if tokens >= budget {
                        break;
                    }
                } else if tokens + r.prompt_len <= budget {
                    let r = core.waiting.pop_front().unwrap();
                    tokens += r.prompt_len;
                    batch.push(r);
                } else {
                    break;
                }
            }
        }
        let shapes: Vec<AttnShape> = batch
            .iter()
            .map(|r| AttnShape {
                q: r.prompt_len.min(budget),
                c: 0,
            })
            .collect();
        let bshape = BatchShape::from_shapes(shapes);
        let sms = self.workers[idx].core.cfg.gpu.num_sms;
        let res = self.workers[idx]
            .core
            .executor
            .run(&bshape, sms, DispatchMode::Eager, None);
        // A prompt larger than the budget runs over multiple chunked
        // iterations; model that as ceil(prompt/budget) sequential spans.
        let mut extra = 0.0;
        for r in &batch {
            if r.prompt_len > budget {
                let n_extra = r.prompt_len.div_ceil(budget) - 1;
                let shape = BatchShape::from_shapes(vec![AttnShape {
                    q: budget,
                    c: budget,
                }]);
                let per = self.workers[idx]
                    .core
                    .executor
                    .run(&shape, sms, DispatchMode::Eager, None);
                extra += n_extra as f64 * per.total();
            }
        }
        let dur = res.total() + extra;
        let t_end = now + dur;
        {
            let core = &mut self.workers[idx].core;
            core.clock = t_end;
            core.last_active = t_end;
            core.metrics.busy_time += res.gpu_time + extra;
            core.metrics
                .record_util(res.gpu_time + extra, res.sm_util, res.hbm_util);
            core.metrics.iterations += 1;
        }

        // Completed prompts: first token produced here, then KV transfer.
        for mut r in batch {
            // The prefill worker holds no paged KV for this request once
            // the prompt leaves for a decode worker.
            let _ = self.workers[idx].core.kv.release(r.id);
            r.advance_prefill(r.remaining_prompt());
            r.advance_decode(t_end); // first output token from prefill logits
            if r.phase == Phase::Finished {
                let core = &mut self.workers[idx].core;
                core.metrics.record_finished(&r);
                core.finished.push(r);
                continue;
            }
            let ready = t_end
                + self.workers[idx]
                    .core
                    .executor
                    .kv_transfer_time(r.context_len());
            self.transfers.push(Transfer {
                request: r,
                ready_at: ready,
            });
        }
    }

    /// One decode iteration on worker `idx`: admit ready transfers (when
    /// this worker is the least-loaded decode worker), then run one
    /// decode-only step over the whole running batch.
    fn step_decode(&mut self, idx: usize) {
        let now = self.workers[idx].core.clock;
        let my_load = self.workers[idx].core.running_len();
        let am_least = self
            .workers
            .iter()
            .enumerate()
            .filter(|(i, w)| w.role == WorkerRole::Decode && *i != idx)
            .all(|(_, w)| w.core.running_len() >= my_load);
        if am_least {
            let mut i = 0;
            while i < self.transfers.len() {
                if self.transfers[i].ready_at <= now {
                    let t = self.transfers.swap_remove(i);
                    let mut r = t.request;
                    let id = r.id;
                    let core = &mut self.workers[idx].core;
                    core.kv.register(id);
                    if core.kv.append(id, r.context_len()).is_err() {
                        // Decode KV full: requeue the transfer for later.
                        let _ = core.kv.release(id);
                        self.transfers.push(Transfer {
                            request: r,
                            ready_at: now + 0.05,
                        });
                        break;
                    }
                    r.phase = Phase::Decode;
                    core.running.push(r);
                } else {
                    i += 1;
                }
            }
        }

        if self.workers[idx].core.running_len() == 0 {
            // Idle: jump to the next transfer-ready time or park.
            let next = self
                .transfers
                .iter()
                .map(|t| t.ready_at)
                .fold(f64::INFINITY, f64::min);
            self.idle_advance(idx, next.is_finite().then_some(next));
            return;
        }

        let sms = self.workers[idx].core.cfg.gpu.num_sms;
        let shapes: Vec<AttnShape> = self.workers[idx]
            .core
            .running
            .iter()
            .map(|r| AttnShape {
                q: 1,
                c: r.context_len(),
            })
            .collect();
        let bshape = BatchShape::from_shapes(shapes);
        let res = self.workers[idx]
            .core
            .executor
            .run(&bshape, sms, DispatchMode::Graph, None);
        let dur = res.total();
        let t_end = now + dur;
        let core = &mut self.workers[idx].core;
        core.clock = t_end;
        core.last_active = t_end;
        core.metrics.busy_time += res.gpu_time;
        core.metrics
            .record_util(res.gpu_time, res.sm_util, res.hbm_util);
        core.metrics.iterations += 1;

        for r in core.running.iter_mut() {
            let _ = core.kv.append(r.id, 1);
            r.advance_decode(t_end);
        }
        core.retire_finished();
    }

    /// Dynamo-planner emulation: flip one worker's role when the phases
    /// are persistently imbalanced. Switching preempts in-flight work
    /// (recompute: back to a prefill worker) and takes `reconfig_s`.
    fn plan_reconfig(&mut self, now: f64) {
        let (_, p_count, d_count) = self.role_counts();
        let queue_pressure: usize = self
            .workers
            .iter()
            .filter(|w| w.role == WorkerRole::Prefill)
            .map(|w| w.core.queue_len())
            .sum();
        let decode_load: usize = self
            .workers
            .iter()
            .filter(|w| w.role == WorkerRole::Decode)
            .map(|w| w.core.running_len())
            .sum();

        // Prefill backlogged, decode workers light: D -> P.
        if queue_pressure > 8 * p_count && d_count > 1 && decode_load < 4 * d_count {
            let victim = self
                .workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.role == WorkerRole::Decode)
                .min_by_key(|(_, w)| w.core.running_len())
                .map(|(i, _)| i);
            if let Some(v) = victim {
                let drained: Vec<Request> = self.workers[v].core.running.drain(..).collect();
                for r in &drained {
                    let _ = self.workers[v].core.kv.release(r.id);
                }
                self.workers[v].role = WorkerRole::Prefill;
                self.workers[v].offline_until = now + self.reconfig_s;
                self.reconfigs += 1;
                for r in drained {
                    // Preempted decodes restart from scratch.
                    let fresh = Request::new(r.id, r.arrival, r.prompt_len, r.output_len);
                    let tgt = self.lightest_prefill_worker(now);
                    self.workers[tgt].core.inject_front(fresh);
                }
            }
        // Decode overloaded, prefill side keeping up: P -> D.
        } else if queue_pressure < 4 * p_count && decode_load > 8 * d_count.max(1) && p_count > 1 {
            let victim = self
                .workers
                .iter()
                .position(|w| w.role == WorkerRole::Prefill);
            if let Some(v) = victim {
                let moved: Vec<Request> = self.workers[v].core.waiting.drain(..).collect();
                for r in &moved {
                    let _ = self.workers[v].core.kv.release(r.id);
                }
                self.workers[v].role = WorkerRole::Decode;
                self.workers[v].offline_until = now + self.reconfig_s;
                self.reconfigs += 1;
                for r in moved {
                    // Re-route the displaced queue to the surviving
                    // prefill workers.
                    let tgt = self.lightest_prefill_worker(now);
                    self.workers[tgt].core.inject(r);
                }
            }
        }
    }

    /// The prefill worker with the shortest queue, preferring online ones.
    fn lightest_prefill_worker(&self, now: f64) -> usize {
        let pick = |require_online: bool| {
            self.workers
                .iter()
                .enumerate()
                .filter(|(_, w)| {
                    w.role == WorkerRole::Prefill && (!require_online || w.offline_until <= now)
                })
                .min_by_key(|(i, w)| (w.core.queue_len(), *i))
                .map(|(i, _)| i)
        };
        pick(true)
            .or_else(|| pick(false))
            .expect("topology lost its last prefill worker")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::engine::router::{LeastOutstandingRouter, RoundRobinRouter};
    use crate::workload::synthetic::fixed_workload;

    fn unified_cfg() -> ServingConfig {
        ServingConfig::default_8b().with_policy(Policy::VllmChunked)
    }

    #[test]
    fn single_unified_worker_matches_sim_engine() {
        let w = fixed_workload(20, 2048, 16, 4.0, 1);
        let mut cluster =
            ClusterEngine::replicated(unified_cfg(), 1, 1, Box::new(RoundRobinRouter::new()));
        let rc = cluster.run(w.clone());
        let mut sim = crate::engine::engine_for(unified_cfg(), 1);
        let rs = sim.run(w);
        assert_eq!(rc.completed, rs.completed);
        assert_eq!(rc.iterations, rs.iterations);
        assert!(
            (rc.duration - rs.duration).abs() < 1e-9,
            "cluster {} vs sim {}",
            rc.duration,
            rs.duration
        );
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn arrivals_are_dispatched_per_request_not_sharded() {
        // With a shared stream and a least-outstanding router, both
        // workers must receive work (static index sharding is gone).
        let mut cluster =
            ClusterEngine::replicated(unified_cfg(), 2, 1, Box::new(LeastOutstandingRouter::new()));
        let rep = cluster.run(fixed_workload(30, 4000, 32, 10.0, 2));
        assert_eq!(rep.completed, 30);
        for (i, w) in cluster.workers.iter().enumerate() {
            assert!(
                w.core.metrics.completed > 0,
                "worker {i} never completed a request"
            );
        }
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn dispatch_skips_offline_workers() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 2,
            decode_gpus: 1,
        });
        let mut cluster =
            ClusterEngine::disagg(cfg, 2, 1, 1, Box::new(LeastOutstandingRouter::new()));
        cluster.workers[0].offline_until = 100.0; // reconfiguring
        cluster.pending.push_back(Request::new(0, 0.0, 512, 4));
        cluster.dispatch_arrivals(0.0);
        assert_eq!(cluster.workers[0].core.queue_len(), 0, "offline worker got work");
        assert_eq!(cluster.workers[1].core.queue_len(), 1);
    }

    #[test]
    fn transfer_queue_feeds_decode_workers() {
        let cfg = ServingConfig::default_8b().with_policy(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        });
        let mut cluster =
            ClusterEngine::disagg(cfg, 1, 1, 1, Box::new(LeastOutstandingRouter::new()));
        let rep = cluster.run(fixed_workload(10, 4000, 16, 2.0, 3));
        assert_eq!(rep.completed, 10);
        // Decode worker must have executed iterations (fed by transfers).
        let (_, p, d) = cluster.role_counts();
        assert_eq!((p, d), (1, 1));
        assert!(cluster.workers[1].core.metrics.iterations > 0);
        assert!(cluster.transfers.is_empty());
        cluster.check_invariants().unwrap();
    }
}
