//! Pluggable request routing for multi-worker topologies.
//!
//! The cluster engine digests its worker state into [`RouteCandidate`]s
//! (only *eligible* workers: online, and in a role that accepts new
//! arrivals) and asks a [`Router`] to pick one per arriving request. This
//! is the seam where replicated serving stops being static sharding:
//! requests are dispatched at arrival time against live load signals.
//!
//! The same seam serves three dispatch points:
//!
//! - **batch arrivals** — `ClusterEngine::run` replays a workload's
//!   arrival stream through it;
//! - **live submissions** — a cluster-backed
//!   [`ServerCore`](crate::server::ServerCore) injects each accepted
//!   submission when its arrival comes due, so the candidates' queue
//!   depths, outstanding tokens and free-KV counts reflect the *live*
//!   in-flight state at submit time (including everything earlier
//!   submissions put on each worker);
//! - **prefill→decode transfers** — ready KV handoffs are routed to
//!   decode workers at transfer-ready time, with not-yet-admitted
//!   in-flight transfer assignments folded into the load signals so a
//!   burst spreads instead of piling onto one worker.

use crate::request::Request;

/// Prompt length (tokens) above which a request counts as a "long
/// prefill" for conditional disaggregation — the [`ConditionalRouter`]'s
/// base threshold and the elastic planner's long-backlog cutoff share it
/// so the two timescales classify requests identically.
pub const LONG_PROMPT_TOKENS: u64 = 2048;

/// Load snapshot of one eligible worker at dispatch time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteCandidate {
    /// Index into the cluster's worker list.
    pub worker: usize,
    /// Requests queued but not yet admitted on that worker.
    pub queue_len: usize,
    /// Remaining prompt + output tokens across the worker's queues.
    pub outstanding_tokens: u64,
    /// Free KV-cache tokens on that worker.
    pub kv_free_tokens: u64,
    /// Prompt tokens resident in the worker's prefix cache (held +
    /// cached blocks). 0 when prefix caching is disabled.
    pub prefix_resident_tokens: u64,
    /// Longest cached prefix the worker holds for *this* request's
    /// prompt, in tokens. Filled per decision at arrival dispatch when
    /// prefix caching is enabled; 0 otherwise (including transfers).
    pub prefix_overlap_tokens: u64,
    /// Whether this worker runs the prefill role (disaggregated prompt
    /// processing; its output KV is handed to a decode worker). The
    /// conditional router partitions the candidate board on this flag.
    pub prefill_only: bool,
}

/// Picks a destination worker for each arriving request.
///
/// Implementations must return the `worker` field of one of `candidates`
/// — the cluster validates this and panics otherwise, which is what
/// guarantees a router can never dispatch to an offline worker or to a
/// role that does not take arrivals.
pub trait Router {
    fn name(&self) -> &'static str;
    /// `candidates` is non-empty and ordered by worker index.
    fn route(&mut self, req: &Request, candidates: &[RouteCandidate]) -> usize;
}

/// Static round-robin over the eligible workers, in arrival order — the
/// classic replica front-end.
#[derive(Debug, Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl RoundRobinRouter {
    pub fn new() -> RoundRobinRouter {
        RoundRobinRouter { next: 0 }
    }
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _req: &Request, candidates: &[RouteCandidate]) -> usize {
        let c = &candidates[self.next % candidates.len()];
        self.next = self.next.wrapping_add(1);
        c.worker
    }
}

/// Join the worker with the fewest outstanding (unprocessed prompt +
/// output) tokens — the "least work left" policy.
#[derive(Debug, Default)]
pub struct LeastOutstandingRouter;

impl LeastOutstandingRouter {
    pub fn new() -> LeastOutstandingRouter {
        LeastOutstandingRouter
    }
}

impl Router for LeastOutstandingRouter {
    fn name(&self) -> &'static str {
        "least-outstanding"
    }

    fn route(&mut self, _req: &Request, candidates: &[RouteCandidate]) -> usize {
        candidates
            .iter()
            .min_by_key(|c| (c.outstanding_tokens, c.queue_len, c.worker))
            .expect("route called with no candidates")
            .worker
    }
}

/// Join the worker with the most free KV-cache tokens; ties break toward
/// less outstanding work. Useful when prompts are long enough that KV
/// admission, not compute, is the scarce resource.
#[derive(Debug, Default)]
pub struct KvPressureRouter;

impl KvPressureRouter {
    pub fn new() -> KvPressureRouter {
        KvPressureRouter
    }
}

impl Router for KvPressureRouter {
    fn name(&self) -> &'static str {
        "kv-pressure"
    }

    fn route(&mut self, _req: &Request, candidates: &[RouteCandidate]) -> usize {
        candidates
            .iter()
            .max_by_key(|c| (c.kv_free_tokens, std::cmp::Reverse(c.outstanding_tokens)))
            .expect("route called with no candidates")
            .worker
    }
}

/// Join the worker already holding the longest cached prefix of this
/// request's prompt (Dynamo-KV-Router-style cache-aware dispatch):
/// maximal overlap first, ties toward free KV, then least outstanding
/// work — so with a cold cache it degrades to `kv-pressure` behavior.
#[derive(Debug, Default)]
pub struct KvOverlapRouter;

impl KvOverlapRouter {
    pub fn new() -> KvOverlapRouter {
        KvOverlapRouter
    }
}

impl Router for KvOverlapRouter {
    fn name(&self) -> &'static str {
        "kv-overlap"
    }

    fn route(&mut self, _req: &Request, candidates: &[RouteCandidate]) -> usize {
        candidates
            .iter()
            .max_by_key(|c| {
                (
                    c.prefix_overlap_tokens,
                    c.kv_free_tokens,
                    std::cmp::Reverse(c.outstanding_tokens),
                )
            })
            .expect("route called with no candidates")
            .worker
    }
}

/// Conditional disaggregation at the request level (the paper's per-
/// request bet, Dynamo-style): long prefills go to prefill-role workers
/// where they cannot stall anyone's decode; short ones stay on
/// aggregated/duet workers and skip the KV-transfer hop entirely.
///
/// The length threshold is *load-adaptive*: it scales with the ratio of
/// prefill-side to aggregated-side load (queue depth plus outstanding
/// tokens), so a backed-up prefill tier sheds marginal requests to the
/// aggregated workers and vice versa. Within the chosen side the pick is
/// least-outstanding. On a homogeneous board (no prefill workers — e.g. a
/// replicated fleet before the elastic planner splits roles — or a
/// decode-transfer board with no aggregated workers) it degrades to plain
/// least-outstanding, so the router is safe as a fleet-wide default.
#[derive(Debug)]
pub struct ConditionalRouter {
    /// Prompt-length threshold (tokens) at neutral load.
    pub base_threshold: u64,
}

impl Default for ConditionalRouter {
    fn default() -> ConditionalRouter {
        ConditionalRouter::new()
    }
}

impl ConditionalRouter {
    pub fn new() -> ConditionalRouter {
        ConditionalRouter {
            base_threshold: LONG_PROMPT_TOKENS,
        }
    }
}

/// Mean load of one side of the board: queue depth plus outstanding
/// tokens normalized to request-scale units.
fn side_load<'a>(side: impl Iterator<Item = &'a RouteCandidate>) -> Option<f64> {
    let (mut n, mut load) = (0u64, 0.0f64);
    for c in side {
        n += 1;
        load += c.queue_len as f64 + c.outstanding_tokens as f64 / 4096.0;
    }
    if n == 0 {
        None
    } else {
        Some(load / n as f64)
    }
}

fn least_outstanding<'a>(
    side: impl Iterator<Item = &'a RouteCandidate>,
) -> Option<usize> {
    side.min_by_key(|c| (c.outstanding_tokens, c.queue_len, c.worker))
        .map(|c| c.worker)
}

impl Router for ConditionalRouter {
    fn name(&self) -> &'static str {
        "conditional"
    }

    fn route(&mut self, req: &Request, candidates: &[RouteCandidate]) -> usize {
        let pre_load = side_load(candidates.iter().filter(|c| c.prefill_only));
        let agg_load = side_load(candidates.iter().filter(|c| !c.prefill_only));
        let (Some(pre), Some(agg)) = (pre_load, agg_load) else {
            // Homogeneous board: nothing to condition on.
            return least_outstanding(candidates.iter())
                .expect("route called with no candidates");
        };
        // Busier prefill tier → higher threshold (fewer requests classify
        // as long); busier aggregated tier → lower. Clamped to 4x either
        // way so the policy stays recognizable under extreme skew.
        let base = self.base_threshold as f64;
        let threshold =
            (base * (1.0 + pre) / (1.0 + agg)).clamp(base / 4.0, base * 4.0);
        let long = req.prompt_len as f64 >= threshold;
        least_outstanding(candidates.iter().filter(|c| c.prefill_only == long))
            .expect("side emptied between load scan and pick")
    }
}

/// Router factory by name (CLI / bench surface).
pub fn router_by_name(name: &str) -> Option<Box<dyn Router + Send>> {
    match name {
        "round-robin" | "rr" => Some(Box::new(RoundRobinRouter::new())),
        "least-outstanding" | "least-loaded" | "ll" => {
            Some(Box::new(LeastOutstandingRouter::new()))
        }
        "kv-pressure" | "kv" => Some(Box::new(KvPressureRouter::new())),
        "kv-overlap" | "overlap" => Some(Box::new(KvOverlapRouter::new())),
        "conditional" | "cond" => Some(Box::new(ConditionalRouter::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(worker: usize, outstanding: u64, kv_free: u64) -> RouteCandidate {
        RouteCandidate {
            worker,
            queue_len: 0,
            outstanding_tokens: outstanding,
            kv_free_tokens: kv_free,
            prefix_resident_tokens: 0,
            prefix_overlap_tokens: 0,
            prefill_only: false,
        }
    }

    fn pre_cand(worker: usize, outstanding: u64) -> RouteCandidate {
        let mut c = cand(worker, outstanding, 0);
        c.prefill_only = true;
        c
    }

    fn req() -> Request {
        Request::new(0, 0.0, 100, 10)
    }

    #[test]
    fn round_robin_cycles_eligible_workers() {
        let mut r = RoundRobinRouter::new();
        let cs = vec![cand(0, 0, 0), cand(2, 0, 0), cand(5, 0, 0)];
        let picks: Vec<usize> = (0..6).map(|_| r.route(&req(), &cs)).collect();
        assert_eq!(picks, vec![0, 2, 5, 0, 2, 5]);
    }

    #[test]
    fn round_robin_survives_candidate_set_shrinking() {
        let mut r = RoundRobinRouter::new();
        let full = vec![cand(0, 0, 0), cand(1, 0, 0), cand(2, 0, 0)];
        for _ in 0..5 {
            r.route(&req(), &full);
        }
        // Worker 1 went offline: only 0 and 2 remain eligible.
        let reduced = vec![cand(0, 0, 0), cand(2, 0, 0)];
        let pick = r.route(&req(), &reduced);
        assert!(pick == 0 || pick == 2, "must pick an eligible worker");
    }

    #[test]
    fn least_outstanding_picks_lightest() {
        let mut r = LeastOutstandingRouter::new();
        let cs = vec![cand(0, 500, 0), cand(1, 20, 0), cand(2, 300, 0)];
        assert_eq!(r.route(&req(), &cs), 1);
    }

    #[test]
    fn kv_pressure_picks_most_free() {
        let mut r = KvPressureRouter::new();
        let cs = vec![cand(0, 0, 1000), cand(1, 0, 9000), cand(2, 0, 500)];
        assert_eq!(r.route(&req(), &cs), 1);
        // Tie on KV free → less outstanding work wins.
        let tie = vec![cand(0, 70, 9000), cand(1, 30, 9000)];
        assert_eq!(r.route(&req(), &tie), 1);
    }

    #[test]
    fn kv_overlap_prefers_cached_prefix_then_free_kv() {
        let mut r = KvOverlapRouter::new();
        let mut a = cand(0, 10, 9000);
        let mut b = cand(1, 500, 100);
        b.prefix_overlap_tokens = 2048;
        // Overlap dominates every load signal.
        assert_eq!(r.route(&req(), &[a, b]), 1);
        // No overlap anywhere → most free KV (kv-pressure degradation).
        b.prefix_overlap_tokens = 0;
        assert_eq!(r.route(&req(), &[a, b]), 0);
        // Overlap tie → free KV breaks it.
        a.prefix_overlap_tokens = 1024;
        b.prefix_overlap_tokens = 1024;
        assert_eq!(r.route(&req(), &[a, b]), 0);
        // Full tie on overlap + KV → least outstanding wins.
        let c = vec![cand(0, 70, 9000), cand(1, 30, 9000)];
        assert_eq!(r.route(&req(), &c), 1);
    }

    #[test]
    fn factory_resolves_aliases() {
        for (name, expect) in [
            ("round-robin", "round-robin"),
            ("rr", "round-robin"),
            ("least-loaded", "least-outstanding"),
            ("kv", "kv-pressure"),
            ("kv-overlap", "kv-overlap"),
            ("overlap", "kv-overlap"),
            ("conditional", "conditional"),
            ("cond", "conditional"),
        ] {
            assert_eq!(router_by_name(name).unwrap().name(), expect);
        }
        assert!(router_by_name("nope").is_none());
    }

    fn sized_req(prompt: u64) -> Request {
        Request::new(0, 0.0, prompt, 10)
    }

    #[test]
    fn conditional_splits_by_prompt_length() {
        let mut r = ConditionalRouter::new();
        let cs = vec![cand(0, 100, 0), pre_cand(1, 100)];
        // Short prompt stays on the aggregated worker.
        assert_eq!(r.route(&sized_req(256), &cs), 0);
        // Long prompt goes to the prefill worker.
        assert_eq!(r.route(&sized_req(8192), &cs), 1);
        // Exactly at the neutral threshold counts as long.
        assert_eq!(r.route(&sized_req(LONG_PROMPT_TOKENS), &cs), 1);
    }

    #[test]
    fn conditional_threshold_adapts_to_load() {
        let mut r = ConditionalRouter::new();
        // Prefill tier drowning, aggregated idle: a nominally-long prompt
        // (just above base) is shed to the aggregated side.
        let skewed = vec![cand(0, 0, 0), pre_cand(1, 400_000)];
        assert_eq!(r.route(&sized_req(3000), &skewed), 0);
        // Reverse skew: a nominally-short prompt is pushed to prefill.
        let reverse = vec![cand(0, 400_000, 0), pre_cand(1, 0)];
        assert_eq!(r.route(&sized_req(1024), &reverse), 1);
        // But the clamp keeps a tiny prompt on the aggregated side even
        // under extreme skew (threshold floors at base/4 = 512).
        assert_eq!(r.route(&sized_req(100), &reverse), 0);
    }

    #[test]
    fn conditional_degrades_on_homogeneous_board() {
        let mut r = ConditionalRouter::new();
        // All-aggregated (replicated fleet): least-outstanding.
        let agg = vec![cand(0, 500, 0), cand(1, 20, 0), cand(2, 300, 0)];
        assert_eq!(r.route(&sized_req(8192), &agg), 1);
        // All-prefill (pure-disagg arrival board): same.
        let pre = vec![pre_cand(0, 500), pre_cand(1, 20)];
        assert_eq!(r.route(&sized_req(16), &pre), 1);
        // Within-side pick is least-outstanding too.
        let mixed = vec![cand(0, 500, 0), cand(1, 20, 0), pre_cand(2, 900), pre_cand(3, 30)];
        assert_eq!(r.route(&sized_req(16), &mixed), 1);
        assert_eq!(r.route(&sized_req(8192), &mixed), 3);
    }
}
