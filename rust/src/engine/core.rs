//! The shared per-iteration serving step.
//!
//! [`EngineCore`] owns everything one worker needs to execute one
//! continuous-batching iteration: scheduler, execution backend, paged KV
//! manager, local virtual clock, waiting/running queues, and a metrics
//! recorder. It deliberately knows nothing about *where requests come
//! from* — arrival streams, routing, replication, and disaggregation are
//! topology concerns layered on top ([`super::SimEngine`] for one worker,
//! [`super::ClusterEngine`] for many) — nor about *how* iterations
//! execute: that is the [`ExecutionBackend`] seam (simulated roofline
//! executor or the real PJRT runtime).

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::config::ServingConfig;
use crate::kvcache::{block_keys, BlockKey, KvManager};
use crate::metrics::Recorder;
use crate::model::AttnShape;
use crate::request::{Phase, Request, RequestId};
use crate::roofline::BatchShape;
use crate::sched::{IterationPlan, PrefillChunk, SchedInput, Scheduler};
use crate::sim::DispatchMode;

use super::backend::{DecodeSlot, ExecutionBackend, IterationBatch, PrefillSlice, SimBackend};
use super::{IterEvent, IterKind};

/// Default cap on *epoch-local* simulated time — a run whose local clock
/// exceeds this has diverged (arrival rate above capacity with an
/// unbounded queue). Shared by every engine topology; the effective
/// per-instance value is [`crate::config::ServingConfig::max_engine_time`]
/// and the drain-on-divergence bookkeeping lives in
/// [`EngineCore::drain_diverged`]. On the serving path the guard
/// *re-arms*: when a topology goes fully idle past
/// [`REBASE_FRACTION`] of its horizon, the local clock re-bases to a new
/// epoch ([`EngineCore::rebase_epoch`]) and cross-epoch time accumulates
/// in `epoch_offset`, so a long-lived instance never hits a hard
/// end-of-life cliff.
pub const MAX_SIM_TIME: f64 = crate::config::DEFAULT_MAX_ENGINE_TIME;

/// Fraction of the divergence horizon an idle epoch must have consumed
/// before the clock re-bases. Below it, idle topologies keep their clock
/// (so paper-scale live runs take *byte-identical* event trajectories to
/// batch replay — the live ≡ batch property tests never observe a
/// re-base); above it, re-basing keeps weeks-uptime serving honest.
pub const REBASE_FRACTION: f64 = 0.5;

/// What one call to [`EngineCore::step_once`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreStep {
    /// An iteration executed; the local clock advanced.
    Executed,
    /// Nothing schedulable; the caller decides how to advance the clock.
    Idle,
    /// The head waiting request can never fit in KV and was dropped.
    DroppedHead(RequestId),
}

/// Running-set size above which per-iteration id lookups go through a
/// position map instead of linear scans. Below it the map build costs
/// more than the scans it saves (the N=1 whole-iteration bench row).
const POS_MAP_MIN: usize = 16;

/// Remaining work of one request (unprefilled prompt + ungenerated
/// output) — the unit of the incremental `outstanding` load signal.
fn work_of(r: &Request) -> u64 {
    r.remaining_prompt() + (r.output_len - r.generated)
}

/// Reusable per-iteration buffers. They are *taken* into the
/// [`IterationBatch`] when it is built and recovered by destructuring the
/// batch after the backend call, so steady-state iterations allocate
/// nothing on the decode path (the prefill slice vec stays per-iteration:
/// it borrows `running` and is at most a few chunks long).
#[derive(Default)]
struct StepScratch {
    dec_slots: Vec<DecodeSlot>,
    dec_shapes: Vec<AttnShape>,
    pre_shapes: Vec<AttnShape>,
    /// id → index into `running`, rebuilt per iteration for large running
    /// sets. Positions go stale the moment a preemption removes a running
    /// entry — callers gate lookups on a `preemptions` snapshot.
    pos: HashMap<RequestId, usize>,
}

/// O(1) lookup of a running request through the per-iteration position
/// map while `fresh` (no preemption has shifted positions since the map
/// was built); linear scan otherwise. A free function so callers can hold
/// disjoint borrows of other `EngineCore` fields.
fn find_running<'a>(
    running: &'a mut [Request],
    pos: &HashMap<RequestId, usize>,
    fresh: bool,
    id: RequestId,
) -> Option<&'a mut Request> {
    if fresh {
        let &i = pos.get(&id)?;
        let r = &mut running[i];
        debug_assert_eq!(r.id, id, "stale running position map");
        return Some(r);
    }
    running.iter_mut().find(|r| r.id == id)
}

/// Build the backend batch descriptor for a planned iteration from the
/// running set, into caller-provided scratch storage. A free function
/// (not a method) so the caller can hold the borrow of `running` while
/// mutably using other `EngineCore` fields. `pos` is an optional id →
/// index map over `running` (O(1) lookups for large batches).
fn iteration_batch<'a>(
    running: &'a [Request],
    decode: &[RequestId],
    prefill: &[PrefillChunk],
    pos: Option<&HashMap<RequestId, usize>>,
    mut dec_slots: Vec<DecodeSlot>,
    mut dec_shapes: Vec<AttnShape>,
    mut pre_shapes: Vec<AttnShape>,
) -> IterationBatch<'a> {
    dec_slots.clear();
    dec_shapes.clear();
    pre_shapes.clear();
    let find = |id: RequestId| -> Option<&'a Request> {
        match pos {
            Some(m) => m.get(&id).map(|&i| &running[i]),
            None => running.iter().find(|r| r.id == id),
        }
    };
    for &id in decode {
        if let Some(r) = find(id) {
            dec_slots.push(DecodeSlot {
                id: r.id,
                context_len: r.context_len(),
            });
            dec_shapes.push(AttnShape {
                q: 1,
                c: r.context_len(),
            });
        }
    }
    let mut pre: Vec<PrefillSlice<'a>> = Vec::with_capacity(prefill.len());
    for c in prefill {
        if let Some(r) = find(c.id) {
            pre.push(PrefillSlice {
                id: r.id,
                chunk_tokens: c.tokens,
                context_len: r.context_len(),
                completes_prompt: c.tokens == r.remaining_prompt(),
                prompt: r.prompt_tokens.as_deref(),
            });
            pre_shapes.push(AttnShape {
                q: c.tokens,
                c: r.context_len(),
            });
        }
    }
    IterationBatch {
        decode: dec_slots,
        prefill: pre,
        dec_shape: BatchShape::from_shapes(dec_shapes),
        pre_shape: BatchShape::from_shapes(pre_shapes),
    }
}

/// One worker's serving state + the per-iteration step all engine
/// topologies share.
pub struct EngineCore {
    pub cfg: ServingConfig,
    scheduler: Box<dyn Scheduler>,
    pub(crate) backend: Box<dyn ExecutionBackend>,
    pub(crate) kv: KvManager,
    /// Local virtual clock, seconds *within the current epoch*. Re-based
    /// to 0 when the worker goes fully idle past the re-base threshold
    /// ([`EngineCore::rebase_epoch`]); absolute engine time is
    /// [`EngineCore::total_time`].
    pub clock: f64,
    /// Clock value after the last *executed* iteration (excludes idle
    /// jumps/parking — the cluster uses it for wall-time accounting).
    /// Epoch-local and shifted on re-base, so it may go negative when
    /// the last activity happened in a previous epoch; the invariant
    /// `epoch_offset + last_active == absolute last-active time` always
    /// holds ([`EngineCore::total_active`]).
    pub last_active: f64,
    /// Engine-clock epochs completed (number of clock re-bases).
    pub epoch: u64,
    /// Engine-clock seconds accumulated in all previous epochs; added to
    /// the local clock wherever absolute time is reported.
    pub epoch_offset: f64,
    /// Arrived-and-routed-here requests, not yet admitted (FCFS).
    pub(crate) waiting: VecDeque<Request>,
    pub(crate) running: Vec<Request>,
    pub finished: Vec<Request>,
    /// Watermark into `finished` for streaming front-ends: entries before
    /// it were already pumped to their token streams
    /// ([`super::ServingTopology::pump`]).
    pub(crate) pumped_finished: usize,
    /// Release finished requests once their tokens have been pumped
    /// (enabled with streaming metrics on long-lived serving paths, so
    /// resident state stays O(in-flight) instead of O(total served);
    /// batch engines keep the vector for post-run inspection).
    pub(crate) trim_finished: bool,
    pub metrics: Recorder,
    /// Requests dropped because their prompt can never fit in KV.
    pub dropped: u64,
    /// Requests preempted (recompute-style) due to KV exhaustion.
    pub preemptions: u64,
    /// Spatial plans degraded to aggregated execution because the backend
    /// cannot partition SMs.
    pub spatial_degraded: u64,
    spatial_degrade_warned: bool,
    /// Detailed per-iteration log (Fig. 10); disabled by default.
    pub log_events: bool,
    pub events: Vec<IterEvent>,
    /// Incrementally maintained outstanding work (remaining prompt +
    /// output tokens across waiting and running) — the O(1) router load
    /// signal; equals [`EngineCore::recompute_outstanding`] at every
    /// step boundary (invariant-checked).
    outstanding: u64,
    scratch: StepScratch,
}

impl EngineCore {
    /// Core over the simulated backend (the evaluation path).
    pub fn new(cfg: ServingConfig, scheduler: Box<dyn Scheduler>, seed: u64) -> EngineCore {
        let backend = Box::new(SimBackend::from_config(&cfg, seed));
        EngineCore::with_backend(cfg, scheduler, backend)
    }

    /// Core over an arbitrary execution backend (the serving path).
    pub fn with_backend(
        cfg: ServingConfig,
        scheduler: Box<dyn Scheduler>,
        backend: Box<dyn ExecutionBackend>,
    ) -> EngineCore {
        let mut kv = KvManager::new(cfg.kv_capacity_blocks(), cfg.kv_block_tokens);
        if cfg.prefix_cache {
            kv.enable_prefix_cache();
        }
        EngineCore {
            cfg,
            scheduler,
            backend,
            kv,
            clock: 0.0,
            last_active: 0.0,
            epoch: 0,
            epoch_offset: 0.0,
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            pumped_finished: 0,
            trim_finished: false,
            metrics: Recorder::new(),
            dropped: 0,
            preemptions: 0,
            spatial_degraded: 0,
            spatial_degrade_warned: false,
            log_events: false,
            events: Vec::new(),
            outstanding: 0,
            scratch: StepScratch::default(),
        }
    }

    pub fn policy_name(&self) -> String {
        self.scheduler.name()
    }

    /// Spare prefill capacity the scheduler advertises (elastic planner
    /// signal; see [`Scheduler::prefill_headroom`]).
    pub fn prefill_headroom(&self) -> f64 {
        self.scheduler.prefill_headroom()
    }

    /// Replace the iteration scheduler — the cluster's elastic planner
    /// swaps a worker's policy when it flips its role. The caller drains
    /// running/waiting requests first (`displace_all`); the new scheduler
    /// starts from a clean queue.
    pub fn set_scheduler(&mut self, scheduler: Box<dyn Scheduler>) {
        self.scheduler = scheduler;
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Mutable access to the execution backend (streaming front-ends pull
    /// token values through this).
    pub fn backend_mut(&mut self) -> &mut dyn ExecutionBackend {
        &mut *self.backend
    }

    /// Accept one routed request into the waiting queue.
    pub fn inject(&mut self, mut r: Request) {
        r.phase = Phase::Waiting;
        self.kv.register(r.id);
        self.seed_prefix(&mut r);
        self.outstanding += work_of(&r);
        self.waiting.push_back(r);
    }

    /// Requeue a request at the head of the waiting queue (reconfiguration
    /// and preemption paths).
    pub fn inject_front(&mut self, mut r: Request) {
        r.phase = Phase::Waiting;
        self.kv.register(r.id);
        self.seed_prefix(&mut r);
        self.outstanding += work_of(&r);
        self.waiting.push_front(r);
    }

    /// Prefix-cache admission match: seed the request's block table with
    /// the longest cached prefix of its prompt and advance `prefilled`
    /// past the hit, so the scheduler only plans the uncached suffix.
    /// Capped below the full prompt — a total hit still runs one forward
    /// pass to produce the first-token logits. Runs at injection time,
    /// *before* the incremental `outstanding` signal counts the request
    /// and before any scheduler sees it.
    fn seed_prefix(&mut self, r: &mut Request) {
        if !self.kv.prefix_enabled() || r.prefilled != 0 || r.generated != 0 {
            return;
        }
        let keys = block_keys(r, self.kv.block_tokens());
        if keys.is_empty() {
            return;
        }
        let matched = self.kv.seed_prefix(r.id, &keys, r.prompt_len - 1);
        if matched > 0 {
            r.advance_prefill(matched);
            r.phase = Phase::Waiting; // not admitted yet; stays queued
            self.metrics.prefix_hits += 1;
            self.metrics.prefix_cached_tokens += matched;
        }
    }

    /// Whether this worker's KV manager runs the prefix cache.
    pub fn prefix_enabled(&self) -> bool {
        self.kv.prefix_enabled()
    }

    /// Prompt tokens resident in this worker's prefix index (held +
    /// cached) — the router's residency signal.
    pub fn prefix_resident_tokens(&self) -> u64 {
        self.kv.prefix_resident_tokens()
    }

    /// Longest cached prefix this worker holds for a prompt identified by
    /// `keys`, in tokens — the `kv-overlap` routing signal.
    pub fn prefix_overlap_tokens(&self, keys: &[BlockKey]) -> u64 {
        self.kv.probe_prefix(keys)
    }

    /// Chained block keys for `r` under this worker's block size.
    pub fn prefix_keys(&self, r: &Request) -> Vec<BlockKey> {
        block_keys(r, self.kv.block_tokens())
    }

    /// Any admitted or queued work on this worker?
    pub fn has_local_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Absolute engine time: epoch offset + the epoch-local clock.
    /// Monotone across re-bases (the serving uptime counter).
    pub fn total_time(&self) -> f64 {
        self.epoch_offset + self.clock
    }

    /// Absolute time of the last executed iteration, invariant across
    /// re-bases (wall-time accounting for merged reports).
    pub fn total_active(&self) -> f64 {
        self.epoch_offset + self.last_active
    }

    /// Shift the local time base down by `delta` (the re-base
    /// primitive): local clocks move toward 0 while every absolute
    /// quantity (`total_time`, `total_active`) is preserved. The caller
    /// must guarantee no queued or running work references the old base.
    pub(crate) fn shift_clock(&mut self, delta: f64) {
        debug_assert!(!self.has_local_work(), "re-base with work in flight");
        self.clock -= delta;
        self.last_active -= delta;
        self.epoch_offset += delta;
        self.epoch += 1;
    }

    /// Re-base the local clock to a new epoch when this worker is fully
    /// idle and the current epoch has consumed enough of its divergence
    /// horizon ([`REBASE_FRACTION`] of `cfg.max_engine_time`). Resets the
    /// local clock to 0 — re-arming the `max_engine_time` divergence
    /// guard — while `epoch_offset` keeps absolute time monotone.
    /// Returns whether a re-base happened.
    pub fn rebase_epoch(&mut self) -> bool {
        if self.has_local_work() || self.clock <= REBASE_FRACTION * self.cfg.max_engine_time {
            return false;
        }
        self.shift_clock(self.clock);
        true
    }

    /// Tokens this worker still has to process (remaining prompt +
    /// remaining output across waiting and running) — the load signal for
    /// least-outstanding-token routing. O(1): maintained incrementally on
    /// every queue mutation and token advance.
    pub fn outstanding_tokens(&self) -> u64 {
        self.outstanding
    }

    /// The O(queues) reference for the incremental `outstanding` counter
    /// (invariant checks and the naive-scan cluster reference).
    pub fn recompute_outstanding(&self) -> u64 {
        self.waiting
            .iter()
            .chain(self.running.iter())
            .map(work_of)
            .sum()
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn kv_free_tokens(&self) -> u64 {
        self.kv.free_blocks() * self.kv.block_tokens() as u64
    }

    pub fn kv_total_tokens(&self) -> u64 {
        self.kv.total_blocks() * self.kv.block_tokens() as u64
    }

    /// Visit this worker's requests that may carry new tokens — the
    /// running set as one slice, then the not-yet-pumped tail of
    /// `finished` as one slice with the flag set (each finished request
    /// is visited exactly once, tracked by `pumped_finished`) — paired
    /// with the backend holding their token values. Batched slices, not
    /// per-request closure calls: the serving path drains tokens in
    /// chunks ([`super::ServingTopology::pump`]).
    pub(crate) fn pump_local(
        &mut self,
        f: &mut dyn FnMut(&[Request], &mut dyn ExecutionBackend, bool),
    ) {
        let EngineCore {
            running,
            finished,
            backend,
            pumped_finished,
            trim_finished,
            ..
        } = self;
        if !running.is_empty() {
            f(running, &mut **backend, false);
        }
        if *pumped_finished < finished.len() {
            f(&finished[*pumped_finished..], &mut **backend, true);
            *pumped_finished = finished.len();
        }
        // Long-lived serving: everything up to the watermark (== len
        // after the visit above) has been delivered to its stream; retire
        // the payloads so resident state stays O(in-flight).
        if *trim_finished && !finished.is_empty() {
            finished.clear();
            *pumped_finished = 0;
        }
    }

    /// Remove a request from this worker's waiting or running queues,
    /// releasing its KV. Returns false when the request is not here.
    /// Backend-side state is reclaimed separately (the front-end releases
    /// it when the stream closes).
    pub(crate) fn cancel_local(&mut self, id: RequestId) -> bool {
        if let Some(pos) = self.waiting.iter().position(|r| r.id == id) {
            let r = self.waiting.remove(pos).unwrap();
            let _ = self.kv.release(r.id);
            self.outstanding -= work_of(&r);
            return true;
        }
        if let Some(pos) = self.running.iter().position(|r| r.id == id) {
            let r = self.running.remove(pos);
            let _ = self.kv.release(r.id);
            self.outstanding -= work_of(&r);
            return true;
        }
        false
    }

    /// Divergence drain: drop all queued and in-flight work, releasing
    /// its KV. Returns how many requests were discarded (also added to
    /// `self.dropped`).
    pub fn drain_diverged(&mut self) -> u64 {
        let mut n = 0u64;
        while let Some(r) = self.waiting.pop_front() {
            let _ = self.kv.release(r.id);
            self.backend.release(r.id);
            n += 1;
        }
        let EngineCore {
            running,
            kv,
            backend,
            ..
        } = self;
        for r in running.drain(..) {
            let _ = kv.release(r.id);
            backend.release(r.id);
            n += 1;
        }
        self.dropped += n;
        self.outstanding = 0;
        n
    }

    /// Run one scheduling + execution iteration over the local queues.
    ///
    /// `allow_drop_head`: when the scheduler idles with an empty running
    /// set, the head waiting request can never be admitted (its prompt
    /// exceeds KV) — drop it to avoid deadlock. Topologies pass `false`
    /// while arrivals are still pending so the legacy ordering (drain
    /// arrivals first, then drop) is preserved.
    pub fn step_once(&mut self, allow_drop_head: bool) -> CoreStep {
        let sched_start = Instant::now();
        let input = SchedInput {
            running: &self.running,
            waiting: self.waiting.make_contiguous(),
            kv_free_tokens: self.kv.free_blocks() * self.kv.block_tokens() as u64,
            kv_total_tokens: self.kv.total_blocks() * self.kv.block_tokens() as u64,
        };
        let plan = self.scheduler.plan(&input);
        let sched_s = sched_start.elapsed().as_secs_f64();
        self.metrics.sched_overhead += sched_s;
        self.metrics.qos_preemptions += self.scheduler.take_qos_preemptions();

        match plan {
            IterationPlan::Idle => {
                if allow_drop_head && !self.waiting.is_empty() && self.running.is_empty() {
                    // Head request can never fit: drop it or we deadlock.
                    let r = self.waiting.pop_front().unwrap();
                    let _ = self.kv.release(r.id);
                    self.backend.release(r.id);
                    self.dropped += 1;
                    self.outstanding -= work_of(&r);
                    CoreStep::DroppedHead(r.id)
                } else {
                    CoreStep::Idle
                }
            }
            IterationPlan::Aggregated { decode, prefill } => {
                self.exec_aggregated(decode, prefill, sched_s);
                CoreStep::Executed
            }
            IterationPlan::Spatial {
                decode,
                prefill,
                plan,
            } => {
                if self.backend.supports_spatial() {
                    self.exec_spatial(decode, prefill, plan, sched_s);
                } else {
                    // The backend cannot partition SMs (e.g. the PJRT
                    // runtime): degrade to one aggregated batch.
                    if !self.spatial_degrade_warned {
                        self.spatial_degrade_warned = true;
                        eprintln!(
                            "engine: backend `{}` cannot run spatial plans; \
                             degrading to aggregated execution",
                            self.backend.name()
                        );
                    }
                    self.spatial_degraded += 1;
                    self.exec_aggregated(decode, prefill, sched_s);
                }
                CoreStep::Executed
            }
        }
    }

    /// Move scheduled waiting requests into running (admission).
    fn admit_scheduled(&mut self, prefill: &[PrefillChunk]) {
        for c in prefill.iter().filter(|c| c.admit) {
            if let Some(pos) = self.waiting.iter().position(|r| r.id == c.id) {
                let r = self.waiting.remove(pos).unwrap();
                self.running.push(r);
            }
        }
    }

    /// KV-append with recompute-preemption on exhaustion: the most
    /// recently admitted running request is evicted, reset, and requeued
    /// (vLLM's recompute preemption policy).
    fn kv_append_or_preempt(&mut self, id: RequestId, tokens: u64) -> bool {
        loop {
            match self.kv.append(id, tokens) {
                Ok(()) => return true,
                Err(_) => {
                    // Evict the newest running request that is not `id`.
                    let victim = self
                        .running
                        .iter()
                        .rposition(|r| r.id != id && r.phase != Phase::Finished);
                    match victim {
                        Some(pos) => {
                            let v = self.running.remove(pos);
                            let _ = self.kv.release(v.id);
                            self.backend.release(v.id);
                            self.preemptions += 1;
                            self.metrics.preemptions += 1;
                            self.outstanding -= work_of(&v);
                            // Recompute preemption: progress is lost.
                            let fresh = v.reset_for_retry();
                            self.kv.register(fresh.id);
                            self.outstanding += work_of(&fresh);
                            self.waiting.push_front(fresh);
                        }
                        None => return false, // single request larger than KV
                    }
                }
            }
        }
    }

    /// Rebuild the id → running-index map when the running set is large
    /// enough to amortize it. Returns whether the map is in use this
    /// iteration.
    fn build_pos_map(&mut self) -> bool {
        if self.running.len() < POS_MAP_MIN {
            return false;
        }
        self.scratch.pos.clear();
        for (i, r) in self.running.iter().enumerate() {
            self.scratch.pos.insert(r.id, i);
        }
        true
    }

    fn exec_aggregated(&mut self, decode: Vec<RequestId>, prefill: Vec<PrefillChunk>, sched_s: f64) {
        self.admit_scheduled(&prefill);
        let use_pos = self.build_pos_map();
        let batch = iteration_batch(
            &self.running,
            &decode,
            &prefill,
            use_pos.then_some(&self.scratch.pos),
            std::mem::take(&mut self.scratch.dec_slots),
            std::mem::take(&mut self.scratch.dec_shapes),
            std::mem::take(&mut self.scratch.pre_shapes),
        );
        // Decode-only batches replay captured graphs; any prefill in the
        // batch forces eager dispatch (dynamic shapes — §4.3).
        let mode = if batch.pre_shape.is_empty() {
            DispatchMode::Graph
        } else {
            DispatchMode::Eager
        };
        let pre_tokens = batch.pre_shape.n_tokens;
        let res = self
            .backend
            .run_aggregated(&batch, self.cfg.gpu.num_sms, mode);
        let IterationBatch {
            decode: dec_slots,
            prefill: pre_slices,
            dec_shape,
            pre_shape,
        } = batch;
        drop(pre_slices); // ends the borrow of `running`
        self.scratch.dec_slots = dec_slots;
        self.scratch.dec_shapes = dec_shape.shapes;
        self.scratch.pre_shapes = pre_shape.shapes;
        // The virtual clock stays deterministic: measured CPU scheduling
        // time is *reported* (metrics/events) but not added to simulated
        // time — it is µs against ~100 ms iterations (Fig. 10).
        let dur = res.total();
        let t_end = self.clock + dur;
        let preempt_snap = self.preemptions;

        // KV appends + request state updates.
        for &id in &decode {
            if self.kv_append_or_preempt(id, 1) {
                let fresh = use_pos && self.preemptions == preempt_snap;
                if let Some(r) = find_running(&mut self.running, &self.scratch.pos, fresh, id) {
                    if r.phase == Phase::Decode {
                        r.advance_decode(t_end);
                        self.outstanding -= 1;
                    }
                }
            }
        }
        for c in &prefill {
            if self.kv_append_or_preempt(c.id, c.tokens) {
                let fresh = use_pos && self.preemptions == preempt_snap;
                if let Some(r) = find_running(&mut self.running, &self.scratch.pos, fresh, c.id) {
                    r.advance_prefill(c.tokens);
                    self.outstanding -= c.tokens;
                    self.metrics.prefilled_tokens += c.tokens;
                    if r.phase == Phase::Decode {
                        // Prompt completed: this forward's logits produce
                        // the first output token.
                        let id = r.id;
                        if self.kv_append_or_preempt(id, 1) {
                            let fresh = use_pos && self.preemptions == preempt_snap;
                            if let Some(r) =
                                find_running(&mut self.running, &self.scratch.pos, fresh, id)
                            {
                                r.advance_decode(t_end);
                                self.outstanding -= 1;
                            }
                        }
                    }
                }
            }
        }

        self.metrics
            .record_util(res.gpu_time, res.sm_util, res.hbm_util);
        self.metrics.busy_time += res.gpu_time;
        self.metrics.iterations += 1;
        if self.log_events {
            self.events.push(IterEvent {
                t_start: self.clock,
                duration: dur,
                kind: IterKind::Aggregated,
                n_decode: decode.len() as u32,
                prefill_tokens: pre_tokens,
                sched_s,
                sm_util: res.sm_util,
                hbm_util: res.hbm_util,
            });
        }
        self.clock = t_end;
        self.last_active = t_end;
        self.retire_finished();
    }

    fn exec_spatial(
        &mut self,
        decode: Vec<RequestId>,
        prefill: Vec<PrefillChunk>,
        plan: crate::hw::PartitionPlan,
        sched_s: f64,
    ) {
        self.admit_scheduled(&prefill);
        let use_pos = self.build_pos_map();
        let batch = iteration_batch(
            &self.running,
            &decode,
            &prefill,
            use_pos.then_some(&self.scratch.pos),
            std::mem::take(&mut self.scratch.dec_slots),
            std::mem::take(&mut self.scratch.dec_shapes),
            std::mem::take(&mut self.scratch.pre_shapes),
        );
        let pre_tokens = batch.pre_shape.n_tokens;
        let res = self.backend.run_spatial(&batch, &plan);
        let IterationBatch {
            decode: dec_slots,
            prefill: pre_slices,
            dec_shape,
            pre_shape,
        } = batch;
        drop(pre_slices); // ends the borrow of `running`
        self.scratch.dec_slots = dec_slots;
        self.scratch.dec_shapes = dec_shape.shapes;
        self.scratch.pre_shapes = pre_shape.shapes;
        let dur = res.span;
        let t_end = self.clock + dur;
        let k = plan.k.max(1);
        let preempt_snap = self.preemptions;

        // Look-ahead decode: reserve k slots per request up front (§4.3),
        // then run k uninterrupted steps; step i completes at
        // t0 + dispatch + (i+1)·t_step.
        for &id in &decode {
            let _ = self.kv.reserve(id, k as u64); // best-effort; append below enforces
        }
        let t0 = self.clock;
        for i in 0..k {
            let t_tok = t0 + res.dec.dispatch_time + (i + 1) as f64 * res.t_decode_step;
            for &id in &decode {
                let fresh = use_pos && self.preemptions == preempt_snap;
                let done = find_running(&mut self.running, &self.scratch.pos, fresh, id)
                    .map(|r| r.phase != Phase::Decode)
                    .unwrap_or(true);
                if done {
                    continue; // finished mid-look-ahead: slot wasted
                }
                if self.kv_append_or_preempt(id, 1) {
                    let fresh = use_pos && self.preemptions == preempt_snap;
                    if let Some(r) = find_running(&mut self.running, &self.scratch.pos, fresh, id) {
                        r.advance_decode(t_tok.min(t_end));
                        self.outstanding -= 1;
                    }
                }
            }
        }

        // Prefill side advances at the synchronization point.
        for c in &prefill {
            if self.kv_append_or_preempt(c.id, c.tokens) {
                let fresh = use_pos && self.preemptions == preempt_snap;
                if let Some(r) = find_running(&mut self.running, &self.scratch.pos, fresh, c.id) {
                    r.advance_prefill(c.tokens);
                    self.outstanding -= c.tokens;
                    self.metrics.prefilled_tokens += c.tokens;
                    if r.phase == Phase::Decode {
                        let id = r.id;
                        if self.kv_append_or_preempt(id, 1) {
                            let fresh = use_pos && self.preemptions == preempt_snap;
                            if let Some(r) =
                                find_running(&mut self.running, &self.scratch.pos, fresh, id)
                            {
                                r.advance_decode(t_end);
                                self.outstanding -= 1;
                            }
                        }
                    }
                }
            }
        }

        // Utilization: weight each side by its busy time over its SM share.
        let f_dec = plan.decode.fraction(&self.cfg.gpu);
        let f_pre = plan.prefill.fraction(&self.cfg.gpu);
        let busy_dec = (k as f64 * res.t_decode_step).min(res.span);
        let busy_pre = res.t_prefill.min(res.span);
        let sm = f_dec * res.dec.sm_util * busy_dec / res.span
            + f_pre * res.pre.sm_util * busy_pre / res.span;
        let hbm =
            res.dec.hbm_util * busy_dec / res.span + res.pre.hbm_util * busy_pre / res.span;
        self.metrics.record_util(res.span, sm, hbm);
        self.metrics.busy_time += res.span;
        self.metrics.iterations += 1;
        self.metrics.spatial_iterations += 1;
        if self.log_events {
            self.events.push(IterEvent {
                t_start: self.clock,
                duration: dur,
                kind: IterKind::Spatial {
                    decode_tpcs: plan.decode.n_tpcs,
                    prefill_tpcs: plan.prefill.n_tpcs,
                    k,
                },
                n_decode: decode.len() as u32,
                prefill_tokens: pre_tokens,
                sched_s,
                sm_util: sm,
                hbm_util: hbm,
            });
        }
        self.clock = t_end;
        self.last_active = t_end;
        self.retire_finished();
    }

    pub(crate) fn retire_finished(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].phase == Phase::Finished {
                let r = self.running.swap_remove(i);
                if self.kv.prefix_enabled() {
                    // Decay the request's full prompt blocks into the
                    // cached pool instead of freeing them.
                    let keys = block_keys(&r, self.kv.block_tokens());
                    let _ = self.kv.finish_release(r.id, &keys);
                } else {
                    let _ = self.kv.release(r.id);
                }
                self.metrics.record_finished(&r);
                self.finished.push(r);
            } else {
                i += 1;
            }
        }
        if self.kv.prefix_enabled() {
            // The manager's eviction counter is cumulative; assignment
            // (not +=) keeps the recorder merge-correct across workers.
            self.metrics.prefix_evictions = self.kv.prefix_evictions();
        }
    }

    /// Pull every finished-prefill (now Decode-phase) request out of this
    /// worker, releasing its local KV — the disaggregated prefill→decode
    /// hand-off. Appends `(request, transfer_time)` pairs to `out` in
    /// queue order; the caller owns `out` so the per-event Vec the old
    /// extraction loop allocated disappears.
    pub(crate) fn extract_decode_ready(&mut self, out: &mut Vec<(Request, f64)>) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].phase == Phase::Decode {
                let r = self.running.remove(i); // keep arrival order
                let _ = self.kv.release(r.id);
                self.backend.release(r.id);
                self.outstanding -= work_of(&r);
                let dt = self.backend.kv_transfer_time(r.context_len());
                out.push((r, dt));
            } else {
                i += 1;
            }
        }
    }

    /// Admit a transferred (already-prefilled) request into this worker's
    /// running set, materializing its KV context. Err(request) hands the
    /// request back untouched when KV space is insufficient.
    pub(crate) fn admit_transferred(&mut self, mut r: Request) -> Result<(), Request> {
        self.kv.register(r.id);
        if self.kv.append(r.id, r.context_len()).is_err() {
            let _ = self.kv.release(r.id);
            return Err(r);
        }
        r.phase = Phase::Decode;
        self.outstanding += work_of(&r);
        self.running.push(r);
        Ok(())
    }

    /// One decode-only iteration over everything running — the
    /// decode-worker step in a disaggregated cluster. Uses the same
    /// scratch buffers as [`exec_aggregated`](Self::exec_aggregated); the
    /// batch replays a captured graph (decode-only shapes are static).
    pub(crate) fn decode_step_transferred(&mut self) {
        let mut dec_slots = std::mem::take(&mut self.scratch.dec_slots);
        let mut dec_shapes = std::mem::take(&mut self.scratch.dec_shapes);
        dec_slots.clear();
        dec_shapes.clear();
        for r in &self.running {
            dec_slots.push(DecodeSlot {
                id: r.id,
                context_len: r.context_len(),
            });
            dec_shapes.push(AttnShape {
                q: 1,
                c: r.context_len(),
            });
        }
        let batch = IterationBatch {
            decode: dec_slots,
            prefill: Vec::new(),
            dec_shape: BatchShape::from_shapes(dec_shapes),
            pre_shape: BatchShape::default(),
        };
        let res = self
            .backend
            .run_aggregated(&batch, self.cfg.gpu.num_sms, DispatchMode::Graph);
        let IterationBatch {
            decode: dec_slots,
            dec_shape,
            ..
        } = batch;
        self.scratch.dec_slots = dec_slots;
        self.scratch.dec_shapes = dec_shape.shapes;
        let t_end = self.clock + res.total();
        self.metrics.busy_time += res.gpu_time;
        self.metrics
            .record_util(res.gpu_time, res.sm_util, res.hbm_util);
        self.metrics.iterations += 1;
        let EngineCore {
            running,
            kv,
            outstanding,
            ..
        } = self;
        for r in running.iter_mut() {
            let _ = kv.append(r.id, 1);
            r.advance_decode(t_end);
            *outstanding -= 1;
        }
        self.clock = t_end;
        self.last_active = t_end;
        self.retire_finished();
    }

    /// Displace all local work (waiting first, then running, preserving
    /// order) into `out`, releasing KV and backend state — the
    /// reconfiguration planner's role-flip drain.
    pub(crate) fn displace_all(&mut self, out: &mut Vec<Request>) {
        while let Some(r) = self.waiting.pop_front() {
            let _ = self.kv.release(r.id);
            self.backend.release(r.id);
            out.push(r);
        }
        for r in self.running.drain(..) {
            let _ = self.kv.release(r.id);
            self.backend.release(r.id);
            out.push(r);
        }
        self.outstanding = 0;
    }

    /// Engine-level invariants, used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        let expect = self.recompute_outstanding();
        if self.outstanding != expect {
            return Err(format!(
                "incremental outstanding {} != recomputed {expect}",
                self.outstanding
            ));
        }
        for r in &self.running {
            if r.phase == Phase::Finished {
                return Err(format!("finished request {} still running", r.id));
            }
            if r.generated > r.output_len {
                return Err(format!("request {} over-generated", r.id));
            }
        }
        for r in &self.finished {
            if r.generated != r.output_len || r.phase != Phase::Finished {
                return Err(format!("request {} retired unfinished", r.id));
            }
            if r.token_times.windows(2).any(|w| w[1] < w[0]) {
                return Err(format!("request {} token times not monotone", r.id));
            }
            if let Some(t) = r.first_token_at {
                if t < r.arrival {
                    return Err(format!("request {} produced a token before arrival", r.id));
                }
            }
        }
        Ok(())
    }
}
