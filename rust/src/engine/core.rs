//! The shared per-iteration serving step.
//!
//! [`EngineCore`] owns everything one worker needs to execute one
//! continuous-batching iteration: scheduler, execution backend, paged KV
//! manager, local virtual clock, waiting/running queues, and a metrics
//! recorder. It deliberately knows nothing about *where requests come
//! from* — arrival streams, routing, replication, and disaggregation are
//! topology concerns layered on top ([`super::SimEngine`] for one worker,
//! [`super::ClusterEngine`] for many) — nor about *how* iterations
//! execute: that is the [`ExecutionBackend`] seam (simulated roofline
//! executor or the real PJRT runtime).

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::ServingConfig;
use crate::kvcache::KvManager;
use crate::metrics::Recorder;
use crate::model::AttnShape;
use crate::request::{Phase, Request, RequestId};
use crate::roofline::BatchShape;
use crate::sched::{IterationPlan, PrefillChunk, SchedInput, Scheduler};
use crate::sim::DispatchMode;

use super::backend::{DecodeSlot, ExecutionBackend, IterationBatch, PrefillSlice, SimBackend};
use super::{IterEvent, IterKind};

/// Default cap on *epoch-local* simulated time — a run whose local clock
/// exceeds this has diverged (arrival rate above capacity with an
/// unbounded queue). Shared by every engine topology; the effective
/// per-instance value is [`crate::config::ServingConfig::max_engine_time`]
/// and the drain-on-divergence bookkeeping lives in
/// [`EngineCore::drain_diverged`]. On the serving path the guard
/// *re-arms*: when a topology goes fully idle past
/// [`REBASE_FRACTION`] of its horizon, the local clock re-bases to a new
/// epoch ([`EngineCore::rebase_epoch`]) and cross-epoch time accumulates
/// in `epoch_offset`, so a long-lived instance never hits a hard
/// end-of-life cliff.
pub const MAX_SIM_TIME: f64 = crate::config::DEFAULT_MAX_ENGINE_TIME;

/// Fraction of the divergence horizon an idle epoch must have consumed
/// before the clock re-bases. Below it, idle topologies keep their clock
/// (so paper-scale live runs take *byte-identical* event trajectories to
/// batch replay — the live ≡ batch property tests never observe a
/// re-base); above it, re-basing keeps weeks-uptime serving honest.
pub const REBASE_FRACTION: f64 = 0.5;

/// What one call to [`EngineCore::step_once`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreStep {
    /// An iteration executed; the local clock advanced.
    Executed,
    /// Nothing schedulable; the caller decides how to advance the clock.
    Idle,
    /// The head waiting request can never fit in KV and was dropped.
    DroppedHead(RequestId),
}

/// Build the backend batch descriptor for a planned iteration from the
/// running set. A free function (not a method) so the caller can hold the
/// borrow of `running` while mutably using other `EngineCore` fields.
fn iteration_batch<'a>(
    running: &'a [Request],
    decode: &[RequestId],
    prefill: &[PrefillChunk],
) -> IterationBatch<'a> {
    let find = |id: RequestId| running.iter().find(|r| r.id == id);
    let dec: Vec<DecodeSlot> = decode
        .iter()
        .filter_map(|&id| find(id))
        .map(|r| DecodeSlot {
            id: r.id,
            context_len: r.context_len(),
        })
        .collect();
    let pre: Vec<PrefillSlice<'a>> = prefill
        .iter()
        .filter_map(|c| find(c.id).map(|r| (r, c.tokens)))
        .map(|(r, q)| PrefillSlice {
            id: r.id,
            chunk_tokens: q,
            context_len: r.context_len(),
            completes_prompt: q == r.remaining_prompt(),
            prompt: r.prompt_tokens.as_deref(),
        })
        .collect();
    let dec_shape = BatchShape::from_shapes(
        dec.iter()
            .map(|d| AttnShape {
                q: 1,
                c: d.context_len,
            })
            .collect(),
    );
    let pre_shape = BatchShape::from_shapes(
        pre.iter()
            .map(|p| AttnShape {
                q: p.chunk_tokens,
                c: p.context_len,
            })
            .collect(),
    );
    IterationBatch {
        decode: dec,
        prefill: pre,
        dec_shape,
        pre_shape,
    }
}

/// One worker's serving state + the per-iteration step all engine
/// topologies share.
pub struct EngineCore {
    pub cfg: ServingConfig,
    scheduler: Box<dyn Scheduler>,
    pub(crate) backend: Box<dyn ExecutionBackend>,
    pub(crate) kv: KvManager,
    /// Local virtual clock, seconds *within the current epoch*. Re-based
    /// to 0 when the worker goes fully idle past the re-base threshold
    /// ([`EngineCore::rebase_epoch`]); absolute engine time is
    /// [`EngineCore::total_time`].
    pub clock: f64,
    /// Clock value after the last *executed* iteration (excludes idle
    /// jumps/parking — the cluster uses it for wall-time accounting).
    /// Epoch-local and shifted on re-base, so it may go negative when
    /// the last activity happened in a previous epoch; the invariant
    /// `epoch_offset + last_active == absolute last-active time` always
    /// holds ([`EngineCore::total_active`]).
    pub last_active: f64,
    /// Engine-clock epochs completed (number of clock re-bases).
    pub epoch: u64,
    /// Engine-clock seconds accumulated in all previous epochs; added to
    /// the local clock wherever absolute time is reported.
    pub epoch_offset: f64,
    /// Arrived-and-routed-here requests, not yet admitted (FCFS).
    pub(crate) waiting: VecDeque<Request>,
    pub(crate) running: Vec<Request>,
    pub finished: Vec<Request>,
    /// Watermark into `finished` for streaming front-ends: entries before
    /// it were already pumped to their token streams
    /// ([`super::ServingTopology::pump`]).
    pub(crate) pumped_finished: usize,
    /// Release finished requests once their tokens have been pumped
    /// (enabled with streaming metrics on long-lived serving paths, so
    /// resident state stays O(in-flight) instead of O(total served);
    /// batch engines keep the vector for post-run inspection).
    pub(crate) trim_finished: bool,
    pub metrics: Recorder,
    /// Requests dropped because their prompt can never fit in KV.
    pub dropped: u64,
    /// Requests preempted (recompute-style) due to KV exhaustion.
    pub preemptions: u64,
    /// Spatial plans degraded to aggregated execution because the backend
    /// cannot partition SMs.
    pub spatial_degraded: u64,
    spatial_degrade_warned: bool,
    /// Detailed per-iteration log (Fig. 10); disabled by default.
    pub log_events: bool,
    pub events: Vec<IterEvent>,
}

impl EngineCore {
    /// Core over the simulated backend (the evaluation path).
    pub fn new(cfg: ServingConfig, scheduler: Box<dyn Scheduler>, seed: u64) -> EngineCore {
        let backend = Box::new(SimBackend::from_config(&cfg, seed));
        EngineCore::with_backend(cfg, scheduler, backend)
    }

    /// Core over an arbitrary execution backend (the serving path).
    pub fn with_backend(
        cfg: ServingConfig,
        scheduler: Box<dyn Scheduler>,
        backend: Box<dyn ExecutionBackend>,
    ) -> EngineCore {
        let kv = KvManager::new(cfg.kv_capacity_blocks(), cfg.kv_block_tokens);
        EngineCore {
            cfg,
            scheduler,
            backend,
            kv,
            clock: 0.0,
            last_active: 0.0,
            epoch: 0,
            epoch_offset: 0.0,
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            pumped_finished: 0,
            trim_finished: false,
            metrics: Recorder::new(),
            dropped: 0,
            preemptions: 0,
            spatial_degraded: 0,
            spatial_degrade_warned: false,
            log_events: false,
            events: Vec::new(),
        }
    }

    pub fn policy_name(&self) -> String {
        self.scheduler.name()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Mutable access to the execution backend (streaming front-ends pull
    /// token values through this).
    pub fn backend_mut(&mut self) -> &mut dyn ExecutionBackend {
        &mut *self.backend
    }

    /// Accept one routed request into the waiting queue.
    pub fn inject(&mut self, mut r: Request) {
        r.phase = Phase::Waiting;
        self.kv.register(r.id);
        self.waiting.push_back(r);
    }

    /// Requeue a request at the head of the waiting queue (reconfiguration
    /// and preemption paths).
    pub fn inject_front(&mut self, mut r: Request) {
        r.phase = Phase::Waiting;
        self.kv.register(r.id);
        self.waiting.push_front(r);
    }

    /// Any admitted or queued work on this worker?
    pub fn has_local_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Absolute engine time: epoch offset + the epoch-local clock.
    /// Monotone across re-bases (the serving uptime counter).
    pub fn total_time(&self) -> f64 {
        self.epoch_offset + self.clock
    }

    /// Absolute time of the last executed iteration, invariant across
    /// re-bases (wall-time accounting for merged reports).
    pub fn total_active(&self) -> f64 {
        self.epoch_offset + self.last_active
    }

    /// Shift the local time base down by `delta` (the re-base
    /// primitive): local clocks move toward 0 while every absolute
    /// quantity (`total_time`, `total_active`) is preserved. The caller
    /// must guarantee no queued or running work references the old base.
    pub(crate) fn shift_clock(&mut self, delta: f64) {
        debug_assert!(!self.has_local_work(), "re-base with work in flight");
        self.clock -= delta;
        self.last_active -= delta;
        self.epoch_offset += delta;
        self.epoch += 1;
    }

    /// Re-base the local clock to a new epoch when this worker is fully
    /// idle and the current epoch has consumed enough of its divergence
    /// horizon ([`REBASE_FRACTION`] of `cfg.max_engine_time`). Resets the
    /// local clock to 0 — re-arming the `max_engine_time` divergence
    /// guard — while `epoch_offset` keeps absolute time monotone.
    /// Returns whether a re-base happened.
    pub fn rebase_epoch(&mut self) -> bool {
        if self.has_local_work() || self.clock <= REBASE_FRACTION * self.cfg.max_engine_time {
            return false;
        }
        self.shift_clock(self.clock);
        true
    }

    /// Tokens this worker still has to process (remaining prompt +
    /// remaining output across waiting and running) — the load signal for
    /// least-outstanding-token routing.
    pub fn outstanding_tokens(&self) -> u64 {
        self.waiting
            .iter()
            .chain(self.running.iter())
            .map(|r| r.remaining_prompt() + (r.output_len - r.generated))
            .sum()
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn kv_free_tokens(&self) -> u64 {
        self.kv.free_blocks() * self.kv.block_tokens() as u64
    }

    pub fn kv_total_tokens(&self) -> u64 {
        self.kv.total_blocks() * self.kv.block_tokens() as u64
    }

    /// Visit this worker's requests that may carry new tokens — every
    /// running request, then each finished request exactly once (tracked
    /// by `pumped_finished`) with the flag set — paired with the backend
    /// holding their token values. Streaming front-ends drive this
    /// through [`super::ServingTopology::pump`].
    pub(crate) fn pump_local(
        &mut self,
        f: &mut dyn FnMut(&Request, &mut dyn ExecutionBackend, bool),
    ) {
        let EngineCore {
            running,
            finished,
            backend,
            pumped_finished,
            trim_finished,
            ..
        } = self;
        for r in running.iter() {
            f(r, &mut **backend, false);
        }
        while *pumped_finished < finished.len() {
            let r = &finished[*pumped_finished];
            *pumped_finished += 1;
            f(r, &mut **backend, true);
        }
        // Long-lived serving: everything up to the watermark (== len
        // after the loop above) has been delivered to its stream; retire
        // the payloads so resident state stays O(in-flight).
        if *trim_finished && !finished.is_empty() {
            finished.clear();
            *pumped_finished = 0;
        }
    }

    /// Remove a request from this worker's waiting or running queues,
    /// releasing its KV. Returns false when the request is not here.
    /// Backend-side state is reclaimed separately (the front-end releases
    /// it when the stream closes).
    pub(crate) fn cancel_local(&mut self, id: RequestId) -> bool {
        if let Some(pos) = self.waiting.iter().position(|r| r.id == id) {
            let r = self.waiting.remove(pos).unwrap();
            let _ = self.kv.release(r.id);
            return true;
        }
        if let Some(pos) = self.running.iter().position(|r| r.id == id) {
            let r = self.running.remove(pos);
            let _ = self.kv.release(r.id);
            return true;
        }
        false
    }

    /// Divergence drain: drop all queued and in-flight work, releasing
    /// its KV. Returns how many requests were discarded (also added to
    /// `self.dropped`).
    pub fn drain_diverged(&mut self) -> u64 {
        let mut n = 0u64;
        while let Some(r) = self.waiting.pop_front() {
            let _ = self.kv.release(r.id);
            self.backend.release(r.id);
            n += 1;
        }
        for r in self.running.drain(..) {
            let _ = self.kv.release(r.id);
            self.backend.release(r.id);
            n += 1;
        }
        self.dropped += n;
        n
    }

    /// Run one scheduling + execution iteration over the local queues.
    ///
    /// `allow_drop_head`: when the scheduler idles with an empty running
    /// set, the head waiting request can never be admitted (its prompt
    /// exceeds KV) — drop it to avoid deadlock. Topologies pass `false`
    /// while arrivals are still pending so the legacy ordering (drain
    /// arrivals first, then drop) is preserved.
    pub fn step_once(&mut self, allow_drop_head: bool) -> CoreStep {
        let sched_start = Instant::now();
        let input = SchedInput {
            running: &self.running,
            waiting: self.waiting.make_contiguous(),
            kv_free_tokens: self.kv.free_blocks() * self.kv.block_tokens() as u64,
            kv_total_tokens: self.kv.total_blocks() * self.kv.block_tokens() as u64,
        };
        let plan = self.scheduler.plan(&input);
        let sched_s = sched_start.elapsed().as_secs_f64();
        self.metrics.sched_overhead += sched_s;

        match plan {
            IterationPlan::Idle => {
                if allow_drop_head && !self.waiting.is_empty() && self.running.is_empty() {
                    // Head request can never fit: drop it or we deadlock.
                    let r = self.waiting.pop_front().unwrap();
                    let _ = self.kv.release(r.id);
                    self.backend.release(r.id);
                    self.dropped += 1;
                    CoreStep::DroppedHead(r.id)
                } else {
                    CoreStep::Idle
                }
            }
            IterationPlan::Aggregated { decode, prefill } => {
                self.exec_aggregated(decode, prefill, sched_s);
                CoreStep::Executed
            }
            IterationPlan::Spatial {
                decode,
                prefill,
                plan,
            } => {
                if self.backend.supports_spatial() {
                    self.exec_spatial(decode, prefill, plan, sched_s);
                } else {
                    // The backend cannot partition SMs (e.g. the PJRT
                    // runtime): degrade to one aggregated batch.
                    if !self.spatial_degrade_warned {
                        self.spatial_degrade_warned = true;
                        eprintln!(
                            "engine: backend `{}` cannot run spatial plans; \
                             degrading to aggregated execution",
                            self.backend.name()
                        );
                    }
                    self.spatial_degraded += 1;
                    self.exec_aggregated(decode, prefill, sched_s);
                }
                CoreStep::Executed
            }
        }
    }

    /// Move scheduled waiting requests into running (admission).
    fn admit_scheduled(&mut self, prefill: &[PrefillChunk]) {
        for c in prefill.iter().filter(|c| c.admit) {
            if let Some(pos) = self.waiting.iter().position(|r| r.id == c.id) {
                let r = self.waiting.remove(pos).unwrap();
                self.running.push(r);
            }
        }
    }

    /// KV-append with recompute-preemption on exhaustion: the most
    /// recently admitted running request is evicted, reset, and requeued
    /// (vLLM's recompute preemption policy).
    fn kv_append_or_preempt(&mut self, id: RequestId, tokens: u64) -> bool {
        loop {
            match self.kv.append(id, tokens) {
                Ok(()) => return true,
                Err(_) => {
                    // Evict the newest running request that is not `id`.
                    let victim = self
                        .running
                        .iter()
                        .rposition(|r| r.id != id && r.phase != Phase::Finished);
                    match victim {
                        Some(pos) => {
                            let v = self.running.remove(pos);
                            let _ = self.kv.release(v.id);
                            self.backend.release(v.id);
                            self.preemptions += 1;
                            // Recompute preemption: progress is lost.
                            let fresh = v.reset_for_retry();
                            self.kv.register(fresh.id);
                            self.waiting.push_front(fresh);
                        }
                        None => return false, // single request larger than KV
                    }
                }
            }
        }
    }

    fn exec_aggregated(&mut self, decode: Vec<RequestId>, prefill: Vec<PrefillChunk>, sched_s: f64) {
        self.admit_scheduled(&prefill);
        let batch = iteration_batch(&self.running, &decode, &prefill);
        // Decode-only batches replay captured graphs; any prefill in the
        // batch forces eager dispatch (dynamic shapes — §4.3).
        let mode = if batch.pre_shape.is_empty() {
            DispatchMode::Graph
        } else {
            DispatchMode::Eager
        };
        let pre_tokens = batch.pre_shape.n_tokens;
        let res = self
            .backend
            .run_aggregated(&batch, self.cfg.gpu.num_sms, mode);
        drop(batch);
        // The virtual clock stays deterministic: measured CPU scheduling
        // time is *reported* (metrics/events) but not added to simulated
        // time — it is µs against ~100 ms iterations (Fig. 10).
        let dur = res.total();
        let t_end = self.clock + dur;

        // KV appends + request state updates.
        for &id in &decode {
            if self.kv_append_or_preempt(id, 1) {
                if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                    if r.phase == Phase::Decode {
                        r.advance_decode(t_end);
                    }
                }
            }
        }
        for c in &prefill {
            if self.kv_append_or_preempt(c.id, c.tokens) {
                if let Some(pos) = self.running.iter().position(|r| r.id == c.id) {
                    let r = &mut self.running[pos];
                    r.advance_prefill(c.tokens);
                    if r.phase == Phase::Decode {
                        // Prompt completed: this forward's logits produce
                        // the first output token.
                        let id = r.id;
                        if self.kv_append_or_preempt(id, 1) {
                            if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                                r.advance_decode(t_end);
                            }
                        }
                    }
                }
            }
        }

        self.metrics
            .record_util(res.gpu_time, res.sm_util, res.hbm_util);
        self.metrics.busy_time += res.gpu_time;
        self.metrics.iterations += 1;
        if self.log_events {
            self.events.push(IterEvent {
                t_start: self.clock,
                duration: dur,
                kind: IterKind::Aggregated,
                n_decode: decode.len() as u32,
                prefill_tokens: pre_tokens,
                sched_s,
                sm_util: res.sm_util,
                hbm_util: res.hbm_util,
            });
        }
        self.clock = t_end;
        self.last_active = t_end;
        self.retire_finished();
    }

    fn exec_spatial(
        &mut self,
        decode: Vec<RequestId>,
        prefill: Vec<PrefillChunk>,
        plan: crate::hw::PartitionPlan,
        sched_s: f64,
    ) {
        self.admit_scheduled(&prefill);
        let batch = iteration_batch(&self.running, &decode, &prefill);
        let pre_tokens = batch.pre_shape.n_tokens;
        let res = self.backend.run_spatial(&batch, &plan);
        drop(batch);
        let dur = res.span;
        let t_end = self.clock + dur;
        let k = plan.k.max(1);

        // Look-ahead decode: reserve k slots per request up front (§4.3),
        // then run k uninterrupted steps; step i completes at
        // t0 + dispatch + (i+1)·t_step.
        for &id in &decode {
            let _ = self.kv.reserve(id, k as u64); // best-effort; append below enforces
        }
        let t0 = self.clock;
        for i in 0..k {
            let t_tok = t0 + res.dec.dispatch_time + (i + 1) as f64 * res.t_decode_step;
            for &id in &decode {
                let done = self
                    .running
                    .iter()
                    .find(|r| r.id == id)
                    .map(|r| r.phase != Phase::Decode)
                    .unwrap_or(true);
                if done {
                    continue; // finished mid-look-ahead: slot wasted
                }
                if self.kv_append_or_preempt(id, 1) {
                    if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                        r.advance_decode(t_tok.min(t_end));
                    }
                }
            }
        }

        // Prefill side advances at the synchronization point.
        for c in &prefill {
            if self.kv_append_or_preempt(c.id, c.tokens) {
                if let Some(pos) = self.running.iter().position(|r| r.id == c.id) {
                    let r = &mut self.running[pos];
                    r.advance_prefill(c.tokens);
                    if r.phase == Phase::Decode {
                        let id = r.id;
                        if self.kv_append_or_preempt(id, 1) {
                            if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
                                r.advance_decode(t_end);
                            }
                        }
                    }
                }
            }
        }

        // Utilization: weight each side by its busy time over its SM share.
        let f_dec = plan.decode.fraction(&self.cfg.gpu);
        let f_pre = plan.prefill.fraction(&self.cfg.gpu);
        let busy_dec = (k as f64 * res.t_decode_step).min(res.span);
        let busy_pre = res.t_prefill.min(res.span);
        let sm = f_dec * res.dec.sm_util * busy_dec / res.span
            + f_pre * res.pre.sm_util * busy_pre / res.span;
        let hbm =
            res.dec.hbm_util * busy_dec / res.span + res.pre.hbm_util * busy_pre / res.span;
        self.metrics.record_util(res.span, sm, hbm);
        self.metrics.busy_time += res.span;
        self.metrics.iterations += 1;
        self.metrics.spatial_iterations += 1;
        if self.log_events {
            self.events.push(IterEvent {
                t_start: self.clock,
                duration: dur,
                kind: IterKind::Spatial {
                    decode_tpcs: plan.decode.n_tpcs,
                    prefill_tpcs: plan.prefill.n_tpcs,
                    k,
                },
                n_decode: decode.len() as u32,
                prefill_tokens: pre_tokens,
                sched_s,
                sm_util: sm,
                hbm_util: hbm,
            });
        }
        self.clock = t_end;
        self.last_active = t_end;
        self.retire_finished();
    }

    pub(crate) fn retire_finished(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].phase == Phase::Finished {
                let r = self.running.swap_remove(i);
                let _ = self.kv.release(r.id);
                self.metrics.record_finished(&r);
                self.finished.push(r);
            } else {
                i += 1;
            }
        }
    }

    /// Engine-level invariants, used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        for r in &self.running {
            if r.phase == Phase::Finished {
                return Err(format!("finished request {} still running", r.id));
            }
            if r.generated > r.output_len {
                return Err(format!("request {} over-generated", r.id));
            }
        }
        for r in &self.finished {
            if r.generated != r.output_len || r.phase != Phase::Finished {
                return Err(format!("request {} retired unfinished", r.id));
            }
            if r.token_times.windows(2).any(|w| w[1] < w[0]) {
                return Err(format!("request {} token times not monotone", r.id));
            }
            if let Some(t) = r.first_token_at {
                if t < r.arrival {
                    return Err(format!("request {} produced a token before arrival", r.id));
                }
            }
        }
        Ok(())
    }
}
