//! The execution-backend seam: *how* an [`IterationPlan`] actually runs.
//!
//! [`EngineCore`](super::EngineCore) turns a scheduler's plan into an
//! [`IterationBatch`] — request ids, chunk sizes, context lengths, and
//! (when serving real traffic) prompt token payloads — and hands it to an
//! [`ExecutionBackend`]. The backend executes it and reports timing; the
//! core does everything else (KV accounting, request state, metrics).
//!
//! Two implementations exist:
//!
//! - [`SimBackend`] wraps the roofline-calibrated
//!   [`GpuExecutor`](crate::sim::GpuExecutor): iteration latencies are
//!   *modelled*, tokens are synthetic. This is the evaluation path every
//!   bench and test runs.
//! - [`PjrtBackend`](crate::runtime::PjrtBackend) wraps the AOT-compiled
//!   [`TinyRuntime`](crate::runtime::TinyRuntime): iteration latencies
//!   are *measured wall clock*, tokens are real greedy argmax. It cannot
//!   partition SMs, so spatial plans degrade to aggregated execution
//!   (logged once by the core).
//!
//! The trait is the seam the unified serving front-end
//! ([`crate::server`]) builds on: one request lifecycle, pluggable
//! execution.
//!
//! [`IterationPlan`]: crate::sched::IterationPlan

use crate::hw::PartitionPlan;
use crate::model::AttnShape;
use crate::request::RequestId;
use crate::roofline::BatchShape;
use crate::sim::{DispatchMode, ExecResult, GpuExecutor, SpatialResult};

/// One decode-side entry of an iteration: the request generates exactly
/// one token per decode step at `context_len` tokens of KV context.
#[derive(Debug, Clone, Copy)]
pub struct DecodeSlot {
    pub id: RequestId,
    pub context_len: u64,
}

/// One prefill-side entry: `chunk_tokens` prompt tokens of request `id`
/// processed this iteration, on top of `context_len` cached tokens.
#[derive(Debug, Clone, Copy)]
pub struct PrefillSlice<'a> {
    pub id: RequestId,
    pub chunk_tokens: u64,
    pub context_len: u64,
    /// This chunk finishes the prompt (the forward's last logits yield
    /// the first output token).
    pub completes_prompt: bool,
    /// The actual prompt token ids, when the request carries a payload
    /// (serving path). Simulated requests have none.
    pub prompt: Option<&'a [i32]>,
}

/// Everything a backend needs to execute one iteration.
pub struct IterationBatch<'a> {
    pub decode: Vec<DecodeSlot>,
    pub prefill: Vec<PrefillSlice<'a>>,
    /// Attention shapes of the decode side (one q=1 row per slot).
    pub dec_shape: BatchShape,
    /// Attention shapes of the prefill side (one row per chunk).
    pub pre_shape: BatchShape,
}

impl IterationBatch<'_> {
    pub fn is_empty(&self) -> bool {
        self.decode.is_empty() && self.prefill.is_empty()
    }
}

impl IterationBatch<'static> {
    /// A decode-only batch (no prefill side) — cluster decode workers
    /// batch transferred-KV requests this way.
    pub fn decode_only(decode: Vec<DecodeSlot>) -> IterationBatch<'static> {
        let dec_shape = BatchShape::from_shapes(
            decode
                .iter()
                .map(|d| AttnShape {
                    q: 1,
                    c: d.context_len,
                })
                .collect(),
        );
        IterationBatch {
            decode,
            prefill: Vec::new(),
            dec_shape,
            pre_shape: BatchShape::from_shapes(Vec::new()),
        }
    }
}

/// Executes iteration batches and reports per-request progress.
///
/// Contract:
/// - `run_aggregated` / `run_spatial` are called once per executed
///   iteration, after the scheduler planned it and before the core
///   updates KV/request state from the returned timing.
/// - `pop_token(id, index)` is called by streaming front-ends once per
///   produced output token, in production order per request; `index` is
///   the token's position in the request's output. Backends with real
///   runtimes return the argmax token; the default synthesizes a
///   deterministic placeholder.
/// - `release(id)` is called when a request leaves the engine without
///   finishing (preemption, drop, cancel) so backend-side state (real KV
///   slots, pending tokens) can be reclaimed. Front-ends also call it
///   after a finished request's stream is fully drained.
pub trait ExecutionBackend {
    fn name(&self) -> &'static str;

    /// Can this backend execute a [`Spatial`](crate::sched::IterationPlan)
    /// plan natively? When false the core degrades spatial plans to
    /// aggregated execution and logs a warning once.
    fn supports_spatial(&self) -> bool {
        true
    }

    /// Hard bound on a request's total context (prompt + generated
    /// tokens), when the backend has one — compiled runtimes do; the
    /// analytical simulator does not (KV capacity governs instead).
    /// Front-ends reject submissions that could exceed it.
    fn max_context(&self) -> Option<u64> {
        None
    }

    /// Execute decode + prefill as one synchronous batch on `sms` SMs.
    fn run_aggregated(
        &mut self,
        batch: &IterationBatch<'_>,
        sms: u32,
        mode: DispatchMode,
    ) -> ExecResult;

    /// Execute the batch spatially multiplexed per `plan`. Only called
    /// when [`supports_spatial`](Self::supports_spatial) returns true.
    fn run_spatial(&mut self, batch: &IterationBatch<'_>, plan: &PartitionPlan) -> SpatialResult;

    /// The value of request `id`'s output token number `index`.
    fn pop_token(&mut self, id: RequestId, index: u64) -> i32 {
        // Deterministic synthetic stream: stable across recompute
        // preemption replays (depends only on identity and position).
        (((id.wrapping_mul(0x9E37_79B9) ^ index) & 0x7FFF) as i32).max(1)
    }

    /// Whether [`pop_token`](Self::pop_token) is a pure function of
    /// `(id, index)` — true for the synthetic default, false for real
    /// runtimes that queue argmax values on the device that produced
    /// them. Cluster topologies require this to stream tokens for
    /// requests in flight *between* workers (the producing worker has
    /// already released them); the cluster asserts it when pumping.
    fn deterministic_tokens(&self) -> bool {
        true
    }

    /// Reclaim backend-side state for `id` (slots, pending tokens).
    fn release(&mut self, _id: RequestId) {}

    /// Prefill→decode KV handoff latency for `tokens` cached tokens
    /// (disaggregated topologies).
    fn kv_transfer_time(&self, tokens: u64) -> f64;
}

/// The simulated backend: a thin adapter over [`GpuExecutor`].
pub struct SimBackend {
    exec: GpuExecutor,
}

impl SimBackend {
    pub fn new(exec: GpuExecutor) -> SimBackend {
        SimBackend { exec }
    }

    pub fn from_config(cfg: &crate::config::ServingConfig, seed: u64) -> SimBackend {
        SimBackend::new(GpuExecutor::new(cfg.model.clone(), cfg.gpu.clone(), cfg.tp, seed))
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run_aggregated(
        &mut self,
        batch: &IterationBatch<'_>,
        sms: u32,
        mode: DispatchMode,
    ) -> ExecResult {
        let mut all = batch.dec_shape.shapes.clone();
        all.extend(batch.pre_shape.shapes.iter().copied());
        let combined = BatchShape::from_shapes(all);
        self.exec.run(&combined, sms, mode, None)
    }

    fn run_spatial(&mut self, batch: &IterationBatch<'_>, plan: &PartitionPlan) -> SpatialResult {
        self.exec.run_spatial(&batch.dec_shape, &batch.pre_shape, plan)
    }

    fn kv_transfer_time(&self, tokens: u64) -> f64 {
        self.exec.kv_transfer_time(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::model::AttnShape;

    fn batch(n_dec: u64, pre_tokens: u64) -> IterationBatch<'static> {
        let decode: Vec<DecodeSlot> = (0..n_dec)
            .map(|i| DecodeSlot {
                id: i,
                context_len: 1024,
            })
            .collect();
        let prefill: Vec<PrefillSlice<'static>> = if pre_tokens > 0 {
            vec![PrefillSlice {
                id: 100,
                chunk_tokens: pre_tokens,
                context_len: 0,
                completes_prompt: true,
                prompt: None,
            }]
        } else {
            Vec::new()
        };
        let dec_shape = BatchShape::from_shapes(
            decode.iter().map(|d| AttnShape { q: 1, c: d.context_len }).collect(),
        );
        let pre_shape = BatchShape::from_shapes(
            prefill
                .iter()
                .map(|p| AttnShape {
                    q: p.chunk_tokens,
                    c: p.context_len,
                })
                .collect(),
        );
        IterationBatch {
            decode,
            prefill,
            dec_shape,
            pre_shape,
        }
    }

    fn sim() -> SimBackend {
        SimBackend::new(GpuExecutor::noiseless(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1))
    }

    #[test]
    fn sim_backend_matches_direct_executor() {
        let mut b = sim();
        let mut direct = GpuExecutor::noiseless(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1);
        let ib = batch(16, 2048);
        let via_backend = b.run_aggregated(&ib, 132, DispatchMode::Eager);
        let mut all = ib.dec_shape.shapes.clone();
        all.extend(ib.pre_shape.shapes.iter().copied());
        let expect = direct.run(&BatchShape::from_shapes(all), 132, DispatchMode::Eager, None);
        assert_eq!(via_backend.gpu_time, expect.gpu_time);
        assert_eq!(via_backend.dispatch_time, expect.dispatch_time);
    }

    #[test]
    fn sim_backend_supports_spatial() {
        assert!(sim().supports_spatial());
        assert_eq!(sim().name(), "sim");
    }

    #[test]
    fn default_tokens_are_deterministic_and_positive() {
        let mut b = sim();
        let t1 = b.pop_token(7, 3);
        let t2 = b.pop_token(7, 3);
        assert_eq!(t1, t2);
        assert!(t1 >= 1);
        // different positions give a stream, not a constant
        assert_ne!(b.pop_token(7, 0), b.pop_token(7, 1));
    }

    #[test]
    fn kv_transfer_time_delegates() {
        let b = sim();
        assert!(b.kv_transfer_time(8000) > 0.0);
    }
}
