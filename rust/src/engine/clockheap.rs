//! Indexed min-heap over per-worker clocks — the event queue behind
//! [`ClusterEngine`](super::ClusterEngine)'s discrete-event loop.
//!
//! The cluster used to pick the next worker with an O(N) scan per event
//! (`min_clock_worker`), which is the wrong shape for fleet-scale sweeps:
//! at N = 1000 every park nudge costs a full fleet scan. This heap makes
//! the pick O(1) and each clock mutation O(log N), while reproducing the
//! scan's selection *bit-exactly*:
//!
//! - ordering is [`f64::total_cmp`] on the key, then ascending worker
//!   index — exactly the "first of the equal minimums" that
//!   `Iterator::min_by` returns, so trajectories are byte-identical to
//!   the naive reference (property-tested in `tests/fleet_hotpath.rs`);
//! - [`shift_all`](MinClockHeap::shift_all) subtracts one common delta
//!   from every key *in place*. IEEE-754 subtraction of a common finite
//!   value is monotone (a ≤ b ⇒ a−x ≤ b−x), so the heap property is
//!   preserved without re-ordering — the epoch re-base keeps relative
//!   order bit-exact, which the live ≡ batch replay property relies on.

/// Indexed binary min-heap keyed by `f64` worker clocks. Worker indices
/// are dense `0..n`; `update` is O(log n), `peek`/`min_key` are O(1).
#[derive(Debug, Clone)]
pub struct MinClockHeap {
    /// Heap array of worker indices.
    heap: Vec<u32>,
    /// `pos[w]` = position of worker `w` in `heap`.
    pos: Vec<u32>,
    /// `keys[w]` = worker `w`'s clock.
    keys: Vec<f64>,
}

impl MinClockHeap {
    /// Heap over workers `0..n`, all with key 0.0. With equal keys the
    /// identity layout is already a valid heap with worker 0 at the root.
    pub fn new(n: usize) -> MinClockHeap {
        assert!(n <= u32::MAX as usize, "worker index space");
        MinClockHeap {
            heap: (0..n as u32).collect(),
            pos: (0..n as u32).collect(),
            keys: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The worker with the minimum key (ties: lowest index — identical to
    /// `min_by(total_cmp)` over worker order).
    pub fn peek(&self) -> usize {
        self.heap[0] as usize
    }

    /// The minimum key.
    pub fn min_key(&self) -> f64 {
        self.keys[self.heap[0] as usize]
    }

    /// Worker `w`'s current key.
    pub fn key(&self, w: usize) -> f64 {
        self.keys[w]
    }

    /// Set worker `w`'s key and restore heap order (sift whichever way).
    pub fn update(&mut self, w: usize, key: f64) {
        self.keys[w] = key;
        let at = self.pos[w] as usize;
        let up = self.sift_up(at);
        if up == at {
            self.sift_down(at);
        }
    }

    /// Subtract one common `delta` from every key, in place. Monotone in
    /// IEEE-754, so heap order is untouched (no sifting) and relative
    /// order across workers stays bit-exact — the epoch re-base contract.
    pub fn shift_all(&mut self, delta: f64) {
        for k in &mut self.keys {
            *k -= delta;
        }
    }

    /// Strict heap order: key, then worker index (total, NaN-safe).
    fn less(&self, a: u32, b: u32) -> bool {
        self.keys[a as usize]
            .total_cmp(&self.keys[b as usize])
            .then(a.cmp(&b))
            .is_lt()
    }

    fn sift_up(&mut self, mut at: usize) -> usize {
        while at > 0 {
            let parent = (at - 1) / 2;
            if self.less(self.heap[at], self.heap[parent]) {
                self.swap(at, parent);
                at = parent;
            } else {
                break;
            }
        }
        at
    }

    fn sift_down(&mut self, mut at: usize) {
        loop {
            let l = 2 * at + 1;
            if l >= self.heap.len() {
                break;
            }
            let r = l + 1;
            let child = if r < self.heap.len() && self.less(self.heap[r], self.heap[l]) {
                r
            } else {
                l
            };
            if self.less(self.heap[child], self.heap[at]) {
                self.swap(at, child);
                at = child;
            } else {
                break;
            }
        }
    }

    fn swap(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a] as usize] = a as u32;
        self.pos[self.heap[b] as usize] = b as u32;
    }

    /// Debug validation: heap order and pos/heap inverse mapping.
    #[cfg(test)]
    fn check(&self) {
        for (i, &w) in self.heap.iter().enumerate() {
            assert_eq!(self.pos[w as usize] as usize, i, "pos/heap mismatch");
            if i > 0 {
                let parent = self.heap[(i - 1) / 2];
                assert!(!self.less(w, parent), "heap order violated at {i}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference: the naive scan the heap replaces.
    fn naive_min(keys: &[f64]) -> usize {
        keys.iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap()
    }

    #[test]
    fn fresh_heap_picks_worker_zero() {
        let h = MinClockHeap::new(8);
        assert_eq!(h.peek(), 0);
        assert_eq!(h.min_key(), 0.0);
        assert_eq!(h.len(), 8);
        assert!(!h.is_empty());
    }

    #[test]
    fn update_tracks_minimum_and_ties_break_low_index() {
        let mut h = MinClockHeap::new(4);
        h.update(0, 5.0);
        h.update(1, 3.0);
        h.update(2, 3.0);
        h.update(3, 9.0);
        h.check();
        // Tie at 3.0: worker 1 (lower index) wins, like min_by.
        assert_eq!(h.peek(), 1);
        h.update(1, 10.0);
        h.check();
        assert_eq!(h.peek(), 2);
        h.update(3, 0.5);
        h.check();
        assert_eq!(h.peek(), 3);
        assert_eq!(h.min_key(), 0.5);
    }

    #[test]
    fn matches_naive_scan_under_random_updates() {
        use crate::util::proptest::check;
        check(64, |g| {
            let n = g.usize_range(1, 33);
            let mut h = MinClockHeap::new(n);
            let mut keys = vec![0.0f64; n];
            for _ in 0..g.usize_range(1, 200) {
                let w = g.usize_range(0, n - 1);
                // Quantized keys to force frequent ties.
                let k = g.u64_range(0, 20) as f64 * 0.25;
                h.update(w, k);
                keys[w] = k;
                if h.peek() != naive_min(&keys) {
                    return Err(format!(
                        "heap picked {} naive picked {} keys {keys:?}",
                        h.peek(),
                        naive_min(&keys)
                    ));
                }
                if h.min_key() != keys[h.peek()] {
                    return Err("min_key mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn shift_all_preserves_order_bit_exactly() {
        let mut h = MinClockHeap::new(5);
        for (w, k) in [(0, 7.25), (1, 3.5), (2, 3.5), (3, 12.0), (4, 3.75)] {
            h.update(w, k);
        }
        let order_before = h.peek();
        h.shift_all(3.5);
        h.check();
        assert_eq!(h.peek(), order_before);
        // x - x == +0.0 exactly in IEEE-754.
        assert_eq!(h.min_key(), 0.0);
        assert_eq!(h.key(4), 0.25);
    }

    #[test]
    fn nan_key_does_not_panic() {
        let mut h = MinClockHeap::new(3);
        h.update(1, f64::NAN);
        h.update(2, 1.0);
        h.check();
        // total_cmp sorts NaN above every finite value: never the pick.
        assert_eq!(h.peek(), 0);
    }
}
