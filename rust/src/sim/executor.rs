//! The simulated GPU executor.

use crate::config::{GpuSpec, ModelSpec};
use crate::hw::PartitionPlan;
use crate::model::{block_cost, classifier_cost, ops::allreduce_latency, OpCost, OpKind};
use crate::roofline::BatchShape;
use crate::util::rng::Rng;

/// How kernels reach the GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Individual CPU launches per kernel (prefill path: dynamic shapes
    /// prevent graph capture — §4.3).
    Eager,
    /// CUDA-Graph-style replay: one launch for the whole captured decode
    /// step (<0.5 ms — §4.3).
    Graph,
}

/// Outcome of executing one batch on one partition.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecResult {
    /// GPU busy time, seconds.
    pub gpu_time: f64,
    /// CPU dispatch time preceding GPU work, seconds.
    pub dispatch_time: f64,
    /// Achieved FLOP/s divided by partition peak (SM utilization proxy).
    pub sm_util: f64,
    /// Achieved bytes/s divided by device peak (HBM utilization proxy).
    pub hbm_util: f64,
    pub flops: f64,
    pub bytes: f64,
}

impl ExecResult {
    pub fn total(&self) -> f64 {
        self.gpu_time + self.dispatch_time
    }
}

/// Result of one spatially-multiplexed iteration.
#[derive(Debug, Clone, Copy)]
pub struct SpatialResult {
    /// Measured latency of a single decode step on its partition.
    pub t_decode_step: f64,
    /// Measured latency of the prefill span on its partition.
    pub t_prefill: f64,
    /// Wall time of the iteration: max(k·t_d, t_p) + dispatch skew.
    pub span: f64,
    /// Decode-side idle fraction within the span (compute bubbles).
    pub decode_bubble: f64,
    /// Prefill-side idle fraction within the span.
    pub prefill_bubble: f64,
    /// Per-side execution details (utilization accounting).
    pub dec: ExecResult,
    pub pre: ExecResult,
}

/// Per-op-kind efficiency: achieved fraction of peak compute / bandwidth.
/// Calibrated to typical measured H100 kernel efficiencies (GEMM ~0.75–0.85
/// of dense peak, FA-3 prefill ~0.55–0.65, decode attention ~0.8 of
/// streaming bandwidth).
fn compute_eff(kind: OpKind) -> f64 {
    match kind {
        k if k.is_linear() => 0.80,
        OpKind::Attention => 0.60,
        OpKind::NormAct => 0.50,
        _ => 0.70,
    }
}

fn bandwidth_eff(kind: OpKind) -> f64 {
    match kind {
        k if k.is_linear() => 0.85,
        OpKind::Attention => 0.80,
        OpKind::NormAct => 0.90,
        _ => 0.80,
    }
}

/// Kernels launched per transformer layer on the eager path (qkv, rope,
/// attn, o-proj, norm ×2, gate-up, act, down, residual ×2, misc).
const KERNELS_PER_LAYER: f64 = 12.0;
/// CPU time per eager kernel launch (driver + python/runtime overhead;
/// calibrated so a 36-layer prefill dispatch lands in the "tens of ms"
/// regime the paper describes in §4.3).
const EAGER_LAUNCH_S: f64 = 2.5e-5;

/// The simulated device executor for one GPU group (TP counted inside).
#[derive(Debug, Clone)]
pub struct GpuExecutor {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    pub tp: u32,
    rng: Rng,
    /// Multiplicative execution noise sigma (0 disables).
    pub noise: f64,
    /// The *hardware's* bandwidth-scaling shape: more super-linear than
    /// the predictor's spec curve (k 0.12 vs 0.2), making the predictor
    /// conservative for bandwidth-bound decode on small partitions
    /// (paper Appendix A).
    hw_bw_k: f64,
}

impl GpuExecutor {
    pub fn new(model: ModelSpec, gpu: GpuSpec, tp: u32, seed: u64) -> GpuExecutor {
        GpuExecutor {
            model,
            gpu,
            tp,
            rng: Rng::new(seed ^ 0xE8EC),
            noise: 0.015,
            hw_bw_k: 0.12,
        }
    }

    /// Deterministic variant for calibration/unit tests.
    pub fn noiseless(model: ModelSpec, gpu: GpuSpec, tp: u32) -> GpuExecutor {
        let mut e = GpuExecutor::new(model, gpu, tp, 0);
        e.noise = 0.0;
        e
    }

    /// Hardware-achieved bandwidth for `sms` active SMs.
    fn hw_bw(&self, sms: u32) -> f64 {
        let s = sms.min(self.gpu.num_sms);
        if s == 0 {
            return 0.0;
        }
        let x = s as f64 / self.gpu.num_sms as f64;
        let k = self.hw_bw_k;
        self.gpu.hbm_bandwidth * x * (1.0 + k) / (x + k)
    }

    fn op_time(&self, op: &OpCost, pi: f64, bw: f64) -> f64 {
        let tc = op.flops as f64 / (pi * compute_eff(op.kind));
        let tm = op.bytes as f64 / (bw * bandwidth_eff(op.kind));
        tc.max(tm)
    }

    fn noise_factor(&mut self) -> f64 {
        if self.noise == 0.0 {
            1.0
        } else {
            (self.rng.normal(0.0, self.noise)).exp()
        }
    }

    /// Execute one model forward of `batch` on `sms` SMs. `bw_cap`, when
    /// set, caps this partition's achievable bandwidth (HBM contention
    /// from a concurrent partition).
    pub fn run(
        &mut self,
        batch: &BatchShape,
        sms: u32,
        mode: DispatchMode,
        bw_cap: Option<f64>,
    ) -> ExecResult {
        if batch.is_empty() {
            return ExecResult::default();
        }
        let pi = self.gpu.pi_sm(sms);
        let mut bw = self.hw_bw(sms);
        if let Some(cap) = bw_cap {
            bw = bw.min(cap);
        }
        if pi == 0.0 || bw == 0.0 {
            return ExecResult {
                gpu_time: f64::INFINITY,
                ..Default::default()
            };
        }
        let cost = block_cost(&self.model, batch.n_tokens, &batch.shapes, self.tp);
        let mut t_block = 0.0;
        let mut flops = 0.0;
        let mut bytes = 0.0;
        for op in cost.token_ops.iter().chain(cost.attn_ops.iter()) {
            t_block += self.op_time(op, pi, bw);
            flops += op.flops as f64;
            bytes += op.bytes as f64;
        }
        if self.tp > 1 {
            t_block += allreduce_latency(
                self.tp,
                cost.allreduce_bytes,
                self.gpu.allreduce_alpha,
                self.gpu.nvlink_bandwidth,
                pi,
            );
        }
        let l = self.model.layers as f64;
        let cls = classifier_cost(&self.model, batch.n_seqs, self.tp);
        let t_cls = self.op_time(&cls, pi, bw);
        flops = flops * l + cls.flops as f64;
        bytes = bytes * l + cls.bytes as f64;

        let gpu_time = (l * t_block + t_cls) * self.noise_factor();
        let dispatch_time = match mode {
            DispatchMode::Eager => (l * KERNELS_PER_LAYER + 1.0) * EAGER_LAUNCH_S,
            DispatchMode::Graph => self.gpu.graph_launch_overhead,
        };
        ExecResult {
            gpu_time,
            dispatch_time,
            sm_util: (flops / gpu_time) / pi.max(1.0),
            hbm_util: (bytes / gpu_time) / self.gpu.hbm_bandwidth,
            flops,
            bytes,
        }
    }

    /// Execute a spatially-multiplexed iteration per §4.3: `k` look-ahead
    /// decode steps on the decode partition (graph-dispatched,
    /// launched first) concurrently with one prefill span on the prefill
    /// partition (eager-dispatched). Returns measured per-side latencies
    /// and the synchronization span.
    pub fn run_spatial(
        &mut self,
        decode: &BatchShape,
        prefill: &BatchShape,
        plan: &PartitionPlan,
    ) -> SpatialResult {
        let sd = plan.decode.num_sms(&self.gpu);
        let sp = plan.prefill.num_sms(&self.gpu);
        debug_assert!(!plan.decode.overlaps(&plan.prefill));

        // HBM contention: isolated-curve demands may exceed device peak;
        // scale each side's achievable bandwidth proportionally.
        let bd = self.hw_bw(sd);
        let bp = self.hw_bw(sp);
        let total = bd + bp;
        let peak = self.gpu.hbm_bandwidth;
        let (cap_d, cap_p) = if total > peak {
            (bd * peak / total, bp * peak / total)
        } else {
            (bd, bp)
        };

        // Decode launches first (graph replay, negligible CPU cost), so
        // prefill's eager dispatch does not stall it (§4.3 / Fig. 5).
        let dec_step = self.run(decode, sd, DispatchMode::Graph, Some(cap_d));
        let pre = self.run(prefill, sp, DispatchMode::Eager, Some(cap_p));

        let k = plan.k.max(1) as f64;
        // k decode graphs replay back-to-back without CPU sync; the first
        // graph launch is the only dispatch on the critical path.
        let t_dec_side = k * dec_step.gpu_time + dec_step.dispatch_time;
        // Prefill pays its eager dispatch (overlapped with decode's GPU
        // execution, but serial on its own partition's start).
        let t_pre_side = pre.gpu_time + pre.dispatch_time;
        let span = t_dec_side.max(t_pre_side);
        SpatialResult {
            t_decode_step: dec_step.gpu_time,
            t_prefill: pre.gpu_time,
            span,
            decode_bubble: if span > 0.0 { 1.0 - t_dec_side / span } else { 0.0 },
            prefill_bubble: if span > 0.0 { 1.0 - t_pre_side / span } else { 0.0 },
            dec: dec_step,
            pre,
        }
    }

    /// KV-cache transfer time for disaggregated prefill→decode handoff:
    /// `tokens` tokens of cache moved over NVLink P2P.
    pub fn kv_transfer_time(&self, tokens: u64) -> f64 {
        let bytes = tokens * self.model.kv_bytes_per_token();
        20e-6 + bytes as f64 / (0.8 * self.gpu.nvlink_bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::model::AttnShape;
    use crate::roofline::Predictor;

    fn exec() -> GpuExecutor {
        GpuExecutor::noiseless(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1)
    }

    fn prefill(tokens: u64) -> BatchShape {
        BatchShape::from_shapes(vec![AttnShape { q: tokens, c: 0 }])
    }

    fn decode(n: u64, ctx: u64) -> BatchShape {
        BatchShape::from_shapes((0..n).map(|_| AttnShape { q: 1, c: ctx }).collect())
    }

    #[test]
    fn executor_slower_than_ideal_predictor() {
        let mut e = exec();
        let p = Predictor::new(e.model.clone(), e.gpu.clone(), 1);
        for b in [prefill(2048), prefill(8192), decode(32, 4096)] {
            let t_hw = e.run(&b, 132, DispatchMode::Eager, None).gpu_time;
            let t_pred = p.predict_total(&b, 132);
            assert!(
                t_hw > t_pred,
                "hardware (w/ efficiencies) must be slower than ideal roofline"
            );
        }
    }

    #[test]
    fn predictor_conservative_for_decode_on_small_partitions() {
        // Appendix A: at small TPC counts the roofline model OVERestimates
        // decode latency (pred > measured) because the hardware's
        // bandwidth curve is more super-linear than profiled.
        let mut e = exec();
        let p = Predictor::new(e.model.clone(), e.gpu.clone(), 1);
        let b = decode(16, 8192);
        let small_sms = 12; // 6 TPCs
        let t_hw = e.run(&b, small_sms, DispatchMode::Graph, None).gpu_time;
        let t_pred = p.predict_total(&b, small_sms);
        assert!(
            t_pred > t_hw,
            "pred {t_pred} should exceed measured {t_hw} at small partitions"
        );
    }

    #[test]
    fn prefill_8k_budget_exceeds_180ms() {
        // Fig. 1(b): end-to-end prefill under the 8192 budget consistently
        // exceeds 180 ms on the real system.
        let mut e = exec();
        let r = e.run(&prefill(8192), 132, DispatchMode::Eager, None);
        assert!(r.total() > 0.15, "t={}", r.total());
        assert!(r.total() < 0.6, "t={}", r.total());
    }

    #[test]
    fn decode_context_sweep_4x(){
        // Fig. 1(c): decode-only batches with budget 8, >4x latency spread
        // as context grows 1K -> 32K.
        let mut e = exec();
        let t_short = e.run(&decode(8, 1024), 132, DispatchMode::Graph, None).gpu_time;
        let t_long = e.run(&decode(8, 32768), 132, DispatchMode::Graph, None).gpu_time;
        assert!(t_long / t_short > 3.0, "ratio={}", t_long / t_short);
    }

    #[test]
    fn phase_utilization_asymmetry() {
        // Fig. 3(b,c): prefill saturates SMs, decode saturates HBM.
        let mut e = exec();
        let pre = e.run(&prefill(8192), 132, DispatchMode::Eager, None);
        let dec = e.run(&decode(64, 8192), 132, DispatchMode::Graph, None);
        assert!(pre.sm_util > 0.5, "prefill sm_util={}", pre.sm_util);
        assert!(pre.hbm_util < 0.4, "prefill hbm_util={}", pre.hbm_util);
        assert!(dec.hbm_util > 0.5, "decode hbm_util={}", dec.hbm_util);
        assert!(dec.sm_util < 0.2, "decode sm_util={}", dec.sm_util);
    }

    #[test]
    fn graph_dispatch_cheaper_than_eager() {
        let mut e = exec();
        let b = decode(16, 2048);
        let eager = e.run(&b, 132, DispatchMode::Eager, None);
        let graph = e.run(&b, 132, DispatchMode::Graph, None);
        assert!(eager.dispatch_time > 5.0 * graph.dispatch_time);
        // eager prefill dispatch lands in the ~10ms regime
        assert!((0.005..0.05).contains(&eager.dispatch_time));
    }

    #[test]
    fn spatial_iteration_isolates_decode() {
        let mut e = exec();
        let dec = decode(32, 4096);
        let pre = prefill(8192);
        let plan = PartitionPlan::split(&e.gpu, 18, 5);
        let r = e.run_spatial(&dec, &pre, &plan);
        // decode step on 18 TPCs must still be fast (bandwidth-bound,
        // super-linear curve)
        let full = e.run(&dec, 132, DispatchMode::Graph, None).gpu_time;
        assert!(r.t_decode_step < 3.0 * full);
        assert!(r.span >= r.t_prefill);
        // bubbles sum: exactly one side is idle at any given tail
        assert!(r.decode_bubble >= 0.0 && r.prefill_bubble >= 0.0);
        assert!(r.decode_bubble == 0.0 || r.prefill_bubble == 0.0);
    }

    #[test]
    fn hbm_contention_slows_both_sides() {
        let mut e = exec();
        let dec = decode(64, 16384); // very bandwidth hungry
        let pre = prefill(8192);
        let plan = PartitionPlan::split(&e.gpu, 33, 1);
        let spatial = e.run_spatial(&dec, &pre, &plan);
        let iso_dec = e
            .run(&dec, 66, DispatchMode::Graph, None)
            .gpu_time;
        assert!(
            spatial.t_decode_step >= iso_dec * 0.99,
            "contention cannot speed decode up"
        );
    }

    #[test]
    fn kv_transfer_time_scales() {
        let e = exec();
        let t1 = e.kv_transfer_time(1000);
        let t2 = e.kv_transfer_time(100_000);
        assert!(t2 > 10.0 * t1 * 0.5);
        // 8000-token Qwen3-8B cache ≈ 1.18 GB → ~3.3ms over 360GB/s
        let t8k = e.kv_transfer_time(8000);
        assert!((0.002..0.006).contains(&t8k), "t8k={t8k}");
    }

    #[test]
    fn noise_reproducible_by_seed() {
        let mut a = GpuExecutor::new(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1, 7);
        let mut b = GpuExecutor::new(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1, 7);
        let batch = prefill(1024);
        assert_eq!(
            a.run(&batch, 132, DispatchMode::Eager, None).gpu_time,
            b.run(&batch, 132, DispatchMode::Eager, None).gpu_time
        );
    }
}
