//! Discrete-event GPU execution substrate.
//!
//! The engines (aggregated, duet, disaggregated) advance a virtual clock;
//! every scheduled iteration asks [`GpuExecutor`] how long it takes. The
//! executor shares the operator formulas of [`crate::model`] with the
//! roofline predictor but models what the predictor deliberately ignores:
//!
//! - per-operator efficiency (achieved vs peak FLOPs / bandwidth),
//! - CPU kernel-dispatch overhead (eager per-kernel launches vs
//!   CUDA-Graph-style whole-batch replay),
//! - a slightly more super-linear bandwidth curve than the predictor's —
//!   the mechanism behind the paper's "intentionally conservative" decode
//!   estimates at small TPC counts (Appendix A, Fig. 8),
//! - HBM contention between two spatially-multiplexed partitions,
//! - small multiplicative execution noise.

pub mod executor;

pub use executor::{DispatchMode, ExecResult, GpuExecutor, SpatialResult};
