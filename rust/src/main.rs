//! DuetServe launcher.
//!
//! Subcommands:
//!   serve      — run a serving experiment (policy x workload); with
//!                `--backend` the workload goes through the unified
//!                streaming front-end instead of the batch simulator
//!   serve-http — expose the same front-end over an OpenAI-compatible
//!                HTTP/SSE endpoint (POST /v1/completions, GET /healthz,
//!                GET /metrics, POST /shutdown)
//!   traces     — print Table-1 statistics of the calibrated traces
//!   partition  — inspect the Algorithm-1 optimizer for a batch shape
//!   plan       — capacity planning: sweep topology x replicas x router x
//!                scheduler against a declared per-class traffic-and-SLO
//!                mix; prints the cheapest config attaining every target
//!   e2e        — serve the real AOT-compiled tiny model via PJRT
//!                (unified front-end + PjrtBackend)
//!   config     — dump the effective serving configuration
//!
//! Examples:
//!   duetserve serve --policy duet --trace azure-conv --qps 10 --n 300
//!   duetserve serve --policy vllm --isl 8000 --osl 200 --qps 6 --n 100
//!   duetserve serve --backend sim --policy duet --n 50 --qps 8
//!   duetserve serve-http --addr 127.0.0.1:8080 --backend sim --queue-cap 256
//!   duetserve partition --decode 64 --ctx 8192 --prefill 8192
//!   duetserve plan --mix interactive --n 120
//!   duetserve e2e --requests 16 --max-new 24

use std::time::Duration;

use duetserve::cli::Args;
use duetserve::config::{ModelSpec, Policy, ServingConfig};
use duetserve::engine::{
    engine_for, router_by_name, ClusterEngine, DisaggEngine, PlannerMode, ReplicatedEngine,
};
use duetserve::metrics::Report;
use duetserve::model::AttnShape;
use duetserve::request::{Request, SloClass};
use duetserve::roofline::{BatchShape, Predictor};
use duetserve::runtime::{artifacts, PjrtBackend};
use duetserve::sched::{optimize_partition, scheduler_for};
use duetserve::server::http::{
    HttpConfig, HttpServer, DEFAULT_IDLE_TIMEOUT, DEFAULT_MAX_BODY, DEFAULT_MAX_CONNS,
    DEFAULT_POOL_WORKERS,
};
use duetserve::server::{Server, ServerCore, ShardedServer, SubmitOptions, DEFAULT_QUEUE_DEPTH};
use duetserve::util::tablefmt::Table;
use duetserve::workload::sessions::{session_workload, SessionProfile};
use duetserve::workload::synthetic::fixed_workload;
use duetserve::workload::traces::{generate, trace_by_name, TraceKind};
use duetserve::workload::Workload;

fn policy_by_name(name: &str) -> Option<Policy> {
    match name.to_ascii_lowercase().as_str() {
        "vllm" => Some(Policy::VllmChunked),
        "sglang" | "sglang-default" => Some(Policy::SglangDefault),
        "sglang-chunked" => Some(Policy::SglangChunked),
        "duet" | "duetserve" => Some(Policy::Duet),
        "dynamo" | "disagg" => Some(Policy::DisaggPD {
            prefill_gpus: 1,
            decode_gpus: 1,
        }),
        _ => None,
    }
}

fn build_config(args: &Args) -> ServingConfig {
    let model =
        ModelSpec::by_name(&args.str_or("model", "qwen3-8b")).unwrap_or_else(ModelSpec::qwen3_8b);
    let tp = args.u32_or("tp", 1);
    let mut cfg = ServingConfig::default_8b().with_model(model, tp);
    cfg.token_budget = args.u32_or("budget", cfg.token_budget);
    cfg.tbt_slo = args.f64_or("tbt-slo", cfg.tbt_slo);
    cfg.max_batch = args.u32_or("max-batch", cfg.max_batch);
    cfg.policy = policy_by_name(&args.str_or("policy", "duet")).unwrap_or(Policy::Duet);
    // Hidden testability knob: shrink the per-epoch divergence horizon so
    // CI soak runs can drive a server across several engine-clock epochs
    // without simulating 3e4 engine-seconds per epoch. Not part of the
    // documented surface; production deployments keep the default.
    cfg.max_engine_time = args.f64_or("max-engine-time", cfg.max_engine_time);
    if cfg.max_engine_time.is_nan() || cfg.max_engine_time <= 0.0 {
        eprintln!("error: --max-engine-time must be a positive number of engine-seconds");
        std::process::exit(2);
    }
    cfg.prefix_cache = args.flag("prefix-cache");
    cfg
}

/// Split a `--replicas` worker budget into (prefill, decode) roles for
/// `--topology disagg`. Callers reject `replicas < 2` first.
fn disagg_split(replicas: u32) -> (u32, u32) {
    let p = (replicas / 2).max(1);
    (p, replicas - p)
}

/// Default routing policy per topology, matching the engine defaults
/// (`ReplicatedEngine` fronts replicas with round-robin; `DisaggEngine`
/// approximates the shared prefill queue with least-outstanding) so the
/// batch and `--backend` front-end paths serve identical configurations.
/// With the elastic planner the fleet becomes role-heterogeneous at
/// runtime, so the conditional prefill-length router is the natural
/// default — it degrades to least-outstanding on a homogeneous board.
fn default_router(topology: &str, planner: PlannerMode) -> &'static str {
    if planner == PlannerMode::Elastic {
        "conditional"
    } else if topology == "disagg" {
        "least-outstanding"
    } else {
        "round-robin"
    }
}

/// Arm the role planner on a worker cluster per the `--planner` flags.
/// A no-op when the planner is off, preserving the legacy trajectory
/// byte-for-byte.
fn apply_planner(
    e: &mut ClusterEngine,
    planner: PlannerMode,
    interval: Option<f64>,
    reconfig: Option<f64>,
) {
    if planner == PlannerMode::Off {
        return;
    }
    if let Some(s) = reconfig {
        e.reconfig_s = s;
    }
    e.set_planner(planner);
    if let Some(s) = interval {
        e.set_planner_interval(s);
    }
}

fn build_workload(args: &Args, qps: f64, seed: u64) -> Workload {
    let n = args.usize_or("n", 200);
    if args.str_or("workload", "") == "sessions" {
        let mix = SessionProfile::default_mix();
        let p = SessionProfile {
            sessions: args.usize_or("sessions", mix.sessions),
            turns: args.usize_or("turns", mix.turns),
            system_tokens: args.usize_or("system-tokens", mix.system_tokens as usize) as u64,
            user_tokens: args.usize_or("user-tokens", mix.user_tokens as usize) as u64,
            output_tokens: args.usize_or("osl", mix.output_tokens as usize) as u64,
            tenants: args.usize_or("tenants", mix.tenants),
            session_qps: qps,
            mean_think_s: args.f64_or("think", mix.mean_think_s),
        };
        return session_workload(&p, seed);
    }
    if let Some(kind) = args.get("trace").and_then(trace_by_name) {
        generate(kind, Some(n), qps, seed)
    } else {
        let isl = args.usize_or("isl", 4096) as u64;
        let osl = args.usize_or("osl", 128) as u64;
        fixed_workload(n, isl, osl, qps, seed)
    }
}

/// Worker-fleet options shared by `serve` and `serve-http`.
struct FleetOpts {
    replicas: u32,
    router: Option<String>,
    topology: String,
    planner: PlannerMode,
    planner_interval: Option<f64>,
    reconfig_s: Option<f64>,
}

fn parse_fleet_opts(args: &Args) -> FleetOpts {
    let replicas = args.u32_or("replicas", 1);
    if replicas == 0 {
        eprintln!("error: --replicas must be >= 1");
        std::process::exit(2);
    }
    let router = match args.one_of(
        "router",
        &[
            "round-robin",
            "rr",
            "least-loaded",
            "least-outstanding",
            "ll",
            "kv-pressure",
            "kv",
            "kv-overlap",
            "overlap",
            "conditional",
            "cond",
        ],
    ) {
        Ok(choice) => choice.map(str::to_string),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let topology = match args.one_of("topology", &["unified", "disagg"]) {
        Ok(choice) => choice.unwrap_or("unified").to_string(),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if topology == "disagg" && replicas < 2 {
        eprintln!(
            "error: --topology disagg needs at least one prefill and one decode \
             worker; pass --replicas 2 or more"
        );
        std::process::exit(2);
    }
    let planner = match args.one_of("planner", &["elastic", "static", "off"]) {
        Ok(choice) => PlannerMode::from_name(choice.unwrap_or("off")).unwrap(),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if planner != PlannerMode::Off && replicas < 2 {
        eprintln!(
            "error: --planner {} needs a worker fleet to re-role; \
             pass --replicas 2 or more",
            planner.name()
        );
        std::process::exit(2);
    }
    let seconds_opt = |key: &str| -> Option<f64> {
        args.get(key).map(|v| match v.parse::<f64>() {
            Ok(s) if s > 0.0 && s.is_finite() => s,
            _ => {
                eprintln!("error: --{key} must be a positive number of seconds");
                std::process::exit(2);
            }
        })
    };
    FleetOpts {
        replicas,
        router,
        topology,
        planner,
        planner_interval: seconds_opt("planner-interval"),
        reconfig_s: seconds_opt("reconfig-s"),
    }
}

/// The pjrt backend owns one real device: reject fleet flags with it.
fn validate_backend_fleet(backend: &str, fleet: &FleetOpts) {
    if backend == "pjrt-stub"
        && (fleet.replicas > 1 || fleet.topology == "disagg" || fleet.router.is_some())
    {
        eprintln!(
            "error: --replicas/--router/--topology need simulated workers; \
             the pjrt backend owns one real device (use --backend sim)"
        );
        std::process::exit(2);
    }
}

fn cmd_serve(args: &Args) {
    let cfg = build_config(args);
    let qps = args.f64_or("qps", 8.0);
    let seed = args.usize_or("seed", 1) as u64;
    let fleet = parse_fleet_opts(args);
    let backend = match args.one_of("backend", &["sim", "pjrt-stub"]) {
        Ok(choice) => choice.map(str::to_string),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Some(kind) = &backend {
        validate_backend_fleet(kind, &fleet);
    }
    let queue_cap = match args.usize_opt("queue-cap") {
        Ok(q) => q,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let w = build_workload(args, qps, seed);
    if let Some(kind) = backend {
        cmd_serve_front(&kind, cfg, w, qps, seed, &fleet, queue_cap);
        return;
    }
    if queue_cap.is_some() {
        println!(
            "note: --queue-cap applies to the streaming front-end \
             (serve --backend ... / serve-http); the batch simulator has \
             no submission queue"
        );
    }
    let FleetOpts {
        replicas,
        router,
        topology,
        planner,
        planner_interval,
        reconfig_s,
    } = fleet;
    println!(
        "serving {} requests ({}) with {} (TP={})",
        w.requests.len(),
        w.name,
        cfg.policy.name(),
        cfg.tp
    );
    if planner != PlannerMode::Off {
        println!("planner: {} role planning", planner.name());
    }
    let prefix_cache = cfg.prefix_cache;
    let rep = if topology == "disagg" {
        // Explicit --topology disagg: split the --replicas worker budget
        // into prefill and decode roles. This wins over the policy's own
        // topology (--policy dynamo without --topology keeps its
        // configured P/D counts), matching the --backend front-end path.
        let (p, d) = disagg_split(replicas);
        let mut e = ClusterEngine::disagg(
            cfg.clone(),
            p,
            d,
            seed,
            router_by_name(
                router
                    .as_deref()
                    .unwrap_or(default_router(&topology, planner)),
            )
            .unwrap(),
        );
        apply_planner(&mut e, planner, planner_interval, reconfig_s);
        println!("cluster: {p}P+{d}D disaggregated, {} routing", e.router_name());
        e.run(w)
    } else {
        match cfg.policy {
            Policy::DisaggPD {
                prefill_gpus,
                decode_gpus,
            } => {
                if replicas > 1 {
                    eprintln!("note: --replicas is ignored for dynamo (topology is {prefill_gpus}P+{decode_gpus}D)");
                }
                let mut e = DisaggEngine::new(cfg.clone(), prefill_gpus, decode_gpus, seed);
                if let Some(name) = &router {
                    e.set_router(router_by_name(name).unwrap());
                }
                apply_planner(&mut e, planner, planner_interval, reconfig_s);
                e.run(w)
            }
            _ if replicas > 1 || router.is_some() => {
                let mut e = ReplicatedEngine::new(cfg.clone(), replicas, seed);
                let router_name = router
                    .clone()
                    .unwrap_or_else(|| default_router(&topology, planner).to_string());
                e.set_router(router_by_name(&router_name).unwrap());
                apply_planner(&mut e, planner, planner_interval, reconfig_s);
                println!("cluster: {replicas} replicas, {} routing", e.router_name());
                e.run(w)
            }
            _ => {
                let mut e = engine_for(cfg, seed);
                let rep = e.run(w);
                if e.preemptions > 0 || e.dropped > 0 {
                    println!("preemptions: {}, dropped: {}", e.preemptions, e.dropped);
                }
                rep
            }
        }
    };
    if prefix_cache {
        println!(
            "prefix cache: {} hits, {} cached tokens, {} evictions",
            rep.prefix_hits, rep.prefix_cached_tokens, rep.prefix_evictions
        );
    }
    let mut t = Table::new(Report::header());
    t.row(rep.row(qps));
    t.print();
}

/// Start the threaded streaming front-end (`server::Server`) over the
/// requested backend and worker fleet — shared by `serve --backend` and
/// `serve-http`.
fn start_front_server(
    kind: &str,
    cfg: ServingConfig,
    seed: u64,
    fleet: &FleetOpts,
    depth: usize,
) -> anyhow::Result<Server> {
    let multi = fleet.replicas > 1
        || fleet.router.is_some()
        || fleet.topology == "disagg"
        || fleet.planner != PlannerMode::Off;
    match kind {
        "sim" if multi => {
            let replicas = fleet.replicas;
            let router_name = fleet
                .router
                .clone()
                .unwrap_or_else(|| default_router(&fleet.topology, fleet.planner).to_string());
            let topo = fleet.topology.clone();
            let (planner, p_iv, p_rs) = (fleet.planner, fleet.planner_interval, fleet.reconfig_s);
            println!(
                "front-end cluster: {replicas} sim workers ({topo}), {router_name} routing{}",
                if planner == PlannerMode::Off {
                    String::new()
                } else {
                    format!(", {} planner", planner.name())
                }
            );
            Server::start(move || {
                let r = router_by_name(&router_name)
                    .ok_or_else(|| anyhow::anyhow!("unknown router `{router_name}`"))?;
                let core = if planner != PlannerMode::Off {
                    // A planned fleet needs the raw cluster handle so the
                    // role planner can be armed before serving starts.
                    let mut cluster = if topo == "disagg" {
                        let (p, d) = disagg_split(replicas);
                        ClusterEngine::disagg(cfg, p, d, seed, r)
                    } else {
                        ClusterEngine::replicated(cfg, replicas, seed, r)
                    };
                    apply_planner(&mut cluster, planner, p_iv, p_rs);
                    ServerCore::sim_cluster(cluster)
                } else if topo == "disagg" {
                    let (p, d) = disagg_split(replicas);
                    ServerCore::sim_disagg(cfg, p, d, seed, r)
                } else {
                    ServerCore::sim_replicated(cfg, replicas, seed, r)
                };
                Ok(core.with_queue_depth(depth))
            })
        }
        "sim" => Server::start(move || Ok(ServerCore::sim(cfg, seed).with_queue_depth(depth))),
        "pjrt-stub" => Server::start(move || {
            let backend = PjrtBackend::load_default()?;
            let tuned = backend.tune_config(cfg);
            let scheduler = scheduler_for(&tuned);
            Ok(ServerCore::new(tuned, scheduler, Box::new(backend)).with_queue_depth(depth))
        }),
        _ => unreachable!("validated by one_of"),
    }
}

/// Start `shards` independent engine shards behind one submit surface
/// (`serve-http --shards N`). Each shard is a full front-end server —
/// its own topology slice (replicas/topology flags apply *per shard*)
/// and engine thread — with submissions routed across shards through
/// the same `Router` seam the cluster uses, against each shard's live
/// load board. Request ids are strided so they stay globally unique.
fn start_front_sharded(
    kind: &str,
    cfg: ServingConfig,
    seed: u64,
    fleet: &FleetOpts,
    depth: usize,
    shards: usize,
) -> anyhow::Result<ShardedServer> {
    if shards <= 1 {
        return Ok(start_front_server(kind, cfg, seed, fleet, depth)?.into());
    }
    if kind != "sim" {
        anyhow::bail!("--shards needs simulated engines (use --backend sim)");
    }
    let shard_router = fleet
        .router
        .clone()
        .unwrap_or_else(|| default_router(&fleet.topology, fleet.planner).to_string());
    let multi =
        fleet.replicas > 1 || fleet.topology == "disagg" || fleet.planner != PlannerMode::Off;
    let replicas = fleet.replicas;
    let topo = fleet.topology.clone();
    let (planner, p_iv, p_rs) = (fleet.planner, fleet.planner_interval, fleet.reconfig_s);
    println!(
        "front-end shards: {shards} engine shards ({} per shard, {topo}), \
         {shard_router} shard routing",
        if multi {
            format!("{replicas} sim workers")
        } else {
            "1 sim worker".to_string()
        }
    );
    let stride = shards as u64;
    let inner_router = shard_router.clone();
    ShardedServer::start(shards, &shard_router, |i| {
        let cfg = cfg.clone();
        let topo = topo.clone();
        let router_name = inner_router.clone();
        let shard_seed = seed.wrapping_add(i as u64);
        move || {
            let core = if multi {
                let r = router_by_name(&router_name)
                    .ok_or_else(|| anyhow::anyhow!("unknown router `{router_name}`"))?;
                if planner != PlannerMode::Off {
                    // Each shard runs its own elastic planner over its
                    // own worker slice.
                    let mut cluster = if topo == "disagg" {
                        let (p, d) = disagg_split(replicas);
                        ClusterEngine::disagg(cfg, p, d, shard_seed, r)
                    } else {
                        ClusterEngine::replicated(cfg, replicas, shard_seed, r)
                    };
                    apply_planner(&mut cluster, planner, p_iv, p_rs);
                    ServerCore::sim_cluster(cluster)
                } else if topo == "disagg" {
                    let (p, d) = disagg_split(replicas);
                    ServerCore::sim_disagg(cfg, p, d, shard_seed, r)
                } else {
                    ServerCore::sim_replicated(cfg, replicas, shard_seed, r)
                }
            } else {
                ServerCore::sim(cfg, shard_seed)
            };
            Ok(core
                .with_queue_depth(depth)
                .with_id_stride(i as u64 + 1, stride))
        }
    })
}

/// Serve the workload through the unified streaming front-end: a
/// `ServingTopology` (one `EngineCore`, or a `ClusterEngine` of sim
/// workers routed at submit time) behind `server::Server`.
fn cmd_serve_front(
    kind: &str,
    cfg: ServingConfig,
    w: Workload,
    qps: f64,
    seed: u64,
    fleet: &FleetOpts,
    queue_cap: Option<usize>,
) {
    // The whole workload is submitted before any stream is drained, so
    // the default backpressure bound must admit all of it; an explicit
    // --queue-cap overrides that (submissions beyond it are refused and
    // reported, which is the point of the flag).
    let depth = queue_cap.unwrap_or_else(|| w.requests.len().max(1)).max(1);
    let server = match start_front_server(kind, cfg.clone(), seed, fleet, depth) {
        Ok(s) => s,
        Err(e) => {
            // The stub build has no PJRT runtime: report and skip, so CI
            // can exercise this path unconditionally.
            println!("front-end backend `{kind}` unavailable: {e}");
            return;
        }
    };
    println!(
        "front-end: {} requests ({}) via {} scheduler, `{kind}` backend",
        w.requests.len(),
        w.name,
        cfg.policy.name()
    );
    let mut handles = Vec::new();
    for r in &w.requests {
        // Session workloads carry real (materialized) prompt tokens; trace
        // requests carry lengths only, so synthesize a deterministic
        // prompt of the right length.
        let prompt: Vec<i32> = r
            .prompt_tokens
            .clone()
            .unwrap_or_else(|| (0..r.prompt_len).map(|j| (j % 1024) as i32).collect());
        let opts = SubmitOptions {
            max_new_tokens: r.output_len,
            arrival: Some(r.arrival),
            ..Default::default()
        };
        match server.submit(prompt, opts) {
            Ok(h) => handles.push(h),
            Err(e) => eprintln!("submit failed: {e}"),
        }
    }
    let mut streamed = 0usize;
    for h in handles {
        streamed += h.collect().len();
    }
    match server.shutdown() {
        Ok(rep) => {
            println!(
                "streamed {streamed} tokens (queue-cap {})",
                rep.queue_cap
                    .map(|q| q.to_string())
                    .unwrap_or_else(|| "n/a".into())
            );
            let mut t = Table::new(Report::header());
            t.row(rep.row(qps));
            t.print();
        }
        Err(e) => eprintln!("shutdown error: {e}"),
    }
}

/// Expose the streaming front-end over the OpenAI-compatible HTTP
/// transport. Composes with every topology the channel front-end
/// supports: `--backend sim|pjrt-stub [--replicas N --router R
/// --topology unified|disagg]`.
fn cmd_serve_http(args: &Args) {
    let cfg = build_config(args);
    let seed = args.usize_or("seed", 1) as u64;
    let addr = args.str_or("addr", "127.0.0.1:8080");
    let fleet = parse_fleet_opts(args);
    let backend = match args.one_of("backend", &["sim", "pjrt-stub"]) {
        Ok(choice) => choice.unwrap_or("sim").to_string(),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    validate_backend_fleet(&backend, &fleet);
    let numeric = |key: &str, default: usize| match args.usize_opt(key) {
        Ok(v) => v.unwrap_or(default),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let queue_cap = numeric("queue-cap", DEFAULT_QUEUE_DEPTH).max(1);
    let max_body = numeric("max-body", DEFAULT_MAX_BODY);
    let shards = numeric("shards", 1).max(1);
    let http_workers = numeric("http-workers", DEFAULT_POOL_WORKERS);
    let max_conns = numeric("max-conns", DEFAULT_MAX_CONNS);
    let idle_timeout = numeric("idle-timeout", DEFAULT_IDLE_TIMEOUT.as_secs() as usize).max(1);
    if backend == "pjrt-stub" && shards > 1 {
        eprintln!(
            "error: --shards needs simulated engines; the pjrt backend owns \
             one real device (use --backend sim)"
        );
        std::process::exit(2);
    }
    let server = match start_front_sharded(&backend, cfg.clone(), seed, &fleet, queue_cap, shards)
    {
        Ok(s) => s,
        Err(e) => {
            // Mirror `serve --backend pjrt-stub`: report and exit cleanly
            // so CI can probe the stub build unconditionally.
            println!("serve-http backend `{backend}` unavailable: {e}");
            return;
        }
    };
    let http_cfg = HttpConfig {
        model: format!("duetserve/{}", cfg.policy.name()),
        max_body,
        handle_signals: true,
        pool_workers: http_workers,
        max_conns,
        idle_timeout: Duration::from_secs(idle_timeout as u64),
    };
    let http = match HttpServer::start(&addr, server, http_cfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    let front_door = if cfg!(unix) && http_workers > 0 {
        format!("{http_workers}-worker keep-alive pool")
    } else {
        "thread-per-connection".to_string()
    };
    println!(
        "serve-http: listening on http://{} ({backend} backend, {} policy, queue-cap \
         {queue_cap}, {shards} shard(s), {front_door})",
        http.addr(),
        cfg.policy.name()
    );
    println!(
        "  POST /v1/completions | GET /healthz | GET /metrics | \
         POST /shutdown (graceful drain; SIGTERM/SIGINT drain too)"
    );
    match http.join() {
        Ok(rep) => {
            println!(
                "drained cleanly (queue-cap {})",
                rep.queue_cap
                    .map(|q| q.to_string())
                    .unwrap_or_else(|| "n/a".into())
            );
            let mut t = Table::new(Report::header());
            t.row(rep.row(0.0));
            t.print();
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_traces() {
    let mut t = Table::new(vec!["trace", "#requests", "mean-ISL", "mean-OSL"]);
    for kind in TraceKind::all() {
        let (n, _, _, _, _) = kind.calibration();
        let w = generate(kind, Some(n.min(4000)), 10.0, 1);
        let s = w.stats();
        t.row(vec![
            kind.name().to_string(),
            format!("{n}"),
            format!("{:.0}", s.mean_isl),
            format!("{:.0}", s.mean_osl),
        ]);
    }
    t.print();
}

fn cmd_partition(args: &Args) {
    let cfg = build_config(args);
    let pred = Predictor::new(cfg.model.clone(), cfg.gpu.clone(), cfg.tp);
    let n_dec = args.usize_or("decode", 32) as u64;
    let ctx = args.usize_or("ctx", 4096) as u64;
    let pre_tok = args.usize_or("prefill", 8192) as u64;
    let dec = BatchShape::from_shapes((0..n_dec).map(|_| AttnShape { q: 1, c: ctx }).collect());
    let pre = BatchShape::from_shapes(vec![AttnShape { q: pre_tok, c: 0 }]);
    match optimize_partition(&pred, &dec, &pre, cfg.tbt_slo, cfg.max_lookahead) {
        Some(p) => println!(
            "plan: Sd={} TPCs, Sp={} TPCs, k={}, t_d={:.1}ms, t_p={:.1}ms, \
             rho={:.0} tok/s, span={:.1}ms",
            p.decode.n_tpcs,
            p.prefill.n_tpcs,
            p.k,
            p.t_decode * 1e3,
            p.t_prefill * 1e3,
            p.rho,
            p.span() * 1e3
        ),
        None => println!("no feasible split under tbt_slo={}s", cfg.tbt_slo),
    }
}

/// One class slice of a declared traffic mix: what fraction of the load it
/// carries, its request shape, its SLO, and the attainment bar it must meet.
struct ClassMix {
    class: SloClass,
    share: f64,
    isl: u64,
    osl: u64,
    slo_tbt: Option<f64>,
    slo_ttft: Option<f64>,
    target: f64,
}

struct TrafficMix {
    name: &'static str,
    qps: f64,
    n: usize,
    classes: Vec<ClassMix>,
}

/// Built-in traffic-and-SLO declarations for `plan`. Shapes follow the
/// paper's trace statistics: interactive turns are short-prompt/short-output
/// under a tight TBT, batch jobs are long-prompt/short-output under a loose
/// one.
fn builtin_mixes() -> Vec<TrafficMix> {
    let latency = |share| ClassMix {
        class: SloClass::Latency,
        share,
        isl: 512,
        osl: 64,
        slo_tbt: Some(0.040),
        slo_ttft: Some(2.0),
        target: 0.90,
    };
    let standard = |share| ClassMix {
        class: SloClass::Standard,
        share,
        isl: 2048,
        osl: 128,
        slo_tbt: Some(0.150),
        slo_ttft: None,
        target: 0.80,
    };
    let batch = |share, isl| ClassMix {
        class: SloClass::Batch,
        share,
        isl,
        osl: 32,
        slo_tbt: Some(1.0),
        slo_ttft: None,
        target: 0.50,
    };
    vec![
        TrafficMix {
            name: "interactive",
            qps: 8.0,
            n: 120,
            classes: vec![latency(0.6), standard(0.3), batch(0.1, 6000)],
        },
        TrafficMix {
            name: "batch-heavy",
            qps: 4.0,
            n: 100,
            classes: vec![latency(0.2), standard(0.2), batch(0.6, 8000)],
        },
    ]
}

/// Materialize a mix into a concrete workload: each class arrives at its
/// share of the total rate on a deterministic grid, phase-shifted per class
/// so arrivals interleave rather than tie.
fn mix_workload(mix: &TrafficMix, n: usize, qps: f64) -> Workload {
    let mut requests = Vec::new();
    let mut id = 0u64;
    for (ci, c) in mix.classes.iter().enumerate() {
        let n_c = ((n as f64) * c.share).round().max(1.0) as usize;
        let rate = (qps * c.share).max(1e-9);
        for i in 0..n_c {
            let arrival = (i as f64 + 0.31 * (ci as f64 + 1.0)) / rate;
            let mut r = Request::new(id, arrival, c.isl, c.osl).with_class(c.class);
            if let Some(s) = c.slo_tbt {
                r = r.with_slo_tbt(s);
            }
            if let Some(s) = c.slo_ttft {
                r = r.with_slo_ttft(s);
            }
            requests.push(r);
            id += 1;
        }
    }
    Workload {
        name: format!("mix-{}", mix.name),
        requests,
    }
    .sorted_by_arrival()
}

/// One point of the `plan` sweep. `replicas` is the GPU cost; the scheduler
/// axis doubles as the SM-partition axis (duet multiplexes prefill and
/// decode on adaptively partitioned SMs, vllm time-shares the whole GPU).
struct PlanCandidate {
    label: &'static str,
    policy: Policy,
    topology: &'static str,
    replicas: u32,
    router: Option<&'static str>,
    planner: PlannerMode,
}

fn plan_candidates() -> Vec<PlanCandidate> {
    vec![
        PlanCandidate {
            label: "vllm x1",
            policy: Policy::VllmChunked,
            topology: "unified",
            replicas: 1,
            router: None,
            planner: PlannerMode::Off,
        },
        PlanCandidate {
            label: "duet x1",
            policy: Policy::Duet,
            topology: "unified",
            replicas: 1,
            router: None,
            planner: PlannerMode::Off,
        },
        PlanCandidate {
            label: "duet x2 rr",
            policy: Policy::Duet,
            topology: "unified",
            replicas: 2,
            router: Some("round-robin"),
            planner: PlannerMode::Off,
        },
        PlanCandidate {
            label: "duet 1P+1D",
            policy: Policy::Duet,
            topology: "disagg",
            replicas: 2,
            router: Some("least-outstanding"),
            planner: PlannerMode::Off,
        },
        PlanCandidate {
            label: "duet x2 elastic",
            policy: Policy::Duet,
            topology: "unified",
            replicas: 2,
            router: Some("conditional"),
            planner: PlannerMode::Elastic,
        },
        PlanCandidate {
            label: "duet x4 rr",
            policy: Policy::Duet,
            topology: "unified",
            replicas: 4,
            router: Some("round-robin"),
            planner: PlannerMode::Off,
        },
        PlanCandidate {
            label: "duet x4 elastic",
            policy: Policy::Duet,
            topology: "unified",
            replicas: 4,
            router: Some("conditional"),
            planner: PlannerMode::Elastic,
        },
    ]
}

fn run_plan_candidate(c: &PlanCandidate, base: &ServingConfig, w: Workload, seed: u64) -> Report {
    let mut cfg = base.clone();
    cfg.policy = c.policy;
    if c.topology == "disagg" {
        let (p, d) = disagg_split(c.replicas);
        let mut e = ClusterEngine::disagg(
            cfg,
            p,
            d,
            seed,
            router_by_name(c.router.unwrap_or("least-outstanding")).unwrap(),
        );
        apply_planner(&mut e, c.planner, None, None);
        e.run(w)
    } else if c.planner != PlannerMode::Off {
        // Elastic candidates: start unified and let the planner re-role
        // workers under the declared mix. The sweep's horizon is short
        // (tens of engine-seconds), so plan on a tight cadence with a
        // fast flip.
        let mut e = ClusterEngine::replicated(
            cfg,
            c.replicas,
            seed,
            router_by_name(c.router.unwrap_or("conditional")).unwrap(),
        );
        apply_planner(&mut e, c.planner, Some(5.0), Some(1.0));
        e.run(w)
    } else if c.replicas > 1 {
        let mut e = ReplicatedEngine::new(cfg, c.replicas, seed);
        if let Some(r) = c.router {
            e = e.with_router(router_by_name(r).unwrap());
        }
        e.run(w)
    } else {
        engine_for(cfg, seed).run(w)
    }
}

fn attains_targets(rep: &Report, mix: &TrafficMix) -> bool {
    mix.classes.iter().all(|c| {
        let cr = rep.class(c.class);
        cr.completed > 0 && cr.attainment().map_or(false, |a| a >= c.target)
    })
}

fn fmt_attainment(rep: &Report, class: SloClass) -> String {
    match rep.class(class).attainment() {
        Some(a) => format!("{:.0}%", a * 100.0),
        None => "-".to_string(),
    }
}

/// Capacity planning: run every candidate deployment against each declared
/// traffic mix, report per-class attainment, and name the cheapest (fewest
/// GPUs, then highest token throughput) config that attains every target.
fn cmd_plan(args: &Args) {
    let base = build_config(args);
    let seed = args.usize_or("seed", 1) as u64;
    let which = match args.one_of("mix", &["interactive", "batch-heavy", "all"]) {
        Ok(choice) => choice.unwrap_or("all").to_string(),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    for mix in builtin_mixes() {
        if which != "all" && which != mix.name {
            continue;
        }
        let n = args.usize_or("n", mix.n);
        let qps = args.f64_or("qps", mix.qps);
        let w = mix_workload(&mix, n, qps);
        println!(
            "mix `{}`: {} requests at {qps} req/s ({})",
            mix.name,
            w.requests.len(),
            mix.classes
                .iter()
                .map(|c| format!(
                    "{} {:.0}% target {:.0}%",
                    c.class.name(),
                    c.share * 100.0,
                    c.target * 100.0
                ))
                .collect::<Vec<_>>()
                .join(", ")
        );
        let mut t = Table::new(vec![
            "config", "gpus", "tok/s", "latency", "standard", "batch", "attains",
        ]);
        let mut best: Option<(u32, f64, &'static str)> = None;
        for c in plan_candidates() {
            let rep = run_plan_candidate(&c, &base, w.clone(), seed);
            let ok = attains_targets(&rep, &mix);
            t.row(vec![
                c.label.to_string(),
                format!("{}", c.replicas),
                format!("{:.0}", rep.token_throughput),
                fmt_attainment(&rep, SloClass::Latency),
                fmt_attainment(&rep, SloClass::Standard),
                fmt_attainment(&rep, SloClass::Batch),
                if ok { "yes" } else { "no" }.to_string(),
            ]);
            if ok {
                let better = match best {
                    None => true,
                    Some((g, tput, _)) => {
                        c.replicas < g || (c.replicas == g && rep.token_throughput > tput)
                    }
                };
                if better {
                    best = Some((c.replicas, rep.token_throughput, c.label));
                }
            }
        }
        t.print();
        match best {
            Some((g, _, label)) => {
                println!("cheapest attaining config: `{label}` ({g} GPU(s))")
            }
            None => println!("no candidate attains every class target at this load"),
        }
        println!();
    }
}

fn cmd_e2e(args: &Args) -> anyhow::Result<()> {
    if !artifacts::artifacts_available() {
        anyhow::bail!("artifacts not found — run `make artifacts` first");
    }
    let n = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 16) as u64;
    // The real model serves through the same unified lifecycle as the
    // simulations: EngineCore + scheduler, PJRT execution backend.
    let server = Server::start(move || {
        let backend = PjrtBackend::load_default()?;
        println!("platform: {}", backend.platform());
        let tuned =
            backend.tune_config(ServingConfig::default_8b().with_policy(Policy::VllmChunked));
        let scheduler = scheduler_for(&tuned);
        Ok(ServerCore::new(tuned, scheduler, Box::new(backend)))
    })?;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let prompt: Vec<i32> = (0..8 + i % 16)
                .map(|j| ((i * 97 + j * 31 + 3) % 2048) as i32)
                .collect();
            server
                .submit(
                    prompt,
                    SubmitOptions {
                        max_new_tokens: max_new,
                        ..Default::default()
                    },
                )
                .map_err(|e| anyhow::anyhow!("submit: {e}"))
        })
        .collect::<anyhow::Result<_>>()?;
    let mut tokens = 0usize;
    for h in handles {
        tokens += h.collect().len();
    }
    let rep = server.shutdown()?;
    println!(
        "{}: {} requests ({tokens} tokens) in {:.2}s = {:.2} req/s; \
         ttft mean {:.0}ms; tbt mean {:.1}ms p99 {:.1}ms",
        rep.system,
        rep.completed,
        rep.duration,
        rep.throughput_rps,
        rep.ttft.mean * 1e3,
        rep.tbt.mean * 1e3,
        rep.tbt_p99 * 1e3,
    );
    Ok(())
}

fn cmd_config(args: &Args) {
    let cfg = build_config(args);
    println!("{cfg:#?}");
    println!("kv_capacity_tokens = {}", cfg.kv_capacity_tokens());
    println!("kv_capacity_blocks = {}", cfg.kv_capacity_blocks());
}

const USAGE: &str = "\
duetserve — adaptive prefill/decode GPU multiplexing (paper reproduction)

USAGE: duetserve <serve|serve-http|traces|partition|plan|e2e|config> [--options]

serve:      --policy vllm|sglang|sglang-chunked|duet|dynamo
            --trace azure-code|azure-conv|mooncake | --isl N --osl N
            --workload sessions       (multi-turn conversations with
                                       per-tenant shared system prompts;
                                       --sessions N --turns N --tenants N
                                       --system-tokens N --user-tokens N
                                       --think F tune the mix)
            --qps F --n N --model qwen3-8b|qwen3-14b|qwen3-32b --tp N
            --budget N --tbt-slo F --seed N
            --prefix-cache            (block-level prefix caching: finished
                                       requests decay prompt KV blocks into
                                       a cached LRU pool; admission seeds
                                       the longest cached prefix)
            --replicas N --router round-robin|least-loaded|kv-pressure|
                                  kv-overlap (cache-aware: prefers the
                                       worker holding the longest cached
                                       prefix of the arriving prompt)
                                  conditional (length-conditional
                                       disaggregation: long prefills go to
                                       prefill-role workers under a
                                       load-adaptive threshold)
            --topology unified|disagg (disagg splits --replicas into
                                       prefill + decode role workers;
                                       needs --replicas >= 2)
            --planner elastic|static|off (default off; elastic re-roles
                                       workers online toward the forecast
                                       goodput-best role split, static is
                                       the legacy threshold planner;
                                       needs --replicas >= 2; see
                                       docs/elastic_roles.md)
            --planner-interval SECS   (planner tick cadence, default 30)
            --reconfig-s SECS         (worker re-role downtime, default 40)
            --backend sim|pjrt-stub   (stream through the unified
                                       front-end; with --replicas/--router/
                                       --topology the sim front-end serves
                                       live across a routed cluster;
                                       pjrt-stub skips unless built with
                                       --features xla-pjrt)
            --queue-cap N             (front-end submission-queue bound;
                                       beyond it submissions get
                                       QueueFull backpressure)
serve-http: --addr HOST:PORT (default 127.0.0.1:8080)
            --backend sim|pjrt-stub (default sim) --queue-cap N
            --max-body BYTES --seed N
            --replicas N --router R --topology unified|disagg
            --planner elastic|static|off [--planner-interval SECS
                                       --reconfig-s SECS]
            --shards N                (independent engine shards behind one
                                       submit surface; requests routed by
                                       --router against live shard load;
                                       sim backend only)
            --http-workers N          (keep-alive connection-pool size;
                                       0 = thread-per-connection baseline
                                       with Connection: close; default 4)
            --max-conns N             (concurrent-connection cap; excess
                                       accepts get 503 + Connection: close;
                                       0 = unlimited; default 4096)
            --idle-timeout SECS       (close kept-alive connections idle
                                       this long; default 30)
            plus the serve model/policy flags; exposes the
            OpenAI-compatible endpoint (see docs/http_api.md):
            POST /v1/completions (JSON, SSE with \"stream\":true),
            GET /healthz, GET /metrics, POST /shutdown
partition:  --decode N --ctx N --prefill N [--tbt-slo F]
plan:       --mix interactive|batch-heavy|all (default all)
            [--n N --qps F --seed N] plus the serve model flags;
            sweeps topology x replicas x router x scheduler (duet's
            adaptive SM partition vs time-shared chunking, plus
            elastic-planner configs that re-role workers under the mix)
            against the declared per-class traffic-and-SLO mix and
            prints the cheapest config attaining every class target
e2e:        --requests N --max-new N   (needs `make artifacts`)
";

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("serve-http") => cmd_serve_http(&args),
        Some("traces") => cmd_traces(),
        Some("partition") => cmd_partition(&args),
        Some("plan") => cmd_plan(&args),
        Some("e2e") => {
            if let Err(e) = cmd_e2e(&args) {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        }
        Some("config") => cmd_config(&args),
        _ => print!("{USAGE}"),
    }
}
