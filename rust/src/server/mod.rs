//! The unified serving front-end: one request lifecycle, pluggable
//! execution, pluggable topology.
//!
//! # Architecture
//!
//! There is exactly one serving path in this crate. [`ServerCore`] is a
//! deterministic, single-threaded request lifecycle over a
//! [`ServingTopology`] — the seam under which requests actually execute.
//! The front-end owns submission ordering, backpressure, token streams,
//! cancellation and drain; the topology owns routing, clocks, execution
//! and metrics. Two topologies exist:
//!
//! - a single [`EngineCore`] — the *same* iteration core the simulated
//!   engines run — paired with any [`ExecutionBackend`]:
//!   - **sim** ([`SimBackend`](crate::engine::SimBackend)): iteration
//!     latencies come from the roofline-calibrated executor; the serving
//!     path and `SimEngine` produce *identical* metrics for the same
//!     workload and seed (property-tested).
//!   - **pjrt** ([`PjrtBackend`](crate::runtime::PjrtBackend)): the real
//!     AOT-compiled tiny model; latencies are measured wall clock and
//!     tokens are real greedy argmax. On the default (stub) build the
//!     backend fails to construct with a clear message — real execution
//!     needs `--features xla-pjrt` plus `make artifacts`. The runtime
//!     has no SM partitions, so DuetServe's spatial plans degrade to
//!     aggregated iterations (logged once by the core).
//! - a [`ClusterEngine`](crate::engine::ClusterEngine) — N workers
//!   (unified replicas or disaggregated prefill/decode roles) advanced
//!   by the min-clock event loop, with each due submission routed
//!   through the [`Router`](crate::engine::Router) seam against live
//!   load signals. Submit, streaming, cancel, backpressure and graceful
//!   drain behave identically; the drain report is the workers' merged
//!   [`metrics::Recorder`](crate::metrics::Recorder), and the live path
//!   is property-tested identical to the batch
//!   `ClusterEngine::run(workload)` replay.
//!
//! Any [`Scheduler`] — including `DuetScheduler` — can drive the serving
//! path, because admission, chunked prefill, KV accounting, preemption
//! and metrics all live in the shared core, not here.
//!
//! [`Server`] is a thin *transport* layer over `ServerCore`: a dedicated
//! engine thread owns the core (PJRT handles are not `Send`; the engine
//! thread owns the device for its lifetime) while client threads submit
//! through a control channel and stream [`TokenEvent`]s back over
//! per-request channels. Each event carries the engine-clock timestamp of
//! its token, so TTFT/TBT come from the same [`metrics`](crate::metrics)
//! structs as the simulations.
//!
//! # Request lifecycle
//!
//! [`ServerCore::submit`] applies bounded-queue backpressure: beyond the
//! configured depth of not-yet-admitted requests it returns
//! [`SubmitError::QueueFull`] instead of queueing unboundedly. Admission
//! out of the submission queue orders each arrival-due cohort by
//! (aged [`SloClass`] rank, priority desc, arrival, submission order) —
//! for single-class equal-priority traffic that degenerates to pure
//! FCFS in arrival order. Under slot/KV exhaustion the scheduler
//! blocks the head rather than skipping ahead, so first-token order
//! follows admission order (regression-tested). `cancel` removes a
//! request at any stage and closes its stream with
//! [`FinishReason::Cancelled`]; shutdown drains in-flight and queued work
//! before the engine thread exits, returning the final [`Report`].

pub mod http;
#[cfg(unix)]
pub(crate) mod pool;

use std::cmp::Ordering;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::ServingConfig;
use crate::engine::{
    router_by_name, ClusterEngine, EngineCore, ExecutionBackend, RouteCandidate, Router,
    ServingTopology, SimBackend, TopologyLoad, TopologyStep,
};
use crate::metrics::{Recorder, RecorderMode, Report};
use crate::request::{Request, RequestId, SloClass};
use crate::sched::{scheduler_for, Scheduler};

/// Default bound on accepted-but-not-yet-admitted requests.
pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

/// Why a stream ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// All requested tokens were generated.
    Completed,
    /// The client cancelled the request.
    Cancelled,
    /// The engine dropped it (prompt can never fit KV, or divergence
    /// drain).
    Dropped,
}

/// A streamed token event.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenEvent {
    /// One generated token, stamped with the engine-clock time it was
    /// produced (seconds).
    Token { value: i32, at: f64 },
    /// Generation finished.
    Done { reason: FinishReason },
}

/// Typed QoS envelope for one submission: the request's SLO class plus
/// its intra-class priority and per-request SLO targets. Replaces the
/// loose `slo_tbt_ms`/`priority` field bag that used to live directly on
/// [`SubmitOptions`].
#[derive(Debug, Clone, PartialEq)]
pub struct QosSpec {
    /// Scheduling class ([`SloClass::Standard`] when unspecified — the
    /// pre-QoS behavior).
    pub class: SloClass,
    /// Larger runs earlier within the same class among submissions whose
    /// arrivals are due together.
    pub priority: i32,
    /// Per-request decode TBT SLO in milliseconds; attainment is
    /// accounted in the shared metrics ([`Report::slo_attainment`] and
    /// the per-class series). For latency-class requests it also
    /// tightens the duet scheduler's effective iteration SLO.
    pub slo_tbt_ms: Option<f64>,
    /// Per-request TTFT SLO in milliseconds (attainment gate only).
    pub slo_ttft_ms: Option<f64>,
}

impl Default for QosSpec {
    fn default() -> QosSpec {
        QosSpec {
            class: SloClass::Standard,
            priority: 0,
            slo_tbt_ms: None,
            slo_ttft_ms: None,
        }
    }
}

/// Per-request submission options.
#[derive(Debug, Clone)]
pub struct SubmitOptions {
    /// Generation bound (≥ 1).
    pub max_new_tokens: u64,
    /// Engine-clock arrival override (trace replay); `None` means "now".
    pub arrival: Option<f64>,
    /// QoS envelope (class, priority, SLO targets).
    pub qos: QosSpec,
}

impl Default for SubmitOptions {
    fn default() -> SubmitOptions {
        SubmitOptions {
            max_new_tokens: 16,
            arrival: None,
            qos: QosSpec::default(),
        }
    }
}

impl SubmitOptions {
    pub fn with_max_new_tokens(mut self, n: u64) -> SubmitOptions {
        self.max_new_tokens = n;
        self
    }

    pub fn with_arrival(mut self, arrival: f64) -> SubmitOptions {
        self.arrival = Some(arrival);
        self
    }

    pub fn with_qos(mut self, qos: QosSpec) -> SubmitOptions {
        self.qos = qos;
        self
    }

    pub fn with_class(mut self, class: SloClass) -> SubmitOptions {
        self.qos.class = class;
        self
    }

    pub fn with_priority(mut self, priority: i32) -> SubmitOptions {
        self.qos.priority = priority;
        self
    }

    pub fn with_slo_tbt_ms(mut self, ms: f64) -> SubmitOptions {
        self.qos.slo_tbt_ms = Some(ms);
        self
    }

    pub fn with_slo_ttft_ms(mut self, ms: f64) -> SubmitOptions {
        self.qos.slo_ttft_ms = Some(ms);
        self
    }
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Backpressure: the submission queue is at its configured depth.
    QueueFull { depth: usize },
    /// The request itself is invalid (empty prompt, zero tokens).
    Rejected(String),
    /// The server is shutting down (or its engine thread is gone).
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { depth } => {
                write!(f, "submission queue full (depth {depth})")
            }
            SubmitError::Rejected(why) => write!(f, "rejected: {why}"),
            SubmitError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The mergeable pieces of a serving report, before rendering into a
/// [`Report`]. Single-shard servers convert straight through
/// [`into_report`](ReportParts::into_report); a [`ShardedServer`] merges
/// per-shard parts first — the recorders fold exactly as cluster workers
/// fold at drain ([`Recorder::merge`] + max-duration), so an N-shard
/// drain report aggregates identically to an N-worker cluster's.
#[derive(Debug, Clone)]
pub struct ReportParts {
    pub recorder: Recorder,
    /// Topology label (`Report::system` becomes `server/<label>`).
    pub label: String,
    pub engine_epoch: u64,
    pub engine_uptime_s: f64,
    /// Backpressure bound; summed across shards on merge.
    pub queue_cap: Option<usize>,
    /// True when the engine loop aborted on a backend panic.
    pub aborted: bool,
}

impl ReportParts {
    /// Render into the final [`Report`] (same rendering the unsharded
    /// server always did).
    pub fn into_report(self) -> Report {
        let mut rep = self.recorder.report(&self.label);
        rep.system = if self.aborted {
            "server/aborted".to_string()
        } else {
            format!("server/{}", self.label)
        };
        rep.queue_cap = self.queue_cap;
        rep.engine_epoch = self.engine_epoch;
        rep.engine_uptime_s = self.engine_uptime_s;
        rep
    }

    /// Fold `other` into `self`, mirroring the cluster's worker fold:
    /// recorders merge, duration/epoch/uptime take the max, queue caps
    /// sum, and an abort anywhere taints the whole report.
    pub fn merge(&mut self, other: &ReportParts) {
        let dur = self.recorder.duration.max(other.recorder.duration);
        self.recorder.merge(&other.recorder);
        self.recorder.duration = dur;
        self.engine_epoch = self.engine_epoch.max(other.engine_epoch);
        self.engine_uptime_s = self.engine_uptime_s.max(other.engine_uptime_s);
        self.queue_cap = match (self.queue_cap, other.queue_cap) {
            (Some(a), Some(b)) => Some(a + b),
            (a, b) => a.or(b),
        };
        self.aborted |= other.aborted;
    }
}

enum Control {
    Submit {
        prompt: Vec<i32>,
        opts: SubmitOptions,
        reply: Sender<std::result::Result<RequestHandle, SubmitError>>,
    },
    Cancel(RequestId),
    /// Live, non-destructive metrics snapshot (the HTTP transport's
    /// `/metrics` endpoint).
    Report(Sender<ReportParts>),
    Shutdown,
}

/// Handle the client holds for one in-flight request.
#[derive(Debug)]
pub struct RequestHandle {
    id: RequestId,
    /// Wall-clock submission time (client side).
    pub submitted_at: Instant,
    rx: Receiver<TokenEvent>,
    ctl: Option<Sender<Control>>,
}

impl RequestHandle {
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Block until the request completes; returns the token values.
    pub fn collect(self) -> Vec<i32> {
        let mut out = Vec::new();
        while let Ok(ev) = self.rx.recv() {
            match ev {
                TokenEvent::Token { value, .. } => out.push(value),
                TokenEvent::Done { .. } => break,
            }
        }
        out
    }

    /// Block until the request completes; returns every event including
    /// the terminal [`TokenEvent::Done`].
    pub fn collect_events(self) -> Vec<TokenEvent> {
        let mut out = Vec::new();
        while let Ok(ev) = self.rx.recv() {
            let done = matches!(ev, TokenEvent::Done { .. });
            out.push(ev);
            if done {
                break;
            }
        }
        out
    }

    /// Non-blocking poll.
    pub fn try_next(&self) -> Option<TokenEvent> {
        self.rx.try_recv().ok()
    }

    /// Blocking wait for the next event; `None` once the stream closed.
    pub fn next_event(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Blocking wait bounded by `timeout`. `TimedOut` means the request
    /// is still live but produced nothing yet — transports use the gap
    /// to probe their connection for client disconnects.
    pub fn next_event_timeout(&self, timeout: Duration) -> HandlePoll {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => HandlePoll::Event(ev),
            Err(RecvTimeoutError::Timeout) => HandlePoll::TimedOut,
            Err(RecvTimeoutError::Disconnected) => HandlePoll::Closed,
        }
    }

    /// Ask the server to cancel this request. Returns false when the
    /// handle has no control channel (core-driven handles — use
    /// [`ServerCore::cancel`]) or the server is gone.
    pub fn cancel(&self) -> bool {
        match &self.ctl {
            Some(tx) => tx.send(Control::Cancel(self.id)).is_ok(),
            None => false,
        }
    }
}

/// Outcome of [`RequestHandle::next_event_timeout`].
#[derive(Debug, Clone, PartialEq)]
pub enum HandlePoll {
    /// The next stream event.
    Event(TokenEvent),
    /// Nothing within the timeout; the request is still in flight.
    TimedOut,
    /// The stream has ended (all events already consumed).
    Closed,
}

struct PendingEntry {
    req: Request,
    priority: i32,
    /// Submission order, the final admission tie-break (FCFS).
    seq: u64,
}

/// Engine-clock seconds of waiting that promote a request one class rank
/// toward `latency` during admission ordering — the starvation bound:
/// a `batch` submission outranks fresh latency traffic after at most
/// `2 × CLASS_AGING_S` of queueing (then priority/arrival decide).
pub const CLASS_AGING_S: f64 = 30.0;

/// Class rank after aging: the class index, promoted one step toward 0
/// per [`CLASS_AGING_S`] of queue wait. Within one class, rank is
/// non-increasing in waited time — so for single-class traffic, rank
/// order degenerates to arrival order and admission stays pure FCFS.
fn aged_class_rank(class: SloClass, waited_s: f64) -> i64 {
    let promote = (waited_s.max(0.0) / CLASS_AGING_S) as i64;
    (class.index() as i64) - promote.min(SloClass::COUNT as i64)
}

/// Admission order within an arrival-due cohort:
/// (aged class rank, priority desc, arrival, submission order).
fn cohort_order(a: &PendingEntry, b: &PendingEntry, now_abs: f64) -> Ordering {
    let ra = aged_class_rank(a.req.class, now_abs - a.req.arrival);
    let rb = aged_class_rank(b.req.class, now_abs - b.req.arrival);
    ra.cmp(&rb)
        .then(b.priority.cmp(&a.priority))
        .then(a.req.arrival.total_cmp(&b.req.arrival))
        .then(a.seq.cmp(&b.seq))
}

struct StreamState {
    tx: Sender<TokenEvent>,
    /// Tokens consumed from the backend for this request.
    seen: u64,
    /// Token events actually delivered to the client (replays after
    /// recompute preemption are suppressed).
    emitted: u64,
    /// Timestamp of output token 0, to detect recompute replays.
    first_at: f64,
}

/// The unified request lifecycle: a [`ServingTopology`] (one
/// [`EngineCore`] or an N-worker cluster) plus submission queue, token
/// streams, backpressure, cancel and drain. Deterministic and
/// single-threaded — [`Server`] adds the transport.
pub struct ServerCore {
    topology: Box<dyn ServingTopology>,
    pending: VecDeque<PendingEntry>,
    streams: HashMap<RequestId, StreamState>,
    queue_depth: usize,
    next_id: RequestId,
    /// Request-id increment: 1 standalone; the shard count under a
    /// [`ShardedServer`], so shard id spaces interleave disjointly.
    id_stride: u64,
    /// Monotone submission counter (admission FCFS tie-break).
    next_seq: u64,
    /// Requests cancelled by the client.
    pub cancelled: u64,
}

impl ServerCore {
    /// Single-worker core over an explicit scheduler + backend.
    pub fn new(
        cfg: ServingConfig,
        scheduler: Box<dyn Scheduler>,
        backend: Box<dyn ExecutionBackend>,
    ) -> ServerCore {
        ServerCore::over(Box::new(EngineCore::with_backend(cfg, scheduler, backend)))
    }

    /// Core over any serving topology (single core or cluster).
    ///
    /// Serving is the long-lived path, so recorders default to
    /// [`RecorderMode::Streaming`]: resident metrics state and every
    /// live `/metrics` snapshot are O(1) in total samples served
    /// (running aggregates + quantile sketch), and pumped finished
    /// requests are released instead of accumulating. Batch engines and
    /// benches construct their own topologies and keep exact history.
    pub fn over(mut topology: Box<dyn ServingTopology>) -> ServerCore {
        topology.set_recorder_mode(RecorderMode::Streaming);
        ServerCore {
            topology,
            pending: VecDeque::new(),
            streams: HashMap::new(),
            queue_depth: DEFAULT_QUEUE_DEPTH,
            next_id: 0,
            id_stride: 1,
            next_seq: 0,
            cancelled: 0,
        }
    }

    /// Simulated-backend core: the policy scheduler from `cfg` over a
    /// [`SimBackend`] — byte-identical engine construction to
    /// `SimEngine`, so metrics match the simulation exactly.
    pub fn sim(cfg: ServingConfig, seed: u64) -> ServerCore {
        let scheduler = scheduler_for(&cfg);
        let backend = Box::new(SimBackend::from_config(&cfg, seed));
        ServerCore::new(cfg, scheduler, backend)
    }

    /// Cluster-backed core: `replicas` unified sim workers behind
    /// `router` — construction-identical to
    /// [`ClusterEngine::replicated`], so live serving is metric-identical
    /// to the batch cluster run (property-tested).
    pub fn sim_replicated(
        cfg: ServingConfig,
        replicas: u32,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ServerCore {
        ServerCore::over(Box::new(ClusterEngine::replicated(
            cfg, replicas, seed, router,
        )))
    }

    /// Cluster-backed core over an arbitrary prebuilt [`ClusterEngine`]
    /// (e.g. a replicated fleet with the elastic role planner enabled) —
    /// the general spelling of [`sim_replicated`](ServerCore::sim_replicated)
    /// / [`sim_disagg`](ServerCore::sim_disagg).
    pub fn sim_cluster(cluster: ClusterEngine) -> ServerCore {
        ServerCore::over(Box::new(cluster))
    }

    /// Cluster-backed core over a disaggregated prefill/decode fleet.
    pub fn sim_disagg(
        cfg: ServingConfig,
        prefill_gpus: u32,
        decode_gpus: u32,
        seed: u64,
        router: Box<dyn Router>,
    ) -> ServerCore {
        ServerCore::over(Box::new(ClusterEngine::disagg(
            cfg,
            prefill_gpus,
            decode_gpus,
            seed,
            router,
        )))
    }

    /// Set the backpressure bound (accepted-but-not-admitted requests).
    pub fn with_queue_depth(mut self, depth: usize) -> ServerCore {
        self.queue_depth = depth.max(1);
        self
    }

    /// Assign this core a disjoint request-id space: ids start at `base`
    /// and advance by `stride`. Shard *i* of an N-shard server uses
    /// `(i, N)`, so ids stay globally unique across shards.
    pub fn with_id_stride(mut self, base: u64, stride: u64) -> ServerCore {
        self.next_id = base;
        self.id_stride = stride.max(1);
        self
    }

    /// The effective backpressure bound (`--queue-cap`). Surfaced in the
    /// drain report ([`Report::queue_cap`]).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// The single [`EngineCore`] under this server. Panics for
    /// cluster-backed servers — use [`cluster`](ServerCore::cluster).
    pub fn engine(&self) -> &EngineCore {
        self.topology
            .as_engine()
            .expect("server is cluster-backed; use ServerCore::cluster()")
    }

    /// The [`ClusterEngine`] under this server. Panics for single-core
    /// servers — use [`engine`](ServerCore::engine).
    pub fn cluster(&self) -> &ClusterEngine {
        self.topology
            .as_cluster()
            .expect("server is single-core; use ServerCore::engine()")
    }

    /// The topology's arrival reference clock on the **absolute**
    /// engine timeline (epoch offset + epoch-local clock; min worker
    /// clock for a cluster). Monotone across epoch re-bases —
    /// submissions, SSE `at` stamps and reports all live on this
    /// timeline.
    pub fn clock(&self) -> f64 {
        self.topology.epoch_offset() + self.topology.clock()
    }

    /// Engine-clock epochs completed by the topology underneath.
    pub fn epoch(&self) -> u64 {
        self.topology.epoch()
    }

    /// Accepted but not yet admitted requests (backpressure signal).
    pub fn queued(&self) -> usize {
        self.pending.len() + self.topology.queued()
    }

    /// Submit one request. Applies validation and bounded-queue
    /// backpressure; on success the returned handle streams
    /// [`TokenEvent`]s as the engine produces them.
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        opts: SubmitOptions,
    ) -> std::result::Result<RequestHandle, SubmitError> {
        if prompt.is_empty() {
            return Err(SubmitError::Rejected("empty prompt".into()));
        }
        if opts.max_new_tokens == 0 {
            return Err(SubmitError::Rejected("max_new_tokens must be >= 1".into()));
        }
        // Bound the trace-replay arrival override, per-epoch: an arrival
        // too far past the divergence horizon would jump the engine
        // clock over `max_engine_time` on the idle-hint path and drain
        // every in-flight request. Arrivals are on the absolute
        // timeline; anything within one horizon of the current uptime is
        // safe, because a fully idle topology re-bases its epoch before
        // jumping to a future arrival. One bad (or hostile, over HTTP)
        // submission must not brick the server.
        let horizon = self.clock() + self.topology.max_engine_time();
        if opts.arrival.is_some_and(|a| !(0.0..=horizon).contains(&a)) {
            return Err(SubmitError::Rejected(format!(
                "arrival must be within [0, {horizon}] engine-clock seconds \
                 (current uptime + max engine time per epoch)"
            )));
        }
        if let Some(mc) = self.topology.max_context() {
            let need = prompt.len() as u64 + opts.max_new_tokens;
            if need > mc {
                return Err(SubmitError::Rejected(format!(
                    "prompt + max_new_tokens ({need}) exceeds the backend's max context ({mc})"
                )));
            }
        }
        if self.queued() >= self.queue_depth {
            return Err(SubmitError::QueueFull {
                depth: self.queue_depth,
            });
        }
        let id = self.next_id;
        self.next_id += self.id_stride;
        // "Now" on the absolute timeline; converted back to the owning
        // epoch's local coordinates at injection time.
        let arrival = opts.arrival.unwrap_or_else(|| self.clock());
        let mut req = Request::new(id, arrival, prompt.len() as u64, opts.max_new_tokens)
            .with_prompt_tokens(prompt)
            .with_class(opts.qos.class);
        if let Some(ms) = opts.qos.slo_tbt_ms {
            req = req.with_slo_tbt(ms / 1e3);
        }
        if let Some(ms) = opts.qos.slo_ttft_ms {
            req = req.with_slo_ttft(ms / 1e3);
        }
        let (tx, rx) = channel();
        self.streams.insert(
            id,
            StreamState {
                tx,
                seen: 0,
                emitted: 0,
                first_at: f64::NAN,
            },
        );
        // Sorted insert by arrival; equal arrivals keep submission order.
        // Class/priority ordering happens at admission time, across the
        // whole arrival-due cohort ([`cohort_order`]), not here.
        let seq = self.next_seq;
        self.next_seq += 1;
        let pos = self.pending.make_contiguous().partition_point(|e| {
            // total_cmp: a NaN arrival (impossible, but defensively) sorts
            // last instead of panicking the serving thread.
            e.req.arrival.total_cmp(&arrival) != Ordering::Greater
        });
        self.pending.insert(
            pos,
            PendingEntry {
                req,
                priority: opts.qos.priority,
                seq,
            },
        );
        Ok(RequestHandle {
            id,
            submitted_at: Instant::now(),
            rx,
            ctl: None,
        })
    }

    /// Cancel a request at any stage. Returns false when it is unknown
    /// (already finished or never existed).
    pub fn cancel(&mut self, id: RequestId) -> bool {
        let known = if let Some(pos) = self.pending.iter().position(|e| e.req.id == id) {
            self.pending.remove(pos);
            true
        } else {
            self.topology.cancel(id)
        };
        if known {
            self.cancelled += 1;
            self.finish_stream(id, FinishReason::Cancelled);
        }
        known
    }

    /// One topology event. Returns false when no pending, queued or
    /// running work remains.
    ///
    /// The admit / step / idle-arrival-hint structure deliberately
    /// mirrors the batch loops (`SimEngine::step`, `ClusterEngine::run`)
    /// — that equivalence is what makes the serving path produce
    /// identical metrics to the batch runs
    /// (`server_path_matches_sim_engine_metrics` and
    /// `cluster_server_matches_cluster_engine_metrics` pin it; a change
    /// to either side must keep those property tests green).
    pub fn step(&mut self) -> bool {
        if !self.topology.has_work() {
            // Fully idle engine (pending submissions live on the
            // absolute timeline and convert at injection, so a re-base
            // here is transparent to them). This must run *before* any
            // idle jump toward a future arrival — re-basing first keeps
            // the jump within the fresh epoch's divergence horizon.
            self.topology.rebase_if_idle();
        }
        self.admit_pending();
        if self.pending.is_empty() && !self.topology.has_work() {
            return false;
        }
        // Everything due was injected above, so the head of the
        // submission queue is strictly in the future: hint it (in the
        // current epoch's local coordinates) so idle workers jump there
        // instead of parking.
        let mut off = self.topology.epoch_offset();
        if let Some(e) = self.pending.front() {
            // An idle jump to the next submission must stay inside the
            // divergence horizon. When the gap overshoots it, force a
            // re-base first (the topology is necessarily fully idle for
            // a jump to happen): the submit bound
            // `arrival ≤ uptime + max_engine_time` guarantees the
            // post-re-base local arrival fits the fresh epoch, so an
            // accepted submission can never trip the guard by itself.
            if (e.req.arrival - off).max(0.0) > self.topology.max_engine_time()
                && self.topology.rebase_now()
            {
                off = self.topology.epoch_offset();
                self.admit_pending();
            }
        }
        let hint = self.pending.front().map(|e| (e.req.arrival - off).max(0.0));
        match self.topology.step(hint) {
            TopologyStep::Progressed => {
                self.pump_tokens();
                true
            }
            TopologyStep::Dropped(id) => {
                self.finish_stream(id, FinishReason::Dropped);
                true
            }
            TopologyStep::Diverged(mut victims) => {
                // The topology drained itself; discard the un-injected
                // submissions too and close every affected stream.
                self.topology.add_dropped(self.pending.len() as u64);
                victims.extend(self.pending.drain(..).map(|e| e.req.id));
                for id in victims {
                    self.finish_stream(id, FinishReason::Dropped);
                }
                false
            }
            TopologyStep::Exhausted => false,
        }
    }

    /// Drain: run until all accepted work has completed (or been
    /// dropped/cancelled).
    pub fn run_to_idle(&mut self) {
        while self.step() {}
    }

    /// Drain and produce the final report from the shared metrics
    /// structs (same `Recorder`/`Report` as the simulated engines; merged
    /// across workers for a cluster). The engine invariants are checked
    /// on this path too, not just the batch runs.
    pub fn finish(self) -> Report {
        self.finish_parts().into_report()
    }

    /// Drain-time report pieces, pre-rendering — what a
    /// [`ShardedServer`] merges across shards.
    pub fn finish_parts(mut self) -> ReportParts {
        self.run_to_idle();
        let label = self.topology.label();
        let epoch = self.topology.epoch();
        let uptime = self.clock();
        let recorder = self.topology.drain_recorder();
        if let Err(e) = self.topology.check_invariants() {
            // Print before panicking: on the threaded path the panic
            // unwinds the engine thread and `shutdown` only reports "the
            // engine thread panicked" — stderr must carry the diagnostic.
            eprintln!("serving invariants violated at drain: {e}");
            panic!("serving invariants violated at drain: {e}");
        }
        ReportParts {
            recorder,
            label,
            engine_epoch: epoch,
            engine_uptime_s: uptime,
            queue_cap: Some(self.queue_depth),
            aborted: false,
        }
    }

    /// Live, non-destructive metrics snapshot: what has been recorded so
    /// far, without draining. [`ServingTopology::fold_report`] is a
    /// drain-time operation (the cluster implementation retires worker
    /// state while folding), so this goes through the topology's
    /// [`snapshot_recorder`](ServingTopology::snapshot_recorder) seam
    /// instead. Powers the HTTP transport's `/metrics` endpoint.
    pub fn report_snapshot(&self) -> Report {
        self.snapshot_parts().into_report()
    }

    /// Live snapshot pieces, pre-rendering (mergeable across shards).
    pub fn snapshot_parts(&self) -> ReportParts {
        ReportParts {
            recorder: self.topology.snapshot_recorder(),
            label: self.topology.label(),
            engine_epoch: self.topology.epoch(),
            engine_uptime_s: self.clock(),
            queue_cap: Some(self.queue_depth),
            aborted: false,
        }
    }

    /// O(1) load signals for submit-time routing: the topology's
    /// incremental counters plus this core's not-yet-injected backlog.
    pub fn load(&self) -> TopologyLoad {
        let mut l = self.topology.load();
        l.queue_len += self.pending.len();
        l
    }

    fn admit_pending(&mut self) {
        // Pending arrivals are absolute; the topology clock is
        // epoch-local. Compare and inject in local coordinates — the
        // *same* `(arrival - offset).max(0)` expression the step hint
        // uses, so an idle jump to a hinted arrival always makes that
        // arrival due on the next admit pass (no float drift between
        // the two conversions).
        let off = self.topology.epoch_offset();
        let clock = self.topology.clock();
        let due = self
            .pending
            .make_contiguous()
            .partition_point(|e| (e.req.arrival - off).max(0.0) <= clock);
        if due == 0 {
            return;
        }
        // The whole arrival-due cohort admits together, ordered by
        // (aged class rank, priority desc, arrival, submission order) —
        // not pure FCFS. Aging bounds starvation: a batch-class entry
        // promotes one rank per CLASS_AGING_S of queueing. For
        // single-class equal-priority traffic the key degenerates to
        // (arrival, seq), i.e. exactly the old FCFS order.
        let mut batch: Vec<PendingEntry> = self.pending.drain(..due).collect();
        let now_abs = off + clock;
        batch.sort_by(|a, b| cohort_order(a, b, now_abs));
        for mut e in batch {
            e.req.arrival = (e.req.arrival - off).max(0.0);
            self.topology.inject(e.req);
        }
    }

    /// Emit newly produced tokens to their streams. Values come from the
    /// owning worker's backend (real argmax on PJRT, synthetic in
    /// simulation); timestamps come from the request's engine-clock token
    /// times, re-based onto the absolute timeline (epoch offset + local
    /// time) so `at` stamps stay monotone per connection across epoch
    /// re-bases.
    fn pump_tokens(&mut self) {
        let streams = &mut self.streams;
        let mut completed: Vec<RequestId> = Vec::new();
        // One offset covers every request the pump can visit: a cluster
        // shifts all workers by a common delta, and re-bases only happen
        // while fully idle, so no in-flight request straddles epochs.
        let off = self.topology.epoch_offset();
        self.topology.pump(&mut |reqs, backend, finished| {
            for r in reqs {
                Self::pump_one(streams, backend, r, off);
                if finished {
                    completed.push(r.id);
                }
            }
        });
        for id in completed {
            self.finish_stream(id, FinishReason::Completed);
        }
    }

    fn pump_one(
        streams: &mut HashMap<RequestId, StreamState>,
        backend: &mut dyn ExecutionBackend,
        r: &Request,
        epoch_offset: f64,
    ) {
        let Some(st) = streams.get_mut(&r.id) else { return };
        // Recompute preemption replays the request from scratch: progress
        // regressed, or token 0 now carries a different timestamp. Replay
        // consumption from the backend, but do not re-emit to the client.
        // (`first_at` compares epoch-local stamps; a request never spans
        // a re-base, so the comparison base is stable.)
        if r.generated < st.seen
            || (st.seen > 0 && r.generated > 0 && r.token_times[0] != st.first_at)
        {
            st.seen = 0;
        }
        while st.seen < r.generated {
            let idx = st.seen;
            let value = backend.pop_token(r.id, idx);
            let at_local = r.token_times[idx as usize];
            if idx == 0 {
                st.first_at = at_local;
            }
            st.seen += 1;
            if idx >= st.emitted {
                let _ = st.tx.send(TokenEvent::Token {
                    value,
                    at: epoch_offset + at_local,
                });
                st.emitted = idx + 1;
            }
        }
    }

    fn finish_stream(&mut self, id: RequestId, reason: FinishReason) {
        if let Some(st) = self.streams.remove(&id) {
            let _ = st.tx.send(TokenEvent::Done { reason });
        }
        // Backend-side state (real KV slots, pending tokens) is
        // reclaimed once the stream is closed.
        self.topology.release(id);
    }

    /// Close every open stream with a terminal event and report what ran
    /// so far. The transport calls this when a backend failure (panic)
    /// aborts the engine loop: clients must observe an explicit `Done`
    /// rather than a silently truncated stream.
    fn into_aborted_parts(mut self) -> ReportParts {
        let ids: Vec<RequestId> = self.streams.keys().copied().collect();
        for id in ids {
            self.finish_stream(id, FinishReason::Dropped);
        }
        let label = self.topology.label();
        let epoch = self.topology.epoch();
        let uptime = self.clock();
        ReportParts {
            recorder: self.topology.drain_recorder(),
            label,
            engine_epoch: epoch,
            engine_uptime_s: uptime,
            queue_cap: Some(self.queue_depth),
            aborted: true,
        }
    }
}

fn apply_control(core: &mut ServerCore, ctl: Control, handle_ctl: &Sender<Control>) -> bool {
    match ctl {
        Control::Submit {
            prompt,
            opts,
            reply,
        } => {
            let res = core.submit(prompt, opts).map(|mut h| {
                h.ctl = Some(handle_ctl.clone());
                h
            });
            let _ = reply.send(res);
            false
        }
        Control::Cancel(id) => {
            core.cancel(id);
            false
        }
        Control::Report(reply) => {
            let _ = reply.send(core.snapshot_parts());
            false
        }
        Control::Shutdown => true,
    }
}

/// Lock-free per-shard load signals, published by the engine thread once
/// per loop iteration and read by [`ShardedServer::submit`] to build
/// [`RouteCandidate`]s without a control-channel round trip. All loads
/// are `Relaxed`: routing is heuristic, and a slightly stale signal only
/// costs placement quality, never correctness.
#[derive(Debug, Default)]
pub struct LoadBoard {
    queue_len: AtomicUsize,
    outstanding_tokens: AtomicU64,
    kv_free_tokens: AtomicU64,
}

impl LoadBoard {
    fn publish(&self, load: &TopologyLoad) {
        self.queue_len.store(load.queue_len, AtomicOrdering::Relaxed);
        self.outstanding_tokens
            .store(load.outstanding_tokens, AtomicOrdering::Relaxed);
        self.kv_free_tokens
            .store(load.kv_free_tokens, AtomicOrdering::Relaxed);
    }

    /// Render as a routing candidate for shard index `worker`. Prefix
    /// signals are per-request and not tracked across shards: 0.
    fn candidate(&self, worker: usize) -> RouteCandidate {
        RouteCandidate {
            worker,
            queue_len: self.queue_len.load(AtomicOrdering::Relaxed),
            outstanding_tokens: self.outstanding_tokens.load(AtomicOrdering::Relaxed),
            kv_free_tokens: self.kv_free_tokens.load(AtomicOrdering::Relaxed),
            prefix_resident_tokens: 0,
            prefix_overlap_tokens: 0,
            // Shards are whole engines, never single prefill-role
            // workers.
            prefill_only: false,
        }
    }
}

/// Threaded transport over [`ServerCore`]: spawn once, submit from any
/// thread, stream tokens back.
pub struct Server {
    tx: Sender<Control>,
    engine_thread: Option<JoinHandle<ReportParts>>,
    load: Arc<LoadBoard>,
}

impl Server {
    /// Start the engine loop on its own thread. The core is constructed
    /// *on* that thread via `make_core` (real-runtime handles are not
    /// `Send`; the engine thread owns the device for its lifetime).
    /// Construction failures (e.g. the PJRT stub refusing to load) are
    /// reported here, not deferred.
    pub fn start(
        make_core: impl FnOnce() -> Result<ServerCore> + Send + 'static,
    ) -> Result<Server> {
        let (tx, rx) = channel::<Control>();
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let handle_ctl = tx.clone();
        let load = Arc::new(LoadBoard::default());
        let board = Arc::clone(&load);
        let engine_thread = std::thread::spawn(move || -> ReportParts {
            let mut core = match make_core() {
                Ok(c) => {
                    let _ = ready_tx.send(Ok(()));
                    c
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return ReportParts {
                        recorder: Recorder::new(),
                        label: "failed".to_string(),
                        engine_epoch: 0,
                        engine_uptime_s: 0.0,
                        queue_cap: None,
                        aborted: false,
                    };
                }
            };
            let mut draining = false;
            loop {
                loop {
                    match rx.try_recv() {
                        Ok(ctl) => {
                            if apply_control(&mut core, ctl, &handle_ctl) {
                                draining = true;
                            }
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            draining = true;
                            break;
                        }
                    }
                }
                board.publish(&core.load());
                // Contain backend failures (the PJRT adapter surfaces
                // runtime errors as panics): close every stream with a
                // terminal event instead of unwinding the whole thread.
                let progressed = match std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| core.step()),
                ) {
                    Ok(p) => p,
                    Err(_) => return core.into_aborted_parts(),
                };
                if !progressed {
                    if draining {
                        break;
                    }
                    // Idle: block until the next control message.
                    match rx.recv() {
                        Ok(ctl) => {
                            if apply_control(&mut core, ctl, &handle_ctl) {
                                draining = true;
                            }
                        }
                        Err(_) => break,
                    }
                }
            }
            core.finish_parts()
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Server {
                tx,
                engine_thread: Some(engine_thread),
                load,
            }),
            Ok(Err(msg)) => {
                let _ = engine_thread.join();
                Err(anyhow!("server failed to start: {msg}"))
            }
            Err(_) => {
                let _ = engine_thread.join();
                Err(anyhow!("server engine thread died during startup"))
            }
        }
    }

    /// Start over the simulated backend with `cfg`'s policy scheduler.
    pub fn start_sim(cfg: ServingConfig, seed: u64) -> Result<Server> {
        Server::start(move || Ok(ServerCore::sim(cfg, seed)))
    }

    /// Start over a cluster of `replicas` unified sim workers, with live
    /// submissions routed by `router` (a [`crate::engine::router_by_name`]
    /// name).
    pub fn start_sim_replicated(
        cfg: ServingConfig,
        replicas: u32,
        seed: u64,
        router: &str,
    ) -> Result<Server> {
        let name = router.to_string();
        if crate::engine::router_by_name(&name).is_none() {
            return Err(anyhow!("unknown router `{name}`"));
        }
        Server::start(move || {
            let router = crate::engine::router_by_name(&name).expect("validated above");
            Ok(ServerCore::sim_replicated(cfg, replicas, seed, router))
        })
    }

    /// Submit a request; blocks briefly for the engine's accept/reject
    /// decision (backpressure is synchronous).
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        opts: SubmitOptions,
    ) -> std::result::Result<RequestHandle, SubmitError> {
        let (reply, reply_rx) = channel();
        if self
            .tx
            .send(Control::Submit {
                prompt,
                opts,
                reply,
            })
            .is_err()
        {
            return Err(SubmitError::ShuttingDown);
        }
        match reply_rx.recv() {
            Ok(res) => res,
            Err(_) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Live, non-destructive metrics snapshot from the engine thread
    /// ([`ServerCore::report_snapshot`]). `None` when the engine thread
    /// is gone.
    pub fn report_snapshot(&self) -> Option<Report> {
        Some(self.snapshot_parts()?.into_report())
    }

    /// Live snapshot pieces (pre-rendering; mergeable across shards).
    pub fn snapshot_parts(&self) -> Option<ReportParts> {
        let (reply, reply_rx) = channel();
        self.tx.send(Control::Report(reply)).ok()?;
        reply_rx.recv().ok()
    }

    /// This server's live load board (engine-thread-published signals).
    pub fn load_board(&self) -> &Arc<LoadBoard> {
        &self.load
    }

    /// Drain in-flight and queued work, stop the engine thread, and
    /// return the final report.
    pub fn shutdown(self) -> Result<Report> {
        Ok(self.shutdown_parts()?.into_report())
    }

    /// Drain and return the report pieces (pre-rendering).
    pub fn shutdown_parts(mut self) -> Result<ReportParts> {
        let _ = self.tx.send(Control::Shutdown);
        let h = self.engine_thread.take().expect("engine thread already joined");
        h.join().map_err(|_| anyhow!("engine thread panicked"))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Control::Shutdown);
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

/// N independent engine shards behind one submit surface.
///
/// Each shard is a full [`Server`] — its own topology slice and engine
/// thread behind its own bounded control queue — so N shards give N
/// concurrent engine loops instead of one serialized control channel.
/// Submissions are routed at submit time through the same
/// [`Router`] seam the cluster uses for worker dispatch, against each
/// shard's live [`LoadBoard`]; `report_snapshot`/`shutdown` merge the
/// per-shard [`ReportParts`] exactly as cluster workers merge at drain.
///
/// A 1-shard instance (also via `From<Server>`) adds no overhead beyond
/// a vector index — the HTTP transport always runs over this type.
pub struct ShardedServer {
    shards: Vec<Server>,
    router: Mutex<Box<dyn Router + Send>>,
}

impl From<Server> for ShardedServer {
    fn from(server: Server) -> ShardedServer {
        ShardedServer::single(server)
    }
}

impl ShardedServer {
    /// Wrap one server; routing is trivial (everything goes to shard 0).
    pub fn single(server: Server) -> ShardedServer {
        ShardedServer {
            shards: vec![server],
            router: Mutex::new(router_by_name("round-robin").expect("built-in router")),
        }
    }

    /// Start `shards` engine shards. `make(i)` builds shard *i*'s core
    /// constructor (run on that shard's engine thread); give each shard
    /// a distinct seed and `ServerCore::with_id_stride(i, shards)` so
    /// request ids stay globally unique. `router` is a
    /// [`router_by_name`] name.
    pub fn start<G>(
        shards: usize,
        router: &str,
        make: impl Fn(usize) -> G,
    ) -> Result<ShardedServer>
    where
        G: FnOnce() -> Result<ServerCore> + Send + 'static,
    {
        let n = shards.max(1);
        let router =
            router_by_name(router).ok_or_else(|| anyhow!("unknown router `{router}`"))?;
        let mut servers = Vec::with_capacity(n);
        for i in 0..n {
            servers.push(Server::start(make(i))?);
        }
        Ok(ShardedServer {
            shards: servers,
            router: Mutex::new(router),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard a routing decision would pick right now (index into the
    /// shard list). Single shard short-circuits without touching the
    /// router.
    fn pick_shard(&self, prompt_len: usize, opts: &SubmitOptions) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        let candidates: Vec<RouteCandidate> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| s.load.candidate(i))
            .collect();
        // Probe request for the router's load heuristics. Clamped to the
        // constructor's ≥1 invariants; id/arrival are never read by the
        // built-in routers and never reach an engine.
        let probe = Request::new(
            u64::MAX,
            0.0,
            prompt_len.max(1) as u64,
            opts.max_new_tokens.max(1),
        );
        let mut router = self.router.lock().unwrap_or_else(|e| e.into_inner());
        router.route(&probe, &candidates).min(self.shards.len() - 1)
    }

    /// Route and submit: picks a shard against live load signals, then
    /// applies that shard's validation + backpressure.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        opts: SubmitOptions,
    ) -> std::result::Result<RequestHandle, SubmitError> {
        let shard = self.pick_shard(prompt.len(), &opts);
        self.shards[shard].submit(prompt, opts)
    }

    /// Live merged snapshot across all shards. `None` when any shard's
    /// engine thread is gone.
    pub fn report_snapshot(&self) -> Option<Report> {
        let mut acc: Option<ReportParts> = None;
        for s in &self.shards {
            let p = s.snapshot_parts()?;
            match &mut acc {
                None => acc = Some(p),
                Some(a) => a.merge(&p),
            }
        }
        let mut p = acc?;
        if self.shards.len() > 1 {
            p.label = format!("{}x{}", self.shards.len(), p.label);
        }
        Some(p.into_report())
    }

    /// Drain every shard and merge the final reports (same fold as the
    /// cluster's worker merge: recorders sum, duration/uptime max,
    /// queue caps sum).
    pub fn shutdown(self) -> Result<Report> {
        let n = self.shards.len();
        let mut acc: Option<ReportParts> = None;
        for s in self.shards {
            let p = s.shutdown_parts()?;
            match &mut acc {
                None => acc = Some(p),
                Some(a) => a.merge(&p),
            }
        }
        let mut p = acc.expect("at least one shard");
        if n > 1 {
            p.label = format!("{n}x{}", p.label);
        }
        Ok(p.into_report())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::engine::{IterationBatch, MAX_SIM_TIME};
    use crate::hw::PartitionPlan;
    use crate::sim::{DispatchMode, ExecResult, SpatialResult};

    fn cfg() -> ServingConfig {
        ServingConfig::default_8b().with_policy(Policy::VllmChunked)
    }

    /// Sim backend with a compiled-runtime-style context bound, to
    /// exercise the `max_context` submission guard.
    struct CappedSim(SimBackend);

    impl ExecutionBackend for CappedSim {
        fn name(&self) -> &'static str {
            "capped-sim"
        }

        fn run_aggregated(
            &mut self,
            batch: &IterationBatch<'_>,
            sms: u32,
            mode: DispatchMode,
        ) -> ExecResult {
            self.0.run_aggregated(batch, sms, mode)
        }

        fn run_spatial(
            &mut self,
            batch: &IterationBatch<'_>,
            plan: &PartitionPlan,
        ) -> SpatialResult {
            self.0.run_spatial(batch, plan)
        }

        fn max_context(&self) -> Option<u64> {
            Some(64)
        }

        fn kv_transfer_time(&self, tokens: u64) -> f64 {
            self.0.kv_transfer_time(tokens)
        }
    }

    fn prompt(n: usize) -> Vec<i32> {
        (0..n).map(|i| (i % 911) as i32).collect()
    }

    #[test]
    fn submit_validates_and_defaults() {
        let mut s = ServerCore::sim(cfg(), 1);
        assert!(matches!(
            s.submit(Vec::new(), SubmitOptions::default()),
            Err(SubmitError::Rejected(_))
        ));
        assert!(matches!(
            s.submit(
                prompt(4),
                SubmitOptions {
                    max_new_tokens: 0,
                    ..Default::default()
                }
            ),
            Err(SubmitError::Rejected(_))
        ));
        assert!(matches!(
            s.submit(
                prompt(4),
                SubmitOptions {
                    arrival: Some(f64::NAN),
                    ..Default::default()
                }
            ),
            Err(SubmitError::Rejected(_))
        ));
        // Arrivals past the divergence guard (or negative) would wedge
        // the engine clock: rejected up front.
        for bad in [-1.0, MAX_SIM_TIME * 2.0, f64::INFINITY] {
            assert!(matches!(
                s.submit(
                    prompt(4),
                    SubmitOptions {
                        arrival: Some(bad),
                        ..Default::default()
                    }
                ),
                Err(SubmitError::Rejected(_))
            ));
        }
        let h = s.submit(prompt(4), SubmitOptions::default()).unwrap();
        assert_eq!(h.id(), 0);
        assert_eq!(s.queued(), 1);
    }

    #[test]
    fn backend_max_context_bounds_submissions() {
        let c = cfg();
        let backend = Box::new(CappedSim(SimBackend::from_config(&c, 1)));
        let mut s = ServerCore::new(c.clone(), scheduler_for(&c), backend);
        // 60-token prompt + 8 output tokens > 64: rejected up front.
        assert!(matches!(
            s.submit(
                prompt(60),
                SubmitOptions {
                    max_new_tokens: 8,
                    ..Default::default()
                }
            ),
            Err(SubmitError::Rejected(_))
        ));
        // Within the bound: served normally.
        let h = s
            .submit(
                prompt(32),
                SubmitOptions {
                    max_new_tokens: 8,
                    ..Default::default()
                },
            )
            .unwrap();
        s.run_to_idle();
        assert_eq!(h.collect().len(), 8);
    }

    #[test]
    fn backpressure_returns_queue_full() {
        let mut s = ServerCore::sim(cfg(), 1).with_queue_depth(2);
        s.submit(prompt(8), SubmitOptions::default()).unwrap();
        s.submit(prompt(8), SubmitOptions::default()).unwrap();
        let err = s.submit(prompt(8), SubmitOptions::default()).unwrap_err();
        assert_eq!(err, SubmitError::QueueFull { depth: 2 });
        // Draining the queue frees capacity again.
        s.run_to_idle();
        assert!(s.submit(prompt(8), SubmitOptions::default()).is_ok());
    }

    #[test]
    fn tokens_stream_with_monotone_timestamps_and_done() {
        let mut s = ServerCore::sim(cfg(), 1);
        let h = s
            .submit(
                prompt(512),
                SubmitOptions {
                    max_new_tokens: 8,
                    ..Default::default()
                },
            )
            .unwrap();
        s.run_to_idle();
        let events = h.collect_events();
        assert_eq!(events.len(), 9, "8 tokens + Done");
        let times: Vec<f64> = events
            .iter()
            .filter_map(|e| match e {
                TokenEvent::Token { at, .. } => Some(*at),
                TokenEvent::Done { .. } => None,
            })
            .collect();
        assert_eq!(times.len(), 8);
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(
            events.last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Completed
            })
        );
        s.engine().check_invariants().unwrap();
    }

    #[test]
    fn cancel_pending_and_running() {
        let mut s = ServerCore::sim(cfg(), 1);
        let opts = SubmitOptions {
            max_new_tokens: 64,
            ..Default::default()
        };
        let h1 = s.submit(prompt(2048), opts.clone()).unwrap();
        let h2 = s.submit(prompt(2048), opts).unwrap();
        // Cancel h2 while still pending.
        assert!(s.cancel(h2.id()));
        // Run a couple of iterations so h1 is admitted, then cancel it.
        s.step();
        assert!(s.cancel(h1.id()));
        assert!(!s.cancel(h1.id()), "double cancel reports unknown");
        s.run_to_idle();
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.engine().metrics.completed, 0);
        let e1 = h1.collect_events();
        assert_eq!(
            e1.last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Cancelled
            })
        );
        s.engine().check_invariants().unwrap();
    }

    #[test]
    fn priority_breaks_ties_among_equal_arrivals() {
        let mut s = ServerCore::sim(cfg(), 1);
        let mk = |priority| {
            SubmitOptions {
                max_new_tokens: 4,
                arrival: Some(0.0),
                ..Default::default()
            }
            .with_priority(priority)
        };
        let low = s.submit(prompt(64), mk(0)).unwrap();
        let high = s.submit(prompt(64), mk(5)).unwrap();
        s.run_to_idle();
        let first_of = |h: RequestHandle| match h.collect_events().first().cloned() {
            Some(TokenEvent::Token { at, .. }) => at,
            other => panic!("expected a token, got {other:?}"),
        };
        let (t_low, t_high) = (first_of(low), first_of(high));
        assert!(
            t_high <= t_low,
            "high priority ({t_high}) must not start after low ({t_low})"
        );
    }

    #[test]
    fn cohort_orders_by_class_then_priority_then_arrival() {
        let entry = |id, class, priority, arrival, seq| PendingEntry {
            req: Request::new(id, arrival, 8, 4).with_class(class),
            priority,
            seq,
        };
        // Priority orders the due cohort even across distinct arrivals
        // (the old dequeue only honored it on exact arrival ties).
        let low_early = entry(0, SloClass::Standard, 0, 1.0, 0);
        let high_late = entry(1, SloClass::Standard, 5, 2.0, 1);
        assert_eq!(cohort_order(&high_late, &low_early, 3.0), Ordering::Less);
        // Class outranks priority.
        let lat = entry(2, SloClass::Latency, -3, 2.0, 2);
        assert_eq!(cohort_order(&lat, &high_late, 3.0), Ordering::Less);
        // Single class + equal priority: arrival, then submission order —
        // pure FCFS, so legacy traffic admits exactly as before.
        let a = entry(3, SloClass::Batch, 0, 1.0, 3);
        let b = entry(4, SloClass::Batch, 0, 1.0, 4);
        assert_eq!(cohort_order(&a, &b, 3.0), Ordering::Less);
        assert_eq!(cohort_order(&b, &a, 3.0), Ordering::Greater);
    }

    #[test]
    fn aging_promotes_batch_class_past_fresh_latency() {
        let entry = |id, class, arrival, seq| PendingEntry {
            req: Request::new(id, arrival, 8, 4).with_class(class),
            priority: 0,
            seq,
        };
        let stale_batch = entry(0, SloClass::Batch, 0.0, 0);
        // Freshly queued: latency outranks batch.
        let fresh_latency = entry(1, SloClass::Latency, 9.0, 1);
        assert_eq!(
            cohort_order(&fresh_latency, &stale_batch, 10.0),
            Ordering::Less
        );
        // After 2×CLASS_AGING_S of queueing the batch entry has promoted
        // to latency rank; the arrival tie-break then favors it — the
        // starvation bound: batch work always eventually admits first.
        let later_latency = entry(2, SloClass::Latency, 2.0 * CLASS_AGING_S + 4.0, 2);
        assert_eq!(
            cohort_order(&stale_batch, &later_latency, 2.0 * CLASS_AGING_S + 5.0),
            Ordering::Less
        );
    }

    #[test]
    fn priority_orders_admission_within_due_cohort() {
        // The filler's first prefill iteration advances the clock past
        // both later arrivals, so they become due *together* — the old
        // dequeue would admit strictly by arrival, ignoring priority.
        let mut c = cfg();
        c.token_budget = 64;
        let mut s = ServerCore::sim(c, 1);
        let _filler = s
            .submit(
                prompt(256),
                SubmitOptions {
                    max_new_tokens: 4,
                    arrival: Some(0.0),
                    ..Default::default()
                },
            )
            .unwrap();
        let mk = |arrival: f64, priority: i32| {
            SubmitOptions {
                max_new_tokens: 2,
                arrival: Some(arrival),
                ..Default::default()
            }
            .with_priority(priority)
        };
        let low = s.submit(prompt(64), mk(1e-6, 0)).unwrap();
        let high = s.submit(prompt(64), mk(2e-6, 7)).unwrap();
        s.run_to_idle();
        let first_of = |h: RequestHandle| match h.collect_events().first().cloned() {
            Some(TokenEvent::Token { at, .. }) => at,
            other => panic!("expected a token, got {other:?}"),
        };
        let (t_low, t_high) = (first_of(low), first_of(high));
        assert!(
            t_high < t_low,
            "high priority ({t_high}) must beat low ({t_low}) within the due cohort"
        );
    }

    #[test]
    fn class_orders_admission_within_due_cohort() {
        let mut c = cfg();
        c.token_budget = 64;
        let mut s = ServerCore::sim(c, 1);
        let _filler = s
            .submit(
                prompt(256),
                SubmitOptions {
                    max_new_tokens: 4,
                    arrival: Some(0.0),
                    ..Default::default()
                },
            )
            .unwrap();
        let mk = |arrival: f64, class: SloClass| {
            SubmitOptions {
                max_new_tokens: 2,
                arrival: Some(arrival),
                ..Default::default()
            }
            .with_class(class)
        };
        // Batch-class submitted (and arriving) first, latency second.
        let batch = s.submit(prompt(64), mk(1e-6, SloClass::Batch)).unwrap();
        let latency = s.submit(prompt(64), mk(2e-6, SloClass::Latency)).unwrap();
        s.run_to_idle();
        let first_of = |h: RequestHandle| match h.collect_events().first().cloned() {
            Some(TokenEvent::Token { at, .. }) => at,
            other => panic!("expected a token, got {other:?}"),
        };
        let (t_batch, t_latency) = (first_of(batch), first_of(latency));
        assert!(
            t_latency < t_batch,
            "latency class ({t_latency}) must beat batch ({t_batch}) within the due cohort"
        );
        let rep = s.finish();
        assert_eq!(rep.class(SloClass::Latency).completed, 1);
        assert_eq!(rep.class(SloClass::Batch).completed, 1);
        assert_eq!(rep.class(SloClass::Standard).completed, 1, "filler");
    }

    #[test]
    fn oversized_prompt_stream_reports_dropped() {
        let mut c = cfg();
        c.gpu_mem_util = 0.25; // tiny KV
        let kv_tokens = c.kv_capacity_tokens() as usize;
        let mut s = ServerCore::sim(c, 1);
        let h = s.submit(prompt(kv_tokens * 2), SubmitOptions::default()).unwrap();
        s.run_to_idle();
        assert_eq!(
            h.collect_events().last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Dropped
            })
        );
        assert_eq!(s.engine().dropped, 1);
    }

    #[test]
    fn slo_attainment_flows_into_report() {
        let mut s = ServerCore::sim(cfg(), 1);
        let h = s
            .submit(
                prompt(256),
                SubmitOptions {
                    max_new_tokens: 8,
                    ..Default::default()
                }
                .with_slo_tbt_ms(1e-6), // impossibly tight: all violate
            )
            .unwrap();
        s.run_to_idle();
        assert_eq!(h.collect().len(), 8);
        let rep = s.finish();
        let att = rep.slo_attainment.expect("SLO was declared");
        assert!(att < 0.5, "tight SLO must show violations: {att}");
    }

    #[test]
    fn threaded_server_streams_and_drains_on_shutdown() {
        let server = Server::start_sim(cfg(), 1).unwrap();
        let handles: Vec<RequestHandle> = (0..6)
            .map(|i| {
                server
                    .submit(
                        prompt(128 + i * 17),
                        SubmitOptions {
                            max_new_tokens: 5,
                            ..Default::default()
                        },
                    )
                    .unwrap()
            })
            .collect();
        // Shut down immediately: drain must still finish everything.
        let report = server.shutdown().unwrap();
        assert_eq!(report.completed, 6);
        assert!(report.ttft.mean > 0.0);
        for h in handles {
            assert_eq!(h.collect().len(), 5);
        }
    }

    #[test]
    fn threaded_cancel_via_handle() {
        let server = Server::start_sim(cfg(), 1).unwrap();
        let h = server
            .submit(
                prompt(8000),
                SubmitOptions {
                    max_new_tokens: 100_000,
                    ..Default::default()
                },
            )
            .unwrap();
        assert!(h.cancel());
        let events = h.collect_events();
        assert_eq!(
            events.last(),
            Some(&TokenEvent::Done {
                reason: FinishReason::Cancelled
            })
        );
        let report = server.shutdown().unwrap();
        assert_eq!(report.completed, 0);
    }
}
