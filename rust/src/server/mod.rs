//! Threaded serving front-end for the real PJRT engine.
//!
//! The coordinator owns the event loop: a dedicated engine thread runs
//! continuous batching over the PJRT runtime while client threads submit
//! requests through an mpsc queue and receive their tokens over per-
//! request streaming channels. This is the "router" face of the system —
//! the equivalent of vLLM's front-end, minus HTTP (no network stack in
//! the offline vendor set; the channel protocol is the seam where one
//! would bolt it on).

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::pjrt::{TinyRuntime, MAX_SLOTS};

/// A streamed token event.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenEvent {
    /// One generated token.
    Token(i32),
    /// Generation finished (EOS/max tokens).
    Done,
}

/// A submitted request: prompt + generation bound + the stream to answer
/// on.
struct Submission {
    prompt: Vec<i32>,
    max_new_tokens: usize,
    stream: Sender<TokenEvent>,
}

enum Control {
    Submit(Submission),
    Shutdown,
}

/// Handle the client holds for one in-flight request.
pub struct ResponseStream {
    rx: Receiver<TokenEvent>,
    pub submitted_at: Instant,
}

impl ResponseStream {
    /// Block until the request completes; returns all tokens.
    pub fn collect(self) -> Vec<i32> {
        let mut out = Vec::new();
        while let Ok(ev) = self.rx.recv() {
            match ev {
                TokenEvent::Token(t) => out.push(t),
                TokenEvent::Done => break,
            }
        }
        out
    }

    /// Non-blocking poll.
    pub fn try_next(&self) -> Option<TokenEvent> {
        self.rx.try_recv().ok()
    }
}

/// The server: spawn once, submit from any thread.
pub struct Server {
    tx: Sender<Control>,
    engine_thread: Option<JoinHandle<Result<()>>>,
}

struct ActiveSlot {
    length: usize,
    produced: usize,
    max_new: usize,
    next_token: i32,
    stream: Sender<TokenEvent>,
}

impl Server {
    /// Start the engine loop on its own thread. The runtime is
    /// constructed *on* that thread via `make_rt` (PJRT handles are not
    /// `Send`; the engine thread owns the device for its lifetime —
    /// exactly the single-dispatcher model the paper's CPU loop uses).
    /// `lookahead` is the number of decode steps run between admission
    /// points (§4.3's look-ahead).
    pub fn start(
        make_rt: impl FnOnce() -> Result<TinyRuntime> + Send + 'static,
        lookahead: u32,
    ) -> Server {
        let (tx, rx) = channel::<Control>();
        let engine_thread = std::thread::spawn(move || -> Result<()> {
            let mut rt = make_rt()?;
            let mut queue: VecDeque<Submission> = VecDeque::new();
            let mut slots: Vec<Option<ActiveSlot>> = (0..MAX_SLOTS).map(|_| None).collect();
            let mut shutdown = false;
            loop {
                // Drain the control queue (non-blocking while busy; block
                // when idle to avoid spinning).
                let idle =
                    queue.is_empty() && slots.iter().all(|s| s.is_none());
                if idle {
                    if shutdown {
                        return Ok(());
                    }
                    match rx.recv() {
                        Ok(Control::Submit(s)) => queue.push_back(s),
                        Ok(Control::Shutdown) | Err(_) => return Ok(()),
                    }
                }
                while let Ok(ctl) = rx.try_recv() {
                    match ctl {
                        Control::Submit(s) => queue.push_back(s),
                        Control::Shutdown => shutdown = true,
                    }
                }

                // Admission: fill free slots while occupancy is low; one
                // per span under load (decode priority).
                let active = slots.iter().filter(|s| s.is_some()).count();
                let n_admit = if active < MAX_SLOTS / 2 {
                    MAX_SLOTS - active
                } else {
                    1
                };
                for _ in 0..n_admit {
                    let Some(sub) = queue.pop_front() else { break };
                    let Some(idx) = slots.iter().position(|s| s.is_none()) else {
                        queue.push_front(sub);
                        break;
                    };
                    let prompt_len = sub.prompt.len();
                    let pre = rt.prefill(&sub.prompt)?;
                    rt.install_slot(idx, prompt_len, &pre.k, &pre.v);
                    let _ = sub.stream.send(TokenEvent::Token(pre.next_token));
                    if sub.max_new_tokens <= 1 {
                        let _ = sub.stream.send(TokenEvent::Done);
                        rt.clear_slot(idx);
                        continue;
                    }
                    slots[idx] = Some(ActiveSlot {
                        length: prompt_len,
                        produced: 1,
                        max_new: sub.max_new_tokens,
                        next_token: pre.next_token,
                        stream: sub.stream,
                    });
                }

                // Look-ahead decode span.
                if slots.iter().any(|s| s.is_some()) {
                    for _ in 0..lookahead.max(1) {
                        let mut tokens = [0i32; MAX_SLOTS];
                        let mut lengths = [0i32; MAX_SLOTS];
                        for (i, s) in slots.iter().enumerate() {
                            if let Some(s) = s {
                                tokens[i] = s.next_token;
                                lengths[i] = s.length as i32;
                            }
                        }
                        let next = rt.decode_step(&tokens, &lengths)?;
                        for i in 0..MAX_SLOTS {
                            let finished = {
                                let Some(s) = slots[i].as_mut() else { continue };
                                s.length += 1;
                                s.next_token = next[i];
                                s.produced += 1;
                                let _ = s.stream.send(TokenEvent::Token(next[i]));
                                s.produced >= s.max_new
                                    || s.length + 1 >= rt.meta.max_context
                            };
                            if finished {
                                let s = slots[i].take().unwrap();
                                let _ = s.stream.send(TokenEvent::Done);
                                rt.clear_slot(i);
                            }
                        }
                        if slots.iter().all(|s| s.is_none()) {
                            break;
                        }
                    }
                }
            }
        });
        Server {
            tx,
            engine_thread: Some(engine_thread),
        }
    }

    /// Submit a request; returns the token stream handle.
    pub fn submit(&self, prompt: Vec<i32>, max_new_tokens: usize) -> ResponseStream {
        let (stx, srx) = channel();
        let _ = self.tx.send(Control::Submit(Submission {
            prompt,
            max_new_tokens,
            stream: stx,
        }));
        ResponseStream {
            rx: srx,
            submitted_at: Instant::now(),
        }
    }

    /// Drain in-flight work and stop the engine thread.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Control::Shutdown);
        if let Some(h) = self.engine_thread.take() {
            h.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Control::Shutdown);
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}
