//! HTTP/OpenAI-compatible streaming transport over [`ShardedServer`].
//!
//! The offline image has no crates.io, so this is a dependency-free
//! HTTP/1.1 server on `std::net` with the in-crate
//! [`crate::util::json`] module as the wire format. Two accept paths
//! share every handler, parser and response builder:
//!
//! - **pooled** (default, unix): [`HttpConfig::pool_workers`] threads
//!   run a `poll(2)` readiness loop over non-blocking sockets
//!   ([`crate::server::pool`]). Connections are HTTP/1.1 **keep-alive**:
//!   parsed incrementally per readiness event ([`parse_buffered`]) and
//!   served repeatedly on the same socket until the peer sends
//!   `Connection: close`, goes idle past [`HttpConfig::idle_timeout`],
//!   or the server drains. SSE rides the same non-blocking write path
//!   with per-connection output buffers, so a slow reader stalls only
//!   its own connection, never a worker.
//! - **thread-per-connection** (`pool_workers = 0`, and non-unix): the
//!   retained baseline — blocking sockets, one thread per accepted
//!   connection, `Connection: close` per request.
//!
//! It is the network front door to the one request lifecycle in this
//! crate — every completion goes through [`ShardedServer::submit`]
//! (routing across N engine shards, each a full [`Server`]) into
//! [`ServerCore`] over whatever
//! [`ServingTopology`](crate::engine::ServingTopology) each shard was
//! started with, so the transport composes with the sim backend, the
//! PJRT backend, and replicated/disaggregated clusters without any
//! special cases.
//!
//! # Endpoints
//!
//! - `POST /v1/completions` — OpenAI-style completion. `"stream": false`
//!   returns one JSON body; `"stream": true` returns Server-Sent Events
//!   (`data: {chunk}\n\n` per token, then a finish chunk and
//!   `data: [DONE]\n\n`). There is no tokenizer in this reproduction:
//!   `prompt` is either an array of integer token ids or a string
//!   (mapped byte-per-token, verbatim byte values), and completion
//!   "text" is the generated token ids space-joined, with the raw ids
//!   in `token_ids`.
//!   Trace-replay / QoS extensions: `arrival` (engine-clock seconds),
//!   `slo_class` (`"latency"|"standard"|"batch"`, strict — unknown
//!   values are a 400; absent maps to `standard`, byte-identical to the
//!   pre-QoS behavior), `slo_tbt_ms`, `slo_ttft_ms`, `priority`.
//! - `GET /healthz` — liveness.
//! - `GET /metrics` — Prometheus text: transport counters plus a live,
//!   non-destructive engine snapshot ([`Server::report_snapshot`]).
//! - `POST /shutdown` — graceful drain: stop the engine after all
//!   accepted work completes and answer with the final merged
//!   [`Report`] as JSON. `SIGTERM`/`SIGINT` trigger the same drain when
//!   the transport was started with
//!   [`HttpConfig::handle_signals`].
//!
//! There is no authentication anywhere on this surface — `/shutdown`
//! in particular is a one-request kill switch. The transport assumes a
//! trusted network; bind loopback (the CLI default) unless the whole
//! segment is trusted. [`HttpConfig::max_conns`] bounds concurrent
//! connections (excess accepts get `503` + `Connection: close`), so one
//! misbehaving client pool cannot pin every worker.
//!
//! # Error mapping
//!
//! | condition                            | status |
//! |--------------------------------------|--------|
//! | malformed HTTP or JSON, bad fields   | 400    |
//! | unknown route                        | 404    |
//! | wrong method on a known route        | 405    |
//! | body over [`HttpConfig::max_body`]   | 413    |
//! | [`SubmitError::QueueFull`]           | 429    |
//! | draining / engine gone               | 503    |
//!
//! A client that disconnects mid-request cancels its request
//! server-side, so abandoned requests release their slot and KV instead
//! of decoding to completion. On the pooled path the readiness loop
//! observes the hangup directly (`POLLHUP`/EOF on read) — no probing,
//! no per-write socket-mode flips. On the baseline path: the SSE path's
//! next write fails and triggers [`RequestHandle::cancel`]; the
//! non-streaming path probes the socket every [`DISCONNECT_PROBE`]
//! while waiting (note: a half-closed write side reads as a
//! disconnect).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::Report;
use crate::request::SloClass;
use crate::server::{
    FinishReason, HandlePoll, RequestHandle, ShardedServer, SubmitError, SubmitOptions, TokenEvent,
};
use crate::util::json::{self, Json};

#[allow(unused_imports)]
use crate::server::{Server, ServerCore}; // doc links

/// Default cap on one request body (413 beyond it).
pub const DEFAULT_MAX_BODY: usize = 1 << 20;

/// Hard cap on `max_tokens` per completion (400 beyond it). The sim
/// backend has no `max_context`, so without this bound one hostile
/// request could decode until the engine clock trips the
/// `max_engine_time` divergence guard and drains every in-flight
/// stream; 64Ki tokens stays orders of magnitude under the default
/// horizon.
pub const MAX_TOKENS_CAP: u64 = 65_536;

/// Cap on the request line + headers of one request.
const MAX_HEADER_BYTES: usize = 32 * 1024;

/// Accept-loop poll interval while waiting for connections or shutdown.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// How long the accept thread waits for in-flight connection handlers
/// after the engine has drained (they only need to flush final writes).
pub(crate) const CONN_LINGER: Duration = Duration::from_secs(30);

/// Per-socket IO timeouts, so a stalled peer cannot pin a handler thread
/// forever. The pooled path applies the same bound to write *progress*:
/// a connection whose output buffer advances nothing for this long is
/// reaped.
pub(crate) const IO_TIMEOUT: Duration = Duration::from_secs(60);

/// How often a non-streaming handler probes its socket for a client
/// disconnect while the completion is still generating. (The SSE path
/// needs no probe: its per-token writes fail fast on a dead peer.)
const DISCONNECT_PROBE: Duration = Duration::from_millis(250);

/// Default size of the readiness-polled worker pool.
pub const DEFAULT_POOL_WORKERS: usize = 4;

/// Default cap on concurrently handled connections (`--max-conns`).
pub const DEFAULT_MAX_CONNS: usize = 4096;

/// Default keep-alive idle timeout on the pooled path.
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Transport configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Reported as `model` in completion responses.
    pub model: String,
    /// Request-body cap, bytes (413 beyond it).
    pub max_body: usize,
    /// Install a process-wide SIGTERM/SIGINT handler that triggers the
    /// same graceful drain as `POST /shutdown`. The CLI turns this on;
    /// tests and examples leave it off.
    pub handle_signals: bool,
    /// Readiness-polled worker pool size (`--http-workers`). `0` selects
    /// the thread-per-connection `Connection: close` baseline; non-unix
    /// targets always use the baseline.
    pub pool_workers: usize,
    /// Concurrent-connection cap (`--max-conns`); excess accepts are
    /// answered `503` + `Connection: close`. `0` means unlimited.
    pub max_conns: usize,
    /// Pooled path: close a kept-alive connection idle (no request in
    /// progress, nothing buffered) for this long.
    pub idle_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            model: "duetserve".to_string(),
            max_body: DEFAULT_MAX_BODY,
            handle_signals: false,
            pool_workers: DEFAULT_POOL_WORKERS,
            max_conns: DEFAULT_MAX_CONNS,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
        }
    }
}

/// Transport-level counters, exported on `/metrics` alongside the engine
/// snapshot.
#[derive(Debug, Default)]
pub struct HttpStats {
    /// Requests that parsed well enough to be routed.
    pub requests_total: AtomicU64,
    /// Completions accepted into the engine.
    pub completions_total: AtomicU64,
    /// Requests refused without reaching the engine: 4xx (parse errors,
    /// bad routes, backpressure) and 503 while draining.
    pub rejected_total: AtomicU64,
    /// Token events delivered to clients (streaming and non-streaming).
    pub tokens_streamed_total: AtomicU64,
    /// SSE streams currently open.
    pub active_streams: AtomicU64,
    /// Connections currently being handled.
    pub active_connections: AtomicU64,
    /// Requests served on an already-used keep-alive connection (the
    /// 2nd, 3rd, … request on one socket).
    pub keepalive_reuse_total: AtomicU64,
    /// Accepted connections waiting for a pool worker to register them.
    pub pool_queue_depth: AtomicU64,
}

pub(crate) struct Shared {
    /// The engine transport; taken (→ `None`) by whichever path drains
    /// first. Submissions hold the read side only long enough to enqueue.
    pub(crate) server: RwLock<Option<ShardedServer>>,
    /// Serializes [`Shared::drain`] end to end, so a racing second
    /// caller blocks until the report is published instead of observing
    /// the taken-but-not-yet-drained window.
    drain_lock: Mutex<()>,
    /// The final drained report, published exactly once.
    report: Mutex<Option<Report>>,
    /// Set once the drain has been triggered; the accept loop exits on it.
    pub(crate) shutdown: AtomicBool,
    pub(crate) stats: HttpStats,
    pub(crate) cfg: HttpConfig,
}

impl Shared {
    pub(crate) fn server_read(&self) -> std::sync::RwLockReadGuard<'_, Option<ShardedServer>> {
        match self.server.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub(crate) fn report_lock(&self) -> std::sync::MutexGuard<'_, Option<Report>> {
        match self.report.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// The single drain point, shared by `POST /shutdown`, SIGTERM and
    /// [`HttpServer::shutdown`]: take the server, drain the engine
    /// (completing all accepted work), publish the report, then raise the
    /// shutdown flag. Idempotent — concurrent and later callers block on
    /// `drain_lock` until the report is published, then get it.
    pub(crate) fn drain(&self) -> Option<Report> {
        let _serialized = match self.drain_lock.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let taken = match self.server.write() {
            Ok(mut g) => g.take(),
            Err(poisoned) => poisoned.into_inner().take(),
        };
        if let Some(server) = taken {
            match server.shutdown() {
                Ok(rep) => *self.report_lock() = Some(rep),
                Err(e) => eprintln!("http: engine drain failed: {e}"),
            }
        }
        self.shutdown.store(true, Ordering::SeqCst);
        self.report_lock().clone()
    }
}

/// The HTTP front door: bind, accept, and serve until a graceful
/// shutdown drains the engine.
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `server`
    /// — a [`Server`] (via `Into`) or an N-shard [`ShardedServer`] — on
    /// a background accept thread. [`HttpConfig::pool_workers`] selects
    /// the keep-alive pool (default) or the thread-per-connection
    /// baseline.
    pub fn start(
        addr: &str,
        server: impl Into<ShardedServer>,
        cfg: HttpConfig,
    ) -> Result<HttpServer> {
        let server = server.into();
        let listener = TcpListener::bind(addr).map_err(|e| anyhow!("bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow!("set_nonblocking: {e}"))?;
        let local = listener.local_addr().map_err(|e| anyhow!("local_addr: {e}"))?;
        if cfg.handle_signals {
            sig::install();
        }
        let handle_signals = cfg.handle_signals;
        let shared = Arc::new(Shared {
            server: RwLock::new(Some(server)),
            drain_lock: Mutex::new(()),
            report: Mutex::new(None),
            shutdown: AtomicBool::new(false),
            stats: HttpStats::default(),
            cfg,
        });
        let loop_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || run_accept(listener, loop_shared, handle_signals));
        Ok(HttpServer {
            addr: local,
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Transport counters (live).
    pub fn stats(&self) -> &HttpStats {
        &self.shared.stats
    }

    /// Block until a shutdown (`POST /shutdown`, SIGTERM, or
    /// [`shutdown`](HttpServer::shutdown)) has drained the engine;
    /// returns the final merged report.
    pub fn join(mut self) -> Result<Report> {
        let accept = self.accept.take().expect("accept thread already joined");
        accept
            .join()
            .map_err(|_| anyhow!("http accept thread panicked"))?;
        self.shared
            .report_lock()
            .clone()
            .ok_or_else(|| anyhow!("http server stopped without a drain report"))
    }

    /// Trigger the graceful drain programmatically and wait for it.
    pub fn shutdown(self) -> Result<Report> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.join()
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Pick the accept path: the readiness-polled keep-alive pool when
/// configured and supported, else the thread-per-connection baseline.
fn run_accept(listener: TcpListener, shared: Arc<Shared>, handle_signals: bool) {
    #[cfg(unix)]
    {
        let workers = shared.cfg.pool_workers;
        if workers > 0 {
            return crate::server::pool::pool_accept_loop(listener, shared, handle_signals, workers);
        }
    }
    accept_loop(listener, shared, handle_signals);
}

/// Thread-per-connection baseline accept loop (`Connection: close`).
fn accept_loop(listener: TcpListener, shared: Arc<Shared>, handle_signals: bool) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) || (handle_signals && sig::triggered()) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let cap = shared.cfg.max_conns as u64;
                if cap > 0 && shared.stats.active_connections.load(Ordering::SeqCst) >= cap {
                    refuse_over_capacity(&shared, stream);
                    continue;
                }
                shared.stats.active_connections.fetch_add(1, Ordering::SeqCst);
                let conn_shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    handle_connection(&conn_shared, stream);
                    conn_shared.stats.active_connections.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // Drain (no-op when a /shutdown handler already did), then give
    // in-flight handlers a moment to flush: the engine drain guarantees
    // every open stream has received its terminal event.
    shared.drain();
    let t0 = Instant::now();
    while shared.stats.active_connections.load(Ordering::SeqCst) > 0
        && t0.elapsed() < CONN_LINGER
    {
        std::thread::sleep(ACCEPT_POLL);
    }
}

/// Answer an accept beyond [`HttpConfig::max_conns`]: `503` with
/// `Connection: close`, then a short bounded read-drain so closing our
/// side does not turn into a RST racing the response.
pub(crate) fn refuse_over_capacity(shared: &Shared, mut stream: TcpStream) {
    shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
    let body = error_json(503, "connection limit reached (--max-conns); retry later");
    let bytes = response_bytes(
        503,
        "Service Unavailable",
        "application/json",
        body.dump().as_bytes(),
        &[("Retry-After", "1".to_string())],
        "close",
    );
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let _ = stream.write_all(&bytes).and_then(|()| stream.flush());
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = std::io::copy(&mut Read::take(&stream, 1 << 16), &mut std::io::sink());
}

// ---------------------------------------------------------------------
// Request parsing (pure, unit-tested).
// ---------------------------------------------------------------------

/// Why a request could not be read off the socket.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum ReadError {
    /// Protocol violation → 400.
    Malformed(String),
    /// Declared body over the cap → 413.
    TooLarge { limit: usize },
    /// The client closed the connection before sending anything.
    Closed,
}

#[derive(Debug)]
pub(crate) struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Names lowercased; obs-fold continuation lines joined with a space.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// False only for `HTTP/1.0` (keep-alive defaults differ).
    pub http11: bool,
}

impl HttpRequest {
    pub(crate) fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Read one CRLF (or bare-LF) terminated line. `Ok(None)` on EOF. The
/// read itself is capped at the remaining header budget (not just
/// checked afterwards), so a peer streaming an endless line cannot grow
/// the buffer past `MAX_HEADER_BYTES`.
fn read_crlf_line(r: &mut impl BufRead, budget: &mut usize) -> Result<Option<String>, ReadError> {
    let mut buf = Vec::new();
    let n = r
        .by_ref()
        .take(*budget as u64 + 1)
        .read_until(b'\n', &mut buf)
        .map_err(|e| ReadError::Malformed(format!("read: {e}")))?;
    if n == 0 {
        return Ok(None);
    }
    *budget = budget
        .checked_sub(buf.len())
        .ok_or_else(|| ReadError::Malformed("headers exceed 32 KiB".to_string()))?;
    while matches!(buf.last(), Some(b'\n' | b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| ReadError::Malformed("non-UTF-8 header bytes".to_string()))
}

/// Parse the start line + headers of one HTTP/1.x request (obs-fold
/// support, header budget, leading-blank-line leniency). Body handling
/// is the caller's: [`read_request`] blocks for it, the pooled path
/// checks buffered completeness via [`parse_buffered`].
fn read_head(r: &mut impl BufRead) -> Result<HttpRequest, ReadError> {
    let mut budget = MAX_HEADER_BYTES;
    // RFC 9112 §2.2: be lenient about stray blank lines before the
    // request line.
    let start = loop {
        match read_crlf_line(r, &mut budget)? {
            None => return Err(ReadError::Closed),
            Some(l) if l.is_empty() => continue,
            Some(l) => break l,
        }
    };
    let mut parts = start.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m.to_string(), p.to_string(), v),
        _ => return Err(ReadError::Malformed(format!("bad request line `{start}`"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Malformed(format!(
            "unsupported protocol `{version}`"
        )));
    }
    let http11 = version != "HTTP/1.0";
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = read_crlf_line(r, &mut budget)?
            .ok_or_else(|| ReadError::Malformed("connection closed inside headers".to_string()))?;
        if line.is_empty() {
            break;
        }
        if line.starts_with(' ') || line.starts_with('\t') {
            // obs-fold: the line continues the previous header's value.
            let Some(last) = headers.last_mut() else {
                return Err(ReadError::Malformed(
                    "continuation line before any header".to_string(),
                ));
            };
            last.1.push(' ');
            last.1.push_str(line.trim());
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ReadError::Malformed(format!("header without colon `{line}`")));
        };
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(ReadError::Malformed(format!("bad header name `{name}`")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
    Ok(HttpRequest {
        method,
        path,
        headers,
        body: Vec::new(),
        http11,
    })
}

/// Declared body length after framing validation: rejects
/// `Transfer-Encoding`, parses `Content-Length`, enforces `max_body`.
fn body_len(req: &HttpRequest, max_body: usize) -> Result<usize, ReadError> {
    if let Some(te) = req.header("transfer-encoding") {
        return Err(ReadError::Malformed(format!(
            "transfer-encoding `{te}` not supported; send a content-length body"
        )));
    }
    match req.header("content-length") {
        None => Ok(0),
        Some(cl) => {
            let len: usize = cl
                .trim()
                .parse()
                .map_err(|_| ReadError::Malformed(format!("bad content-length `{cl}`")))?;
            if len > max_body {
                return Err(ReadError::TooLarge { limit: max_body });
            }
            Ok(len)
        }
    }
}

/// Does the client want the connection kept open after this response?
/// `Connection: close` always wins; `keep-alive` opts an HTTP/1.0 peer
/// in; otherwise the HTTP/1.1 default (keep) applies.
pub(crate) fn wants_keep_alive(req: &HttpRequest) -> bool {
    match req.header("connection") {
        None => req.http11,
        Some(v) => {
            let mut keep = req.http11;
            for tok in v.split(',') {
                let t = tok.trim();
                if t.eq_ignore_ascii_case("close") {
                    return false;
                }
                if t.eq_ignore_ascii_case("keep-alive") {
                    keep = true;
                }
            }
            keep
        }
    }
}

/// Parse one HTTP/1.x request (start line, headers with obs-fold
/// support, and a `Content-Length` body). `w` carries the interim
/// `100 Continue` when the client sent `Expect: 100-continue` — without
/// it, standards-following clients (curl adds the header for bodies
/// over ~1 KiB) stall before transmitting the body.
pub(crate) fn read_request(
    r: &mut impl BufRead,
    w: &mut impl Write,
    max_body: usize,
) -> Result<HttpRequest, ReadError> {
    let mut req = read_head(r)?;
    let len = body_len(&req, max_body)?;
    if req.header("content-length").is_some() {
        if req
            .header("expect")
            .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
        {
            let _ = write!(w, "HTTP/1.1 100 Continue\r\n\r\n").and_then(|()| w.flush());
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).map_err(|_| {
            ReadError::Malformed(format!(
                "content-length mismatch: body ended before {len} bytes"
            ))
        })?;
        req.body = body;
    }
    Ok(req)
}

/// One incremental parse step over a connection's accumulated read
/// buffer (the pooled path; pure, unit-tested).
#[derive(Debug)]
pub(crate) enum BufParse {
    /// The head is incomplete: wait for more bytes.
    Partial,
    /// Head parsed, body bytes still in flight. `expect_continue` asks
    /// the caller to send the interim `100 Continue` (exactly once).
    PartialBody { expect_continue: bool },
    /// One full request; `usize` is the bytes consumed from the buffer
    /// (pipelined followers remain past it).
    Complete(HttpRequest, usize),
    /// Protocol violation / over-limit: respond and close.
    Fail(ReadError),
}

/// Find the end of the header block: one past the blank line. Accepts
/// CRLF and bare-LF line endings (mixed, like the streaming parser).
fn find_head_end(buf: &[u8]) -> Option<usize> {
    for i in 0..buf.len() {
        if buf[i] == b'\n' {
            if buf[i + 1..].starts_with(b"\r\n") {
                return Some(i + 3);
            }
            if buf[i + 1..].starts_with(b"\n") {
                return Some(i + 2);
            }
        }
    }
    None
}

/// Try to parse one complete request out of `buf` without consuming it.
/// Grammar and limits are shared with the blocking path ([`read_head`] +
/// [`body_len`] run over the buffered head), so both accept paths parse
/// identically by construction.
pub(crate) fn parse_buffered(buf: &[u8], max_body: usize) -> BufParse {
    // RFC 9112 §2.2 leniency: skip stray blank lines between requests.
    let mut start = 0usize;
    loop {
        if buf[start..].starts_with(b"\r\n") {
            start += 2;
        } else if buf[start..].starts_with(b"\n") {
            start += 1;
        } else {
            break;
        }
    }
    let rest = &buf[start..];
    let head_len = match find_head_end(rest) {
        Some(n) => n,
        None => {
            if rest.len() > MAX_HEADER_BYTES {
                return BufParse::Fail(ReadError::Malformed("headers exceed 32 KiB".to_string()));
            }
            return BufParse::Partial;
        }
    };
    let mut head = std::io::Cursor::new(&rest[..head_len]);
    let req = match read_head(&mut head) {
        Ok(r) => r,
        Err(e) => return BufParse::Fail(e),
    };
    let len = match body_len(&req, max_body) {
        Ok(n) => n,
        Err(e) => return BufParse::Fail(e),
    };
    let body_start = start + head_len;
    if buf.len() < body_start + len {
        let expect_continue = req
            .header("expect")
            .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"));
        return BufParse::PartialBody { expect_continue };
    }
    let mut req = req;
    req.body = buf[body_start..body_start + len].to_vec();
    BufParse::Complete(req, body_start + len)
}

// ---------------------------------------------------------------------
// Responses.
// ---------------------------------------------------------------------

fn write_head_conn(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    headers: &[(&str, String)],
    conn: &str,
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Connection: {conn}\r\n\r\n")
}

fn write_head(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    headers: &[(&str, String)],
) -> std::io::Result<()> {
    write_head_conn(w, status, reason, headers, "close")
}

/// Render one full response (head + body) into bytes. Header order and
/// framing are identical on both accept paths — the keep-alive tests pin
/// byte-equality against the fresh-connection baseline.
pub(crate) fn response_bytes(
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    extra: &[(&str, String)],
    conn: &str,
) -> Vec<u8> {
    let mut headers = vec![
        ("Content-Type", content_type.to_string()),
        ("Content-Length", body.len().to_string()),
    ];
    headers.extend(extra.iter().map(|(k, v)| (*k, v.clone())));
    let mut out = Vec::with_capacity(body.len() + 256);
    write_head_conn(&mut out, status, reason, &headers, conn).expect("write to Vec");
    out.extend_from_slice(body);
    out
}

/// JSON response as bytes (the pooled path's buffered writes).
pub(crate) fn json_response_bytes(status: u16, reason: &str, value: &Json, conn: &str) -> Vec<u8> {
    response_bytes(
        status,
        reason,
        "application/json",
        value.dump().as_bytes(),
        &[],
        conn,
    )
}

fn respond(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    extra: &[(&str, String)],
) -> std::io::Result<()> {
    w.write_all(&response_bytes(status, reason, content_type, body, extra, "close"))?;
    w.flush()
}

fn respond_json(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    value: &Json,
) -> std::io::Result<()> {
    respond(w, status, reason, "application/json", value.dump().as_bytes(), &[])
}

/// OpenAI-style error body.
pub(crate) fn error_json(status: u16, message: &str) -> Json {
    let kind = if status < 500 {
        "invalid_request_error"
    } else {
        "server_error"
    };
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("message", Json::string(message)),
            ("type", Json::string(kind)),
            ("code", Json::Num(f64::from(status))),
        ]),
    )])
}

fn reject(shared: &Shared, w: &mut impl Write, status: u16, reason: &str, message: &str) {
    shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
    let _ = respond_json(w, status, reason, &error_json(status, message));
}

/// The drain report as a JSON object (the `POST /shutdown` response
/// body).
pub(crate) fn report_json(rep: &Report) -> Json {
    Json::obj(vec![
        ("system", Json::string(rep.system.clone())),
        ("completed", Json::Num(rep.completed as f64)),
        ("duration_s", Json::Num(rep.duration)),
        ("throughput_rps", Json::Num(rep.throughput_rps)),
        ("token_throughput", Json::Num(rep.token_throughput)),
        ("ttft_mean_s", Json::Num(rep.ttft.mean)),
        ("tbt_mean_s", Json::Num(rep.tbt.mean)),
        ("tbt_p99_s", Json::Num(rep.tbt_p99)),
        ("e2e_mean_s", Json::Num(rep.e2e.mean)),
        ("iterations", Json::Num(rep.iterations as f64)),
        ("spatial_iterations", Json::Num(rep.spatial_iterations as f64)),
        ("mean_sm_util", Json::Num(rep.mean_sm_util)),
        ("mean_hbm_util", Json::Num(rep.mean_hbm_util)),
        ("busy_frac", Json::Num(rep.busy_frac)),
        (
            "slo_attainment",
            rep.slo_attainment.map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "queue_cap",
            rep.queue_cap
                .map(|q| Json::Num(q as f64))
                .unwrap_or(Json::Null),
        ),
        ("engine_epoch", Json::Num(rep.engine_epoch as f64)),
        ("uptime_engine_seconds", Json::Num(rep.engine_uptime_s)),
        ("prefix_hits", Json::Num(rep.prefix_hits as f64)),
        ("prefix_cached_tokens", Json::Num(rep.prefix_cached_tokens as f64)),
        ("prefix_evictions", Json::Num(rep.prefix_evictions as f64)),
        ("prefilled_tokens", Json::Num(rep.prefilled_tokens as f64)),
        ("preemptions", Json::Num(rep.preemptions as f64)),
        ("qos_preemptions", Json::Num(rep.qos_preemptions as f64)),
        ("reconfigs", Json::Num(rep.reconfigs as f64)),
        ("role_occupancy_seconds", role_occupancy_json(rep)),
        ("classes", classes_json(rep)),
    ])
}

/// Per-role worker occupancy keyed by role name:
/// `{"unified": …, "prefill": …, "decode": …}` (seconds).
fn role_occupancy_json(rep: &Report) -> Json {
    Json::obj(
        crate::metrics::ROLE_NAMES
            .iter()
            .zip(rep.role_occupancy.iter())
            .map(|(name, &s)| (*name, Json::Num(s)))
            .collect(),
    )
}

/// Per-class goodput series keyed by class name:
/// `{"latency": {"completed": …, "attained": …, "attainment": …,
/// "tbt_p99_s": …}, …}`.
fn classes_json(rep: &Report) -> Json {
    Json::obj(
        SloClass::all()
            .into_iter()
            .map(|class| {
                let c = rep.class(class);
                (
                    class.name(),
                    Json::obj(vec![
                        ("completed", Json::Num(c.completed as f64)),
                        ("attained", Json::Num(c.attained as f64)),
                        (
                            "attainment",
                            c.attainment().map(Json::Num).unwrap_or(Json::Null),
                        ),
                        ("tbt_p99_s", Json::Num(c.tbt_p99)),
                    ]),
                )
            })
            .collect(),
    )
}

fn prom_metric(out: &mut String, name: &str, kind: &str, help: &str, value: f64) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {value}");
}

/// One metric family with a `class="latency|standard|batch"` label per
/// SLO class (the `duetserve_class_*` families).
fn prom_class_family(
    out: &mut String,
    name: &str,
    kind: &str,
    help: &str,
    rep: &Report,
    value: impl Fn(&crate::metrics::ClassReport) -> f64,
) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    for class in SloClass::all() {
        let _ = writeln!(
            out,
            "{name}{{class=\"{}\"}} {}",
            class.name(),
            value(rep.class(class))
        );
    }
}

/// Render the `/metrics` payload: transport counters plus (when the
/// engine is still up, or after drain from the stored report) the engine
/// snapshot. The queue-cap gauge comes from the snapshot itself
/// ([`Report::queue_cap`]), which the engine stamps with the bound it
/// actually enforces — there is no second copy to keep in sync.
pub(crate) fn render_prometheus(rep: Option<&Report>, stats: &HttpStats) -> String {
    let mut out = String::new();
    prom_metric(
        &mut out,
        "duetserve_http_requests_total",
        "counter",
        "HTTP requests routed",
        stats.requests_total.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "duetserve_http_completions_total",
        "counter",
        "Completions accepted into the engine",
        stats.completions_total.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "duetserve_http_rejected_total",
        "counter",
        "Requests refused without reaching the engine (4xx, or 503 while draining)",
        stats.rejected_total.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "duetserve_http_tokens_streamed_total",
        "counter",
        "Token events delivered to clients",
        stats.tokens_streamed_total.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "duetserve_http_active_streams",
        "gauge",
        "SSE streams currently open",
        stats.active_streams.load(Ordering::SeqCst) as f64,
    );
    prom_metric(
        &mut out,
        "duetserve_http_active_connections",
        "gauge",
        "Connections currently being handled",
        stats.active_connections.load(Ordering::SeqCst) as f64,
    );
    prom_metric(
        &mut out,
        "duetserve_http_keepalive_reuse_total",
        "counter",
        "Requests served on an already-used keep-alive connection",
        stats.keepalive_reuse_total.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "duetserve_http_pool_queue_depth",
        "gauge",
        "Accepted connections waiting for a pool worker to register them",
        stats.pool_queue_depth.load(Ordering::SeqCst) as f64,
    );
    if let Some(r) = rep {
        if let Some(cap) = r.queue_cap {
            prom_metric(
                &mut out,
                "duetserve_queue_cap",
                "gauge",
                "Submission-queue bound in effect (--queue-cap)",
                cap as f64,
            );
        }
        prom_metric(
            &mut out,
            "duetserve_engine_completed_total",
            "counter",
            "Requests completed by the engine",
            r.completed as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_iterations_total",
            "counter",
            "Engine iterations executed",
            r.iterations as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_spatial_iterations_total",
            "counter",
            "Iterations run under a spatial SM partition",
            r.spatial_iterations as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_clock_seconds",
            "gauge",
            "Engine-clock time",
            r.duration,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_epoch",
            "gauge",
            "Engine-clock epoch (increments when the idle engine re-bases its \
             clock, re-arming the divergence guard)",
            r.engine_epoch as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_uptime_engine_seconds_total",
            "counter",
            "Total engine-clock seconds elapsed across all epochs",
            r.engine_uptime_s,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_ttft_mean_seconds",
            "gauge",
            "Mean time to first token",
            r.ttft.mean,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_tbt_mean_seconds",
            "gauge",
            "Mean time between tokens",
            r.tbt.mean,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_tbt_p99_seconds",
            "gauge",
            "p99 time between tokens",
            r.tbt_p99,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_sm_util",
            "gauge",
            "Duration-weighted mean SM utilization",
            r.mean_sm_util,
        );
        prom_metric(
            &mut out,
            "duetserve_engine_hbm_util",
            "gauge",
            "Duration-weighted mean HBM utilization",
            r.mean_hbm_util,
        );
        if let Some(att) = r.slo_attainment {
            prom_metric(
                &mut out,
                "duetserve_engine_slo_attainment",
                "gauge",
                "Fraction of SLO-checked gaps within their TBT SLO",
                att,
            );
        }
        prom_metric(
            &mut out,
            "duetserve_prefix_hits_total",
            "counter",
            "Requests seeded with a non-empty cached prefix at admission",
            r.prefix_hits as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_prefix_cached_tokens_total",
            "counter",
            "Prompt tokens served from the prefix cache instead of prefill",
            r.prefix_cached_tokens as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_prefix_evictions_total",
            "counter",
            "Cached-unreferenced KV blocks evicted under allocation pressure",
            r.prefix_evictions as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_prefilled_tokens_total",
            "counter",
            "Prompt tokens actually computed by prefill",
            r.prefilled_tokens as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_preemptions_total",
            "counter",
            "Running requests recompute-preempted under KV exhaustion",
            r.preemptions as f64,
        );
        prom_metric(
            &mut out,
            "duetserve_qos_preemptions_total",
            "counter",
            "Lower-class prefill chunks shed to protect a latency-class decode TBT",
            r.qos_preemptions as f64,
        );
        prom_class_family(
            &mut out,
            "duetserve_class_completed_total",
            "counter",
            "Requests completed, by SLO class",
            r,
            |c| c.completed as f64,
        );
        prom_class_family(
            &mut out,
            "duetserve_class_attained_total",
            "counter",
            "Completed requests that met every declared SLO, by class",
            r,
            |c| c.attained as f64,
        );
        prom_class_family(
            &mut out,
            "duetserve_class_tbt_p99_seconds",
            "gauge",
            "p99 time between tokens, by SLO class",
            r,
            |c| c.tbt_p99,
        );
        prom_metric(
            &mut out,
            "duetserve_reconfigs_total",
            "counter",
            "Worker role reconfigurations performed by the cluster planner",
            r.reconfigs as f64,
        );
        {
            use std::fmt::Write as _;
            let name = "duetserve_role_occupancy_seconds";
            let _ = writeln!(
                &mut out,
                "# HELP {name} Worker-seconds spent in each cluster role"
            );
            let _ = writeln!(&mut out, "# TYPE {name} counter");
            for (role, &s) in crate::metrics::ROLE_NAMES
                .iter()
                .zip(r.role_occupancy.iter())
            {
                let _ = writeln!(&mut out, "{name}{{role=\"{role}\"}} {s}");
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// Connection handling.
// ---------------------------------------------------------------------

fn handle_connection(shared: &Shared, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let req = match read_request(&mut reader, &mut writer, shared.cfg.max_body) {
        Ok(r) => r,
        Err(ReadError::Closed) => return,
        Err(ReadError::Malformed(msg)) => {
            reject(shared, &mut writer, 400, "Bad Request", &msg);
            discard_unread(&mut reader);
            return;
        }
        Err(ReadError::TooLarge { limit }) => {
            reject(
                shared,
                &mut writer,
                413,
                "Payload Too Large",
                &format!("request body exceeds {limit} bytes"),
            );
            discard_unread(&mut reader);
            return;
        }
    };
    shared.stats.requests_total.fetch_add(1, Ordering::Relaxed);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let _ = respond_json(&mut writer, 200, "OK", &healthz_json(shared));
        }
        ("GET", "/metrics") => {
            let body = metrics_body(shared);
            let _ = respond(
                &mut writer,
                200,
                "OK",
                "text/plain; version=0.0.4",
                body.as_bytes(),
                &[],
            );
        }
        ("POST", "/v1/completions") => handle_completion(shared, &mut writer, &req),
        ("POST", "/shutdown") => match shared.drain() {
            Some(rep) => {
                let _ = respond_json(&mut writer, 200, "OK", &report_json(&rep));
            }
            None => {
                let _ = respond_json(
                    &mut writer,
                    500,
                    "Internal Server Error",
                    &error_json(500, "engine drain produced no report"),
                );
            }
        },
        (_, "/healthz" | "/metrics" | "/v1/completions" | "/shutdown") => {
            reject(
                shared,
                &mut writer,
                405,
                "Method Not Allowed",
                &format!("{} not allowed on {}", req.method, req.path),
            );
        }
        _ => {
            reject(
                shared,
                &mut writer,
                404,
                "Not Found",
                &format!("unknown route {} {}", req.method, req.path),
            );
        }
    }
}

/// The `/healthz` body (shared by both accept paths).
pub(crate) fn healthz_json(shared: &Shared) -> Json {
    let draining = shared.shutdown.load(Ordering::SeqCst) || shared.server_read().is_none();
    let status = if draining { "draining" } else { "ok" };
    Json::obj(vec![
        ("status", Json::string(status)),
        ("model", Json::string(shared.cfg.model.clone())),
    ])
}

/// The `/metrics` body (shared by both accept paths): transport counters
/// plus a live engine snapshot, or the stored drain report after drain.
pub(crate) fn metrics_body(shared: &Shared) -> String {
    let snapshot = shared
        .server_read()
        .as_ref()
        .and_then(|s| s.report_snapshot())
        .or_else(|| shared.report_lock().clone());
    render_prometheus(snapshot.as_ref(), &shared.stats)
}

/// After refusing a request whose body was never read (413/400), consume
/// what the peer already sent (bounded, short timeout) so closing our
/// side does not turn into a TCP RST that races the error response.
fn discard_unread(reader: &mut BufReader<TcpStream>) {
    let _ = reader
        .get_ref()
        .set_read_timeout(Some(Duration::from_secs(5)));
    let _ = std::io::copy(&mut reader.by_ref().take(1 << 22), &mut std::io::sink());
}

/// Parsed `/v1/completions` body.
struct CompletionParams {
    prompt: Vec<i32>,
    opts: SubmitOptions,
    stream: bool,
}

fn parse_completion(v: &Json) -> Result<CompletionParams, String> {
    let prompt: Vec<i32> = match v.get("prompt") {
        Some(Json::Str(s)) => s.bytes().map(i32::from).collect(),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|t| {
                t.as_i64()
                    .and_then(|x| i32::try_from(x).ok())
                    .ok_or_else(|| "`prompt` array must hold integer token ids".to_string())
            })
            .collect::<Result<_, _>>()?,
        Some(_) => {
            return Err("`prompt` must be a string or an array of integer token ids".to_string())
        }
        None => return Err("missing `prompt`".to_string()),
    };
    let mut opts = SubmitOptions::default();
    if let Some(mt) = v.get("max_tokens") {
        opts.max_new_tokens = mt
            .as_u64()
            .ok_or_else(|| "`max_tokens` must be a non-negative integer".to_string())?;
        if opts.max_new_tokens > MAX_TOKENS_CAP {
            return Err(format!("`max_tokens` must be <= {MAX_TOKENS_CAP}"));
        }
    }
    if let Some(x) = v.get("slo_class") {
        let s = match x {
            Json::Str(s) => s.as_str(),
            _ => return Err("`slo_class` must be a string".to_string()),
        };
        opts.qos.class = SloClass::parse(s).ok_or_else(|| {
            format!("`slo_class` must be one of \"latency\", \"standard\", \"batch\" (got \"{s}\")")
        })?;
    }
    if let Some(x) = v.get("slo_tbt_ms") {
        opts.qos.slo_tbt_ms = Some(
            x.as_f64()
                .ok_or_else(|| "`slo_tbt_ms` must be a number".to_string())?,
        );
    }
    if let Some(x) = v.get("slo_ttft_ms") {
        opts.qos.slo_ttft_ms = Some(
            x.as_f64()
                .ok_or_else(|| "`slo_ttft_ms` must be a number".to_string())?,
        );
    }
    if let Some(x) = v.get("priority") {
        let p = x
            .as_i64()
            .ok_or_else(|| "`priority` must be an integer".to_string())?;
        opts.qos.priority =
            i32::try_from(p).map_err(|_| "`priority` out of range".to_string())?;
    }
    if let Some(x) = v.get("arrival") {
        opts.arrival = Some(
            x.as_f64()
                .ok_or_else(|| "`arrival` must be engine-clock seconds".to_string())?,
        );
    }
    let stream = match v.get("stream") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err("`stream` must be a boolean".to_string()),
    };
    Ok(CompletionParams {
        prompt,
        opts,
        stream,
    })
}

pub(crate) fn finish_reason_str(reason: FinishReason) -> &'static str {
    match reason {
        // Generation always ends at `max_tokens` in this reproduction, so
        // the OpenAI name for that outcome is `length`.
        FinishReason::Completed => "length",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Dropped => "dropped",
    }
}

/// Token ids space-joined — the `text` stand-in while the reproduction
/// has no detokenizer.
fn token_text(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(" ")
}

pub(crate) fn completion_json(
    id: u64,
    model: &str,
    tokens: &[i32],
    finish: &str,
    prompt_tokens: usize,
) -> Json {
    Json::obj(vec![
        ("id", Json::string(format!("cmpl-{id}"))),
        ("object", Json::string("text_completion")),
        ("created", Json::Num(0.0)),
        ("model", Json::string(model)),
        (
            "choices",
            Json::arr(vec![Json::obj(vec![
                ("index", Json::Num(0.0)),
                ("text", Json::string(token_text(tokens))),
                (
                    "token_ids",
                    Json::arr(tokens.iter().map(|t| Json::Num(f64::from(*t))).collect()),
                ),
                ("finish_reason", Json::string(finish)),
            ])]),
        ),
        (
            "usage",
            Json::obj(vec![
                ("prompt_tokens", Json::Num(prompt_tokens as f64)),
                ("completion_tokens", Json::Num(tokens.len() as f64)),
                (
                    "total_tokens",
                    Json::Num((prompt_tokens + tokens.len()) as f64),
                ),
            ]),
        ),
    ])
}

/// Outcome of validating + submitting one `/v1/completions` request —
/// the seam both accept paths share, so error mapping, limits and
/// transport counters stay identical by construction.
pub(crate) enum CompletionStart {
    /// Terminal (error) response, rendered with the caller's
    /// `Connection` token, ready to write.
    Respond(Vec<u8>),
    /// Accepted into the engine; the caller owns delivery.
    Accepted {
        handle: RequestHandle,
        prompt_tokens: usize,
        stream: bool,
    },
}

/// Parse, validate and submit a completion request. `conn` is the
/// `Connection` token for any error response (`close` on the baseline
/// path; the connection's keep-alive decision on the pooled path).
pub(crate) fn start_completion(shared: &Shared, req: &HttpRequest, conn: &str) -> CompletionStart {
    let fail = |status: u16, reason: &str, msg: &str| {
        shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
        CompletionStart::Respond(json_response_bytes(
            status,
            reason,
            &error_json(status, msg),
            conn,
        ))
    };
    let Ok(body) = std::str::from_utf8(&req.body) else {
        return fail(400, "Bad Request", "body is not UTF-8");
    };
    let parsed = match json::parse(body) {
        Ok(v) => v,
        Err(e) => return fail(400, "Bad Request", &format!("malformed JSON: {e}")),
    };
    let params = match parse_completion(&parsed) {
        Ok(p) => p,
        Err(msg) => return fail(400, "Bad Request", &msg),
    };
    let CompletionParams {
        prompt,
        opts,
        stream,
    } = params;
    let prompt_tokens = prompt.len();
    // Enqueue under the read lock only; streaming happens lock-free so a
    // concurrent drain can complete these requests.
    let submitted = {
        let guard = shared.server_read();
        guard.as_ref().map(|server| server.submit(prompt, opts))
    };
    let Some(submitted) = submitted else {
        return fail(503, "Service Unavailable", "server is draining");
    };
    match submitted {
        Ok(handle) => {
            shared.stats.completions_total.fetch_add(1, Ordering::Relaxed);
            CompletionStart::Accepted {
                handle,
                prompt_tokens,
                stream,
            }
        }
        Err(SubmitError::QueueFull { depth }) => {
            shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
            let body = error_json(
                429,
                &format!("submission queue full (queue-cap {depth}); retry later"),
            );
            CompletionStart::Respond(response_bytes(
                429,
                "Too Many Requests",
                "application/json",
                body.dump().as_bytes(),
                &[("Retry-After", "1".to_string())],
                conn,
            ))
        }
        Err(SubmitError::Rejected(why)) => fail(400, "Bad Request", &why),
        Err(SubmitError::ShuttingDown) => {
            fail(503, "Service Unavailable", "server is shutting down")
        }
    }
}

fn handle_completion(shared: &Shared, w: &mut TcpStream, req: &HttpRequest) {
    match start_completion(shared, req, "close") {
        CompletionStart::Respond(bytes) => {
            let _ = w.write_all(&bytes).and_then(|()| w.flush());
        }
        CompletionStart::Accepted {
            handle,
            prompt_tokens,
            stream,
        } => {
            if stream {
                stream_completion(shared, w, handle, prompt_tokens);
            } else {
                blocking_completion(shared, w, handle, prompt_tokens);
            }
        }
    }
}

/// Non-blocking probe: has the peer closed or reset the connection?
/// Extra buffered request bytes (pipelining attempts) read as alive.
fn client_gone(w: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    if w.set_nonblocking(true).is_err() {
        return false;
    }
    let gone = match w.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => e.kind() != std::io::ErrorKind::WouldBlock,
    };
    let _ = w.set_nonblocking(false);
    gone
}

fn blocking_completion(
    shared: &Shared,
    w: &mut TcpStream,
    handle: RequestHandle,
    prompt_tokens: usize,
) {
    let id = handle.id();
    let mut tokens = Vec::new();
    // If the stream closes without a terminal event (engine abort), the
    // client still gets a well-formed response marked dropped.
    let mut reason = FinishReason::Dropped;
    let mut last_probe = Instant::now();
    loop {
        // Probe the socket on a fixed cadence even while tokens flow:
        // an abandoned non-streaming request must not decode to
        // completion holding a batch slot nobody will read.
        if last_probe.elapsed() >= DISCONNECT_PROBE {
            last_probe = Instant::now();
            if client_gone(w) {
                handle.cancel();
                reason = FinishReason::Cancelled;
                break;
            }
        }
        match handle.next_event_timeout(DISCONNECT_PROBE) {
            HandlePoll::Event(TokenEvent::Token { value, .. }) => tokens.push(value),
            HandlePoll::Event(TokenEvent::Done { reason: r }) => {
                reason = r;
                break;
            }
            HandlePoll::TimedOut => {}
            HandlePoll::Closed => break,
        }
    }
    shared.stats.tokens_streamed_total.fetch_add(tokens.len() as u64, Ordering::Relaxed);
    let response = completion_json(
        id,
        &shared.cfg.model,
        &tokens,
        finish_reason_str(reason),
        prompt_tokens,
    );
    let _ = respond_json(w, 200, "OK", &response);
}

fn stream_completion(
    shared: &Shared,
    w: &mut TcpStream,
    handle: RequestHandle,
    prompt_tokens: usize,
) {
    shared.stats.active_streams.fetch_add(1, Ordering::SeqCst);
    let result = stream_events(shared, w, &handle, prompt_tokens);
    shared.stats.active_streams.fetch_sub(1, Ordering::SeqCst);
    if result.is_err() {
        // The client went away mid-stream: cancel the server-side work so
        // abandoned streams release their slot and KV budget.
        handle.cancel();
    }
}

fn sse_chunk(w: &mut impl Write, payload: &str) -> std::io::Result<()> {
    w.write_all(b"data: ")?;
    w.write_all(payload.as_bytes())?;
    w.write_all(b"\n\n")?;
    w.flush()
}

/// One SSE `data:` frame as bytes (the pooled path appends these to a
/// connection's output buffer).
pub(crate) fn sse_frame(payload: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 8);
    out.extend_from_slice(b"data: ");
    out.extend_from_slice(payload.as_bytes());
    out.extend_from_slice(b"\n\n");
    out
}

/// The SSE response head (status line + stream headers), as bytes.
pub(crate) fn sse_head_bytes() -> Vec<u8> {
    let mut out = Vec::with_capacity(128);
    write_head(
        &mut out,
        200,
        "OK",
        &[
            ("Content-Type", "text/event-stream".to_string()),
            ("Cache-Control", "no-cache".to_string()),
        ],
    )
    .expect("write to Vec");
    out
}

/// One streamed-token SSE chunk (shared by both accept paths).
pub(crate) fn sse_token_json(id: u64, model: &str, value: i32, at: f64) -> Json {
    Json::obj(vec![
        ("id", Json::string(format!("cmpl-{id}"))),
        ("object", Json::string("text_completion")),
        ("model", Json::string(model)),
        (
            "choices",
            Json::arr(vec![Json::obj(vec![
                ("index", Json::Num(0.0)),
                ("text", Json::string(format!("{value} "))),
                ("token_id", Json::Num(f64::from(value))),
                ("at", Json::Num(at)),
                ("finish_reason", Json::Null),
            ])]),
        ),
    ])
}

/// The terminal SSE chunk with finish reason + usage (shared by both
/// accept paths).
pub(crate) fn sse_finish_json(
    id: u64,
    model: &str,
    reason: FinishReason,
    prompt_tokens: usize,
    generated: usize,
) -> Json {
    Json::obj(vec![
        ("id", Json::string(format!("cmpl-{id}"))),
        ("object", Json::string("text_completion")),
        ("model", Json::string(model)),
        (
            "choices",
            Json::arr(vec![Json::obj(vec![
                ("index", Json::Num(0.0)),
                ("text", Json::string("")),
                ("finish_reason", Json::string(finish_reason_str(reason))),
            ])]),
        ),
        (
            "usage",
            Json::obj(vec![
                ("prompt_tokens", Json::Num(prompt_tokens as f64)),
                ("completion_tokens", Json::Num(generated as f64)),
                (
                    "total_tokens",
                    Json::Num((prompt_tokens + generated) as f64),
                ),
            ]),
        ),
    ])
}

fn stream_events(
    shared: &Shared,
    w: &mut TcpStream,
    handle: &RequestHandle,
    prompt_tokens: usize,
) -> std::io::Result<()> {
    w.write_all(&sse_head_bytes())?;
    w.flush()?;
    let id = handle.id();
    let model = shared.cfg.model.as_str();
    let mut generated = 0usize;
    loop {
        let ev = match handle.next_event_timeout(DISCONNECT_PROBE) {
            HandlePoll::Event(ev) => ev,
            HandlePoll::TimedOut => {
                // Queued or mid-prefill: no tokens are being written, so
                // the write path cannot see a disconnect — probe the
                // socket so a vanished client does not hold its queue
                // slot until admission.
                if client_gone(w) {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::BrokenPipe,
                        "client disconnected while waiting for tokens",
                    ));
                }
                continue;
            }
            HandlePoll::Closed => break,
        };
        match ev {
            TokenEvent::Token { value, at } => {
                sse_chunk(w, &sse_token_json(id, model, value, at).dump())?;
                generated += 1;
                shared.stats.tokens_streamed_total.fetch_add(1, Ordering::Relaxed);
            }
            TokenEvent::Done { reason } => {
                let fin = sse_finish_json(id, model, reason, prompt_tokens, generated);
                sse_chunk(w, &fin.dump())?;
                return sse_chunk(w, "[DONE]");
            }
        }
    }
    // Channel closed without a terminal event (engine abort): still end
    // the stream in-protocol.
    sse_chunk(w, "[DONE]")
}

// ---------------------------------------------------------------------
// Signal handling (graceful drain on SIGTERM/SIGINT).
// ---------------------------------------------------------------------

#[cfg(unix)]
pub(crate) mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TRIGGERED: AtomicBool = AtomicBool::new(false);

    // std already links libc on every unix target; declaring signal(2)
    // directly keeps the transport dependency-free. The handler only
    // stores to an atomic, which is async-signal-safe.
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }

    extern "C" fn on_signal(_signum: i32) {
        TRIGGERED.store(true, Ordering::SeqCst);
    }

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }

    pub fn triggered() -> bool {
        TRIGGERED.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
pub(crate) mod sig {
    pub fn install() {}

    pub fn triggered() -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse_str(s: &str) -> Result<HttpRequest, ReadError> {
        read_request(&mut Cursor::new(s.as_bytes().to_vec()), &mut Vec::new(), 1 << 16)
    }

    #[test]
    fn expect_100_continue_gets_interim_response() {
        let mut interim = Vec::new();
        let req = read_request(
            &mut Cursor::new(
                b"POST / HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nok".to_vec(),
            ),
            &mut interim,
            1 << 16,
        )
        .unwrap();
        assert_eq!(req.body, b"ok");
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");
        // No Expect header: nothing interim is written.
        let mut interim = Vec::new();
        read_request(
            &mut Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\nok".to_vec()),
            &mut interim,
            1 << 16,
        )
        .unwrap();
        assert!(interim.is_empty());
    }

    #[test]
    fn parses_request_line_headers_and_body() {
        let req = parse_str(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn header_names_are_lowercased_and_values_trimmed() {
        let req = parse_str("GET / HTTP/1.1\r\nX-Thing:   padded value  \r\n\r\n").unwrap();
        assert_eq!(req.header("x-thing"), Some("padded value"));
    }

    #[test]
    fn folds_continuation_lines() {
        let req = parse_str(
            "GET / HTTP/1.1\r\nX-Folded: first\r\n  second part\r\n\tthird\r\nHost: h\r\n\r\n",
        )
        .unwrap();
        assert_eq!(req.header("x-folded"), Some("first second part third"));
        assert_eq!(req.header("host"), Some("h"));
    }

    #[test]
    fn continuation_before_any_header_is_malformed() {
        let err = parse_str("GET / HTTP/1.1\r\n  dangling\r\n\r\n").unwrap_err();
        assert!(matches!(err, ReadError::Malformed(_)));
    }

    #[test]
    fn content_length_mismatch_is_malformed() {
        // Declares 10 bytes but the connection ends after 4.
        let err = parse_str("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabcd").unwrap_err();
        match err {
            ReadError::Malformed(msg) => assert!(msg.contains("content-length"), "{msg}"),
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn oversized_body_is_too_large() {
        let err = read_request(
            &mut Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 99999\r\n\r\n".to_vec()),
            &mut Vec::new(),
            1024,
        )
        .unwrap_err();
        assert_eq!(err, ReadError::TooLarge { limit: 1024 });
    }

    #[test]
    fn bad_request_lines_are_malformed() {
        for bad in [
            "GARBAGE\r\n\r\n",
            "GET /\r\n\r\n",
            "GET / HTTP/2\r\n\r\n",
            "GET / HTTP/1.1 extra\r\n\r\n",
        ] {
            assert!(
                matches!(parse_str(bad), Err(ReadError::Malformed(_))),
                "`{bad}` must be malformed"
            );
        }
    }

    #[test]
    fn header_without_colon_is_malformed() {
        assert!(matches!(
            parse_str("GET / HTTP/1.1\r\nNoColonHere\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn eof_before_request_is_closed_not_malformed() {
        assert_eq!(parse_str("").unwrap_err(), ReadError::Closed);
    }

    #[test]
    fn leading_blank_lines_are_tolerated() {
        let req = parse_str("\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.path, "/healthz");
    }

    #[test]
    fn transfer_encoding_is_rejected() {
        assert!(matches!(
            parse_str("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn bare_lf_line_endings_are_accepted() {
        let req = parse_str("GET /metrics HTTP/1.1\nHost: x\n\n").unwrap();
        assert_eq!(req.path, "/metrics");
        assert_eq!(req.header("host"), Some("x"));
    }

    #[test]
    fn completion_params_parse_and_validate() {
        let v = json::parse(
            r#"{"prompt":[1,2,3],"max_tokens":7,"stream":true,"slo_tbt_ms":50,"priority":2,"arrival":1.5}"#,
        )
        .unwrap();
        let p = parse_completion(&v).unwrap();
        assert_eq!(p.prompt, vec![1, 2, 3]);
        assert_eq!(p.opts.max_new_tokens, 7);
        assert!(p.stream);
        assert_eq!(p.opts.qos.slo_tbt_ms, Some(50.0));
        assert_eq!(p.opts.qos.priority, 2);
        assert_eq!(p.opts.arrival, Some(1.5));
        // Legacy body without `slo_class`: standard, the pre-QoS default.
        assert_eq!(p.opts.qos.class, SloClass::Standard);

        // String prompts map byte-per-token.
        let v = json::parse(r#"{"prompt":"AB"}"#).unwrap();
        let p = parse_completion(&v).unwrap();
        assert_eq!(p.prompt, vec![65, 66]);
        assert!(!p.stream);
        assert_eq!(p.opts.max_new_tokens, SubmitOptions::default().max_new_tokens);

        for bad in [
            r#"{}"#,
            r#"{"prompt":5}"#,
            r#"{"prompt":[1.5]}"#,
            r#"{"prompt":["a"]}"#,
            r#"{"prompt":[1],"max_tokens":-1}"#,
            r#"{"prompt":[1],"max_tokens":"x"}"#,
            r#"{"prompt":[1],"stream":1}"#,
            r#"{"prompt":[1],"priority":4000000000}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(parse_completion(&v).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn slo_class_parses_strictly() {
        for (body, class) in [
            (r#"{"prompt":[1],"slo_class":"latency"}"#, SloClass::Latency),
            (r#"{"prompt":[1],"slo_class":"standard"}"#, SloClass::Standard),
            (r#"{"prompt":[1],"slo_class":"batch"}"#, SloClass::Batch),
        ] {
            let v = json::parse(body).unwrap();
            assert_eq!(parse_completion(&v).unwrap().opts.qos.class, class);
        }
        let v = json::parse(r#"{"prompt":[1],"slo_class":"latency","slo_ttft_ms":250}"#).unwrap();
        assert_eq!(parse_completion(&v).unwrap().opts.qos.slo_ttft_ms, Some(250.0));
        // Unknown or mistyped classes are a 400, not a silent default.
        for bad in [
            r#"{"prompt":[1],"slo_class":"gold"}"#,
            r#"{"prompt":[1],"slo_class":"Latency"}"#,
            r#"{"prompt":[1],"slo_class":3}"#,
            r#"{"prompt":[1],"slo_ttft_ms":"x"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(parse_completion(&v).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn finish_reasons_map_to_openai_names() {
        assert_eq!(finish_reason_str(FinishReason::Completed), "length");
        assert_eq!(finish_reason_str(FinishReason::Cancelled), "cancelled");
        assert_eq!(finish_reason_str(FinishReason::Dropped), "dropped");
    }

    #[test]
    fn completion_json_shape() {
        let v = completion_json(3, "m", &[10, 20], "length", 5);
        assert_eq!(v.get("id").and_then(|x| x.as_str()), Some("cmpl-3"));
        let choice = &v.get("choices").unwrap().as_array().unwrap()[0];
        assert_eq!(choice.get("text").and_then(|t| t.as_str()), Some("10 20"));
        assert_eq!(choice.get("token_ids").unwrap().as_array().unwrap().len(), 2);
        let usage = v.get("usage").unwrap();
        assert_eq!(usage.get("completion_tokens").and_then(|x| x.as_u64()), Some(2));
        assert_eq!(usage.get("total_tokens").and_then(|x| x.as_u64()), Some(7));
        // The response is valid JSON end to end.
        assert_eq!(json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn prometheus_rendering_includes_engine_and_transport_sections() {
        let stats = HttpStats::default();
        stats.requests_total.store(4, Ordering::Relaxed);
        stats.tokens_streamed_total.store(17, Ordering::Relaxed);
        let mut rep = crate::metrics::Recorder::new().report("unit");
        rep.queue_cap = Some(64);
        rep.prefix_hits = 3;
        rep.prefix_cached_tokens = 96;
        rep.reconfigs = 2;
        rep.role_occupancy = [12.0, 3.5, 0.0];
        let text = render_prometheus(Some(&rep), &stats);
        assert!(text.contains("duetserve_http_requests_total 4"));
        assert!(text.contains("duetserve_http_tokens_streamed_total 17"));
        assert!(text.contains("duetserve_http_active_connections 0"));
        assert!(text.contains("duetserve_queue_cap 64"));
        assert!(text.contains("duetserve_engine_completed_total 0"));
        assert!(text.contains("# TYPE duetserve_engine_clock_seconds gauge"));
        assert!(text.contains("duetserve_engine_epoch 0"));
        assert!(text.contains("# TYPE duetserve_uptime_engine_seconds_total counter"));
        assert!(text.contains("duetserve_prefix_hits_total 3"));
        assert!(text.contains("duetserve_prefix_cached_tokens_total 96"));
        assert!(text.contains("duetserve_prefix_evictions_total 0"));
        assert!(text.contains("# TYPE duetserve_prefilled_tokens_total counter"));
        assert!(text.contains("duetserve_preemptions_total 0"));
        assert!(text.contains("duetserve_qos_preemptions_total 0"));
        // Per-class families render one labeled sample per SLO class.
        assert!(text.contains("# TYPE duetserve_class_completed_total counter"));
        assert!(text.contains("duetserve_class_completed_total{class=\"latency\"} 0"));
        assert!(text.contains("duetserve_class_attained_total{class=\"standard\"} 0"));
        assert!(text.contains("duetserve_class_tbt_p99_seconds{class=\"batch\"} 0"));
        // Reconfiguration + per-role occupancy families.
        assert!(text.contains("duetserve_reconfigs_total 2"));
        assert!(text.contains("# TYPE duetserve_role_occupancy_seconds counter"));
        assert!(text.contains("duetserve_role_occupancy_seconds{role=\"unified\"} 12"));
        assert!(text.contains("duetserve_role_occupancy_seconds{role=\"prefill\"} 3.5"));
        assert!(text.contains("duetserve_role_occupancy_seconds{role=\"decode\"} 0"));
        // Without a snapshot, only transport metrics render.
        let text = render_prometheus(None, &stats);
        assert!(!text.contains("duetserve_engine_completed_total"));
        assert!(!text.contains("duetserve_queue_cap"));
        assert!(!text.contains("duetserve_prefix_hits_total"));
        assert!(!text.contains("duetserve_class_completed_total"));
        assert!(!text.contains("duetserve_reconfigs_total"));
    }

    #[test]
    fn report_json_carries_classes_and_preemption_counters() {
        let mut rep = crate::metrics::Recorder::new().report("unit");
        rep.reconfigs = 4;
        rep.role_occupancy = [1.0, 2.0, 3.0];
        let v = report_json(&rep);
        assert_eq!(v.get("preemptions").and_then(|x| x.as_f64()), Some(0.0));
        assert_eq!(v.get("qos_preemptions").and_then(|x| x.as_f64()), Some(0.0));
        assert_eq!(v.get("reconfigs").and_then(|x| x.as_f64()), Some(4.0));
        let occ = v.get("role_occupancy_seconds").expect("occupancy object");
        assert_eq!(occ.get("unified").and_then(|x| x.as_f64()), Some(1.0));
        assert_eq!(occ.get("prefill").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(occ.get("decode").and_then(|x| x.as_f64()), Some(3.0));
        let classes = v.get("classes").expect("classes object");
        for class in SloClass::all() {
            let c = classes.get(class.name()).expect("per-class entry");
            assert_eq!(c.get("completed").and_then(|x| x.as_f64()), Some(0.0));
            assert_eq!(c.get("attainment"), Some(&Json::Null));
        }
        // Valid JSON end to end.
        assert_eq!(json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn max_tokens_cap_is_enforced() {
        let v = json::parse(r#"{"prompt":[1],"max_tokens":1000000000}"#).unwrap();
        let err = parse_completion(&v).unwrap_err();
        assert!(err.contains("max_tokens"), "{err}");
        let v = json::parse(&format!(r#"{{"prompt":[1],"max_tokens":{MAX_TOKENS_CAP}}}"#)).unwrap();
        assert!(parse_completion(&v).is_ok());
    }

    #[test]
    fn parse_buffered_walks_through_incremental_states() {
        // Not even a full head yet.
        assert!(matches!(parse_buffered(b"GET /hea", 1024), BufParse::Partial));
        assert!(matches!(
            parse_buffered(b"GET /healthz HTTP/1.1\r\nHost: x\r\n", 1024),
            BufParse::Partial
        ));
        // Complete body-less request; consumed covers head exactly.
        let wire = b"GET /healthz HTTP/1.1\r\n\r\n";
        match parse_buffered(wire, 1024) {
            BufParse::Complete(req, used) => {
                assert_eq!(req.path, "/healthz");
                assert!(req.http11);
                assert_eq!(used, wire.len());
            }
            other => panic!("expected Complete, got {other:?}"),
        }
        // Head done, body still arriving.
        assert!(matches!(
            parse_buffered(b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nab", 1024),
            BufParse::PartialBody {
                expect_continue: false
            }
        ));
        assert!(matches!(
            parse_buffered(
                b"POST /x HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 5\r\n\r\n",
                1024
            ),
            BufParse::PartialBody {
                expect_continue: true
            }
        ));
        // Full request with body.
        match parse_buffered(b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello", 1024) {
            BufParse::Complete(req, used) => {
                assert_eq!(req.body, b"hello");
                assert_eq!(used, 44);
            }
            other => panic!("expected Complete, got {other:?}"),
        }
        // Protocol violations fail (and map to the blocking parser's
        // errors).
        assert!(matches!(
            parse_buffered(b"GARBAGE\r\n\r\n", 1024),
            BufParse::Fail(ReadError::Malformed(_))
        ));
        assert!(matches!(
            parse_buffered(b"POST /x HTTP/1.1\r\nContent-Length: 9999\r\n\r\n", 64),
            BufParse::Fail(ReadError::TooLarge { limit: 64 })
        ));
    }

    #[test]
    fn parse_buffered_handles_pipelined_requests_and_leading_blanks() {
        let wire: Vec<u8> =
            b"GET /healthz HTTP/1.1\r\n\r\nPOST /x HTTP/1.1\r\nContent-Length: 2\r\n\r\nok"
                .to_vec();
        let (first, used) = match parse_buffered(&wire, 1024) {
            BufParse::Complete(req, used) => (req, used),
            other => panic!("expected Complete, got {other:?}"),
        };
        assert_eq!(first.path, "/healthz");
        let (second, used2) = match parse_buffered(&wire[used..], 1024) {
            BufParse::Complete(req, u) => (req, u),
            other => panic!("expected Complete, got {other:?}"),
        };
        assert_eq!(second.path, "/x");
        assert_eq!(second.body, b"ok");
        assert_eq!(used + used2, wire.len());
        // Stray blank lines between requests are skipped and counted as
        // consumed.
        let wire = b"\r\n\nGET /metrics HTTP/1.1\n\n";
        match parse_buffered(wire, 1024) {
            BufParse::Complete(req, used) => {
                assert_eq!(req.path, "/metrics");
                assert_eq!(used, wire.len());
            }
            other => panic!("expected Complete, got {other:?}"),
        }
    }

    #[test]
    fn parse_buffered_endless_header_stream_fails() {
        let mut wire = b"GET / HTTP/1.1\r\n".to_vec();
        while wire.len() <= MAX_HEADER_BYTES {
            wire.extend_from_slice(b"X-Pad: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        assert!(matches!(
            parse_buffered(&wire, 1024),
            BufParse::Fail(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn keep_alive_negotiation_follows_http11_rules() {
        let req = |wire: &str| match parse_buffered(wire.as_bytes(), 1024) {
            BufParse::Complete(r, _) => r,
            other => panic!("expected Complete, got {other:?}"),
        };
        assert!(wants_keep_alive(&req("GET / HTTP/1.1\r\n\r\n")));
        assert!(!wants_keep_alive(&req(
            "GET / HTTP/1.1\r\nConnection: close\r\n\r\n"
        )));
        assert!(!wants_keep_alive(&req("GET / HTTP/1.0\r\n\r\n")));
        assert!(wants_keep_alive(&req(
            "GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n"
        )));
        // `close` wins over any other token, case-insensitively.
        assert!(!wants_keep_alive(&req(
            "GET / HTTP/1.1\r\nConnection: keep-alive, Close\r\n\r\n"
        )));
    }

    #[test]
    fn response_bytes_matches_blocking_respond_output() {
        let v = error_json(400, "nope");
        let bytes = json_response_bytes(400, "Bad Request", &v, "close");
        let mut legacy = Vec::new();
        respond(
            &mut legacy,
            400,
            "Bad Request",
            "application/json",
            v.dump().as_bytes(),
            &[],
        )
        .unwrap();
        assert_eq!(bytes, legacy);
        // Keep-alive variant differs only in the Connection header.
        let ka = json_response_bytes(400, "Bad Request", &v, "keep-alive");
        let s = String::from_utf8(ka).unwrap();
        assert!(s.contains("Connection: keep-alive\r\n"));
        assert!(!s.contains("Connection: close"));
    }

    #[test]
    fn sse_frame_matches_sse_chunk_output() {
        let mut legacy = Vec::new();
        sse_chunk(&mut legacy, "[DONE]").unwrap();
        assert_eq!(sse_frame("[DONE]"), legacy);
        let head = String::from_utf8(sse_head_bytes()).unwrap();
        assert!(head.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(head.contains("Content-Type: text/event-stream\r\n"));
        assert!(head.ends_with("\r\n\r\n"));
    }
}
