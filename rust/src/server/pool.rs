//! Keep-alive front door: a fixed pool of readiness-polled connection
//! workers (unix only; gated at the declaration site).
//!
//! The thread-per-connection baseline in [`super::http`] spawns a thread
//! and burns a connect/close round trip per request — fine for a
//! handful of clients, a bottleneck long before the engine saturates.
//! This module multiplexes every connection over
//! [`HttpConfig::pool_workers`](super::http::HttpConfig) worker threads
//! instead:
//!
//! - sockets are non-blocking and registered with a `poll(2)` readiness
//!   loop (declared directly against libc, like the `signal(2)` binding
//!   in [`super::http::sig`] — the crate stays dependency-free);
//! - requests are parsed *incrementally* per readiness event
//!   ([`parse_buffered`]) and served repeatedly on the same socket
//!   (HTTP/1.1 keep-alive, pipelining included) until `Connection:
//!   close`, the idle timeout, or drain;
//! - responses and SSE frames go through per-connection output buffers
//!   flushed on `POLLOUT`, so a slow reader back-pressures its own
//!   connection and *never* wedges a worker — the disconnect probes and
//!   per-write `set_nonblocking` flips of the baseline path do not
//!   exist here, the readiness loop observes hangups directly.
//!
//! Every handler, parser, limit and response builder is shared with the
//! baseline path (`start_completion`, `parse_buffered` runs the same
//! `read_head`/`body_len` grammar, `response_bytes`), so the two paths
//! answer byte-identically for a `Connection: close` request — the
//! keep-alive tests pin that.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::http::{
    completion_json, error_json, finish_reason_str, healthz_json, json_response_bytes,
    metrics_body, parse_buffered, refuse_over_capacity, report_json, response_bytes, sig,
    sse_finish_json, sse_frame, sse_head_bytes, sse_token_json, start_completion,
    wants_keep_alive, BufParse, CompletionStart, HttpRequest, ReadError, Shared, CONN_LINGER,
    IO_TIMEOUT,
};
use super::{FinishReason, HandlePoll, RequestHandle, TokenEvent};

/// Poll timeout when every connection is idle (keep-alive parked): new
/// intake pickup latency is bounded by this.
const IDLE_POLL_MS: i32 = 10;

/// Poll timeout while any request is in flight: the token pump runs at
/// this cadence even with no socket readiness.
const ACTIVE_POLL_MS: i32 = 2;

/// Accept-loop sleep while the listener has nothing (mirrors the
/// baseline's poll interval).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Read chunk per readiness event.
const READ_CHUNK: usize = 16 * 1024;

/// Stop pulling token events for a connection whose un-flushed output
/// exceeds this — the peer reads too slowly; events stay queued in the
/// request's channel instead of our memory.
const MAX_OUTBUF: usize = 4 << 20;

/// Read-buffer cap beyond one max-size request's worth of headers+body;
/// past it we stop reading (level-triggered poll re-arms when the
/// parser catches up), bounding pipelining memory per connection.
const RBUF_SLACK: usize = 64 * 1024;

/// Minimal `poll(2)` surface. std links libc on every unix target, so
/// declaring the symbol directly keeps the crate offline-buildable.
mod sys {
    use std::os::unix::io::RawFd;

    #[repr(C)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: std::os::raw::c_ulong, timeout: i32) -> i32;
    }

    /// Block up to `timeout_ms` for readiness. Errors (EINTR included)
    /// report zero ready fds — the caller's loop re-polls.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> usize {
        if fds.is_empty() {
            return 0;
        }
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_ulong, timeout_ms) };
        if n < 0 {
            0
        } else {
            n as usize
        }
    }
}

/// What one connection is doing between readiness events.
enum ConnState {
    /// Waiting for (more of) a request.
    Idle,
    /// A non-streaming completion is generating; tokens accumulate until
    /// the terminal event, then one JSON response is queued.
    Waiting {
        handle: RequestHandle,
        tokens: Vec<i32>,
        prompt_tokens: usize,
        keep_alive: bool,
    },
    /// An SSE stream: each token event becomes a frame in the output
    /// buffer. SSE has no length framing, so the connection closes after
    /// the terminal `[DONE]` flushes.
    Streaming {
        handle: RequestHandle,
        prompt_tokens: usize,
        generated: usize,
    },
}

struct Conn {
    stream: TcpStream,
    /// Accumulated unparsed request bytes.
    rbuf: Vec<u8>,
    /// Un-flushed response bytes (`wpos` is the flushed prefix).
    wbuf: Vec<u8>,
    wpos: usize,
    state: ConnState,
    /// Last socket read/write progress (keep-alive idle timeout base).
    last_activity: Instant,
    /// Last write progress while output is pending (slow-reader reap).
    last_write_progress: Instant,
    /// Requests served on this connection (reuse metric).
    served: u64,
    /// `100 Continue` already queued for the in-flight partial body.
    sent_continue: bool,
    /// Cancel already sent for the in-flight request (peer vanished).
    cancel_sent: bool,
    /// Close once the output buffer drains and the state is idle.
    close_after_flush: bool,
    /// Peer sent EOF (half-close); no further requests can arrive.
    peer_eof: bool,
    /// Reap at the next sweep, unconditionally.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        let now = Instant::now();
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            state: ConnState::Idle,
            last_activity: now,
            last_write_progress: now,
            served: 0,
            sent_continue: false,
            cancel_sent: false,
            close_after_flush: false,
            peer_eof: false,
            dead: false,
        }
    }

    fn queue(&mut self, bytes: Vec<u8>) {
        if self.wbuf.len() == self.wpos {
            self.wbuf = bytes;
            self.wpos = 0;
        } else {
            self.wbuf.extend_from_slice(&bytes);
        }
        self.last_write_progress = Instant::now();
    }

    fn pending(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    fn is_idle(&self) -> bool {
        matches!(self.state, ConnState::Idle)
    }
}

/// Accept loop for the pooled path: accepts, applies `--max-conns`, and
/// hands sockets to the least-loaded worker. On shutdown it drops the
/// intake channels (workers observe and drain), drains the engine, and
/// joins the pool.
pub(crate) fn pool_accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    handle_signals: bool,
    workers: usize,
) {
    let workers = workers.max(1);
    let assigned: Arc<Vec<AtomicUsize>> =
        Arc::new((0..workers).map(|_| AtomicUsize::new(0)).collect());
    let mut txs = Vec::with_capacity(workers);
    let mut joins = Vec::with_capacity(workers);
    for i in 0..workers {
        let (tx, rx) = channel::<TcpStream>();
        let shared_w = Arc::clone(&shared);
        let assigned_w = Arc::clone(&assigned);
        joins.push(std::thread::spawn(move || {
            worker_loop(rx, shared_w, assigned_w, i, handle_signals)
        }));
        txs.push(tx);
    }
    loop {
        if shared.shutdown.load(Ordering::SeqCst) || (handle_signals && sig::triggered()) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let cap = shared.cfg.max_conns as u64;
                let in_flight = shared.stats.active_connections.load(Ordering::SeqCst)
                    + shared.stats.pool_queue_depth.load(Ordering::SeqCst);
                if cap > 0 && in_flight >= cap {
                    refuse_over_capacity(&shared, stream);
                    continue;
                }
                let (mut best, mut best_n) = (0usize, usize::MAX);
                for (i, a) in assigned.iter().enumerate() {
                    let n = a.load(Ordering::SeqCst);
                    if n < best_n {
                        best = i;
                        best_n = n;
                    }
                }
                assigned[best].fetch_add(1, Ordering::SeqCst);
                shared.stats.pool_queue_depth.fetch_add(1, Ordering::SeqCst);
                if txs[best].send(stream).is_err() {
                    assigned[best].fetch_sub(1, Ordering::SeqCst);
                    shared.stats.pool_queue_depth.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(ACCEPT_POLL),
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // Close intakes first (workers flip to draining), then drain the
    // engine so every in-flight request gets its terminal event, then
    // wait for the workers to flush and exit.
    drop(txs);
    shared.drain();
    for j in joins {
        let _ = j.join();
    }
}

fn register(shared: &Shared, conns: &mut Vec<Conn>, assigned: &AtomicUsize, stream: TcpStream) {
    shared.stats.pool_queue_depth.fetch_sub(1, Ordering::SeqCst);
    if stream.set_nonblocking(true).is_err() {
        assigned.fetch_sub(1, Ordering::SeqCst);
        return;
    }
    let _ = stream.set_nodelay(true);
    shared.stats.active_connections.fetch_add(1, Ordering::SeqCst);
    conns.push(Conn::new(stream));
}

fn worker_loop(
    intake: Receiver<TcpStream>,
    shared: Arc<Shared>,
    assigned: Arc<Vec<AtomicUsize>>,
    me: usize,
    handle_signals: bool,
) {
    let max_body = shared.cfg.max_body;
    let idle_timeout = shared.cfg.idle_timeout;
    let mut conns: Vec<Conn> = Vec::new();
    let mut intake_open = true;
    let mut drain_started: Option<Instant> = None;
    let mut fds: Vec<sys::PollFd> = Vec::new();
    loop {
        // 1) Pick up newly accepted connections.
        while intake_open {
            match intake.try_recv() {
                Ok(stream) => register(&shared, &mut conns, &assigned[me], stream),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    intake_open = false;
                }
            }
        }
        let draining = !intake_open
            || shared.shutdown.load(Ordering::SeqCst)
            || (handle_signals && sig::triggered());
        if draining && drain_started.is_none() {
            drain_started = Some(Instant::now());
        }
        if conns.is_empty() {
            if draining {
                break;
            }
            // Nothing to poll: block on intake instead of spinning.
            match intake.recv_timeout(Duration::from_millis(50)) {
                Ok(stream) => register(&shared, &mut conns, &assigned[me], stream),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => intake_open = false,
            }
            continue;
        }
        // 2) Readiness: POLLIN always (EOF/hangup detection is how
        // disconnect-cancel works), POLLOUT only with pending output.
        fds.clear();
        for c in &conns {
            let mut ev = sys::POLLIN;
            if c.pending() {
                ev |= sys::POLLOUT;
            }
            fds.push(sys::PollFd {
                fd: c.stream.as_raw_fd(),
                events: ev,
                revents: 0,
            });
        }
        let any_active = conns.iter().any(|c| !c.is_idle());
        let timeout = if any_active || draining {
            ACTIVE_POLL_MS
        } else {
            IDLE_POLL_MS
        };
        sys::poll_fds(&mut fds, timeout);
        // 3) IO + state machine per connection.
        for (c, fd) in conns.iter_mut().zip(&fds) {
            let re = fd.revents;
            if re & (sys::POLLERR | sys::POLLNVAL) != 0 {
                c.dead = true;
                continue;
            }
            if re & (sys::POLLIN | sys::POLLHUP) != 0
                && !c.peer_eof
                && c.rbuf.len() < max_body + RBUF_SLACK
            {
                read_some(c);
            }
        }
        for c in conns.iter_mut() {
            if c.dead {
                continue;
            }
            step_conn(&shared, c, max_body);
            flush_some(c);
        }
        // 4) Reap.
        let now = Instant::now();
        let linger_over = drain_started.is_some_and(|t| t.elapsed() > CONN_LINGER);
        let mut i = 0;
        while i < conns.len() {
            let c = &mut conns[i];
            let flushed = !c.pending();
            let idle = c.is_idle();
            let reap = c.dead
                || linger_over
                || (flushed && idle && c.close_after_flush)
                || (flushed && idle && c.peer_eof)
                || (flushed && idle && draining)
                || (flushed
                    && idle
                    && now.duration_since(c.last_activity) > idle_timeout)
                || (c.pending() && now.duration_since(c.last_write_progress) > IO_TIMEOUT);
            if reap {
                let c = conns.swap_remove(i);
                assigned[me].fetch_sub(1, Ordering::SeqCst);
                finalize(&shared, c);
            } else {
                i += 1;
            }
        }
    }
}

/// Drop a connection: cancel any in-flight request so abandoned work
/// releases its slot and KV, and settle the gauges.
fn finalize(shared: &Shared, c: Conn) {
    match c.state {
        ConnState::Idle => {}
        ConnState::Waiting { handle, .. } => {
            if !c.cancel_sent {
                handle.cancel();
            }
        }
        ConnState::Streaming { handle, .. } => {
            if !c.cancel_sent {
                handle.cancel();
            }
            shared.stats.active_streams.fetch_sub(1, Ordering::SeqCst);
        }
    }
    shared.stats.active_connections.fetch_sub(1, Ordering::SeqCst);
}

/// Non-blocking read into the connection's buffer. EOF marks
/// `peer_eof`; hard errors mark the connection dead.
fn read_some(c: &mut Conn) {
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        match c.stream.read(&mut chunk) {
            Ok(0) => {
                c.peer_eof = true;
                return;
            }
            Ok(n) => {
                c.last_activity = Instant::now();
                c.rbuf.extend_from_slice(&chunk[..n]);
                if n < chunk.len() {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
}

/// Non-blocking flush of the output buffer; stops on `WouldBlock` (the
/// poll loop re-arms with POLLOUT).
fn flush_some(c: &mut Conn) {
    while c.pending() {
        match c.stream.write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => {
                c.wpos += n;
                let now = Instant::now();
                c.last_write_progress = now;
                c.last_activity = now;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    c.wbuf.clear();
    c.wpos = 0;
}

/// Advance one connection's request/response state machine as far as it
/// can go without blocking: parse buffered requests (pipelining
/// included), dispatch them, and pump token events into the output
/// buffer.
fn step_conn(shared: &Shared, c: &mut Conn, max_body: usize) {
    loop {
        match std::mem::replace(&mut c.state, ConnState::Idle) {
            ConnState::Idle => {
                if c.close_after_flush || c.rbuf.is_empty() {
                    return;
                }
                match parse_buffered(&c.rbuf, max_body) {
                    BufParse::Partial => {
                        if c.peer_eof {
                            // EOF mid-head: same 400 the blocking reader
                            // produces when the line read hits EOF.
                            fail_request(shared, c, "connection closed inside headers");
                        }
                        return;
                    }
                    BufParse::PartialBody { expect_continue } => {
                        if c.peer_eof {
                            fail_request(
                                shared,
                                c,
                                "content-length mismatch: body ended before the declared length",
                            );
                            return;
                        }
                        if expect_continue && !c.sent_continue {
                            c.sent_continue = true;
                            c.queue(b"HTTP/1.1 100 Continue\r\n\r\n".to_vec());
                        }
                        return;
                    }
                    BufParse::Complete(req, used) => {
                        c.rbuf.drain(..used);
                        c.sent_continue = false;
                        dispatch(shared, c, &req);
                        if c.close_after_flush || !c.is_idle() {
                            return;
                        }
                        // Pipelined follower may already be buffered.
                        continue;
                    }
                    BufParse::Fail(err) => {
                        match err {
                            ReadError::Malformed(m) => fail_request(shared, c, &m),
                            ReadError::TooLarge { limit } => {
                                shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
                                let msg = format!("request body exceeds {limit} bytes");
                                c.queue(json_response_bytes(
                                    413,
                                    "Payload Too Large",
                                    &error_json(413, &msg),
                                    "close",
                                ));
                                c.close_after_flush = true;
                                c.rbuf.clear();
                            }
                            ReadError::Closed => c.dead = true,
                        }
                        return;
                    }
                }
            }
            ConnState::Waiting {
                handle,
                mut tokens,
                prompt_tokens,
                keep_alive,
            } => {
                if c.peer_eof && !c.cancel_sent {
                    c.cancel_sent = true;
                    handle.cancel();
                }
                let done = loop {
                    match handle.next_event_timeout(Duration::ZERO) {
                        HandlePoll::Event(TokenEvent::Token { value, .. }) => tokens.push(value),
                        HandlePoll::Event(TokenEvent::Done { reason }) => break Some(reason),
                        HandlePoll::TimedOut => break None,
                        // Channel gone without a terminal event (engine
                        // abort): report what we have as dropped.
                        HandlePoll::Closed => break Some(FinishReason::Dropped),
                    }
                };
                let Some(reason) = done else {
                    c.state = ConnState::Waiting {
                        handle,
                        tokens,
                        prompt_tokens,
                        keep_alive,
                    };
                    return;
                };
                shared
                    .stats
                    .tokens_streamed_total
                    .fetch_add(tokens.len() as u64, Ordering::Relaxed);
                let conn_tok = if keep_alive { "keep-alive" } else { "close" };
                let body = completion_json(
                    handle.id(),
                    &shared.cfg.model,
                    &tokens,
                    finish_reason_str(reason),
                    prompt_tokens,
                );
                c.queue(json_response_bytes(200, "OK", &body, conn_tok));
                if !keep_alive {
                    c.close_after_flush = true;
                }
                c.cancel_sent = false;
                // Back to Idle: a pipelined follower may be waiting.
            }
            ConnState::Streaming {
                handle,
                prompt_tokens,
                mut generated,
            } => {
                if c.peer_eof && !c.cancel_sent {
                    c.cancel_sent = true;
                    handle.cancel();
                }
                let id = handle.id();
                let done = loop {
                    if c.wbuf.len() - c.wpos > MAX_OUTBUF {
                        // Slow reader: stop pulling; events wait in the
                        // request channel, not our memory.
                        break None;
                    }
                    match handle.next_event_timeout(Duration::ZERO) {
                        HandlePoll::Event(TokenEvent::Token { value, at }) => {
                            let chunk = sse_token_json(id, &shared.cfg.model, value, at);
                            c.queue(sse_frame(&chunk.dump()));
                            generated += 1;
                            shared
                                .stats
                                .tokens_streamed_total
                                .fetch_add(1, Ordering::Relaxed);
                        }
                        HandlePoll::Event(TokenEvent::Done { reason }) => break Some(Some(reason)),
                        HandlePoll::TimedOut => break None,
                        HandlePoll::Closed => break Some(None),
                    }
                };
                match done {
                    None => {
                        c.state = ConnState::Streaming {
                            handle,
                            prompt_tokens,
                            generated,
                        };
                        return;
                    }
                    Some(reason_opt) => {
                        if let Some(reason) = reason_opt {
                            let fin = sse_finish_json(
                                id,
                                &shared.cfg.model,
                                reason,
                                prompt_tokens,
                                generated,
                            );
                            c.queue(sse_frame(&fin.dump()));
                        }
                        c.queue(sse_frame("[DONE]"));
                        shared.stats.active_streams.fetch_sub(1, Ordering::SeqCst);
                        // SSE is connection-delimited: close once flushed.
                        c.close_after_flush = true;
                        c.cancel_sent = false;
                        return;
                    }
                }
            }
        }
    }
}

/// Queue a `400` for an unparsable (or truncated) request and poison the
/// connection: after a framing error the byte stream is unsynchronized,
/// so it must close (mirrors the blocking path's `reject` + close).
fn fail_request(shared: &Shared, c: &mut Conn, msg: &str) {
    shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
    c.queue(json_response_bytes(
        400,
        "Bad Request",
        &error_json(400, msg),
        "close",
    ));
    c.close_after_flush = true;
    c.rbuf.clear();
}

/// Route one parsed request — the same table as the baseline path's
/// `handle_connection`, writing into the connection's output buffer.
fn dispatch(shared: &Shared, c: &mut Conn, req: &HttpRequest) {
    shared.stats.requests_total.fetch_add(1, Ordering::Relaxed);
    c.served += 1;
    if c.served >= 2 {
        shared
            .stats
            .keepalive_reuse_total
            .fetch_add(1, Ordering::Relaxed);
    }
    let keep = wants_keep_alive(req) && !shared.shutdown.load(Ordering::SeqCst);
    let conn_tok = if keep { "keep-alive" } else { "close" };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            c.queue(json_response_bytes(200, "OK", &healthz_json(shared), conn_tok));
            c.close_after_flush |= !keep;
        }
        ("GET", "/metrics") => {
            let body = metrics_body(shared);
            c.queue(response_bytes(
                200,
                "OK",
                "text/plain; version=0.0.4",
                body.as_bytes(),
                &[],
                conn_tok,
            ));
            c.close_after_flush |= !keep;
        }
        ("POST", "/v1/completions") => match start_completion(shared, req, conn_tok) {
            CompletionStart::Respond(bytes) => {
                c.queue(bytes);
                c.close_after_flush |= !keep;
            }
            CompletionStart::Accepted {
                handle,
                prompt_tokens,
                stream,
            } => {
                if stream {
                    shared.stats.active_streams.fetch_add(1, Ordering::SeqCst);
                    c.queue(sse_head_bytes());
                    c.state = ConnState::Streaming {
                        handle,
                        prompt_tokens,
                        generated: 0,
                    };
                } else {
                    c.state = ConnState::Waiting {
                        handle,
                        tokens: Vec::new(),
                        prompt_tokens,
                        keep_alive: keep,
                    };
                }
            }
        },
        ("POST", "/shutdown") => {
            match shared.drain() {
                Some(rep) => {
                    c.queue(json_response_bytes(200, "OK", &report_json(&rep), "close"));
                }
                None => {
                    c.queue(json_response_bytes(
                        500,
                        "Internal Server Error",
                        &error_json(500, "engine drain produced no report"),
                        "close",
                    ));
                }
            }
            c.close_after_flush = true;
        }
        (_, "/healthz" | "/metrics" | "/v1/completions" | "/shutdown") => {
            shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
            c.queue(json_response_bytes(
                405,
                "Method Not Allowed",
                &error_json(
                    405,
                    &format!("{} not allowed on {}", req.method, req.path),
                ),
                conn_tok,
            ));
            c.close_after_flush |= !keep;
        }
        _ => {
            shared.stats.rejected_total.fetch_add(1, Ordering::Relaxed);
            c.queue(json_response_bytes(
                404,
                "Not Found",
                &error_json(
                    404,
                    &format!("unknown route {} {}", req.method, req.path),
                ),
                conn_tok,
            ));
            c.close_after_flush |= !keep;
        }
    }
}
