//! Request arrival processes.

use crate::util::rng::Rng;

/// Poisson arrival times at rate `qps`, for `n` requests starting at t=0.
/// (§5.1: "we model request arrivals using a Poisson process".)
pub fn poisson_arrivals(rng: &mut Rng, n: usize, qps: f64) -> Vec<f64> {
    assert!(qps > 0.0);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exponential(qps);
        out.push(t);
    }
    out
}

/// Deterministic (uniform) arrivals — used by ablation benches where
/// arrival jitter would obscure the comparison.
pub fn uniform_arrivals(n: usize, qps: f64) -> Vec<f64> {
    assert!(qps > 0.0);
    (0..n).map(|i| (i + 1) as f64 / qps).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Rng::new(1);
        let n = 50_000;
        let ts = poisson_arrivals(&mut rng, n, 8.0);
        assert_eq!(ts.len(), n);
        let span = ts[n - 1];
        let measured_qps = n as f64 / span;
        assert!(
            (measured_qps - 8.0).abs() < 0.2,
            "measured qps {measured_qps}"
        );
        // strictly increasing
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn uniform_is_evenly_spaced() {
        let ts = uniform_arrivals(4, 2.0);
        assert_eq!(ts, vec![0.5, 1.0, 1.5, 2.0]);
    }
}
