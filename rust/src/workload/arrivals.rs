//! Request arrival processes.

use crate::util::rng::Rng;

/// Poisson arrival times at rate `qps`, for `n` requests starting at t=0.
/// (§5.1: "we model request arrivals using a Poisson process".)
pub fn poisson_arrivals(rng: &mut Rng, n: usize, qps: f64) -> Vec<f64> {
    assert!(qps > 0.0);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exponential(qps);
        out.push(t);
    }
    out
}

/// Deterministic (uniform) arrivals — used by ablation benches where
/// arrival jitter would obscure the comparison.
pub fn uniform_arrivals(n: usize, qps: f64) -> Vec<f64> {
    assert!(qps > 0.0);
    (0..n).map(|i| (i + 1) as f64 / qps).collect()
}

/// Non-homogeneous Poisson arrivals by thinning (Lewis–Shedler): draw
/// candidate events at the bounding rate `peak` and accept each with
/// probability `rate(t) / peak`. `rate` must satisfy
/// `0 ≤ rate(t) ≤ peak` for all `t`.
pub fn thinned_arrivals(
    rng: &mut Rng,
    n: usize,
    peak: f64,
    mut rate: impl FnMut(f64) -> f64,
) -> Vec<f64> {
    assert!(peak > 0.0);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        t += rng.exponential(peak);
        if rng.f64() * peak < rate(t) {
            out.push(t);
        }
    }
    out
}

/// Square-wave burst arrivals: `burst_qps` during the first `burst_s`
/// seconds of every `period_s` window, `base_qps` (may be 0) otherwise.
/// The load shape where a statically-roled fleet loses: each burst wants
/// prefill capacity the inter-burst lull wants back.
pub fn burst_arrivals(
    rng: &mut Rng,
    n: usize,
    base_qps: f64,
    burst_qps: f64,
    period_s: f64,
    burst_s: f64,
) -> Vec<f64> {
    assert!(period_s > 0.0 && burst_s > 0.0 && burst_s <= period_s);
    let peak = base_qps.max(burst_qps);
    thinned_arrivals(rng, n, peak, |t| {
        if t % period_s < burst_s {
            burst_qps
        } else {
            base_qps
        }
    })
}

/// Diurnal arrivals: the rate swings sinusoidally between `low_qps` and
/// `high_qps` with period `period_s` (a compressed day).
pub fn diurnal_arrivals(
    rng: &mut Rng,
    n: usize,
    low_qps: f64,
    high_qps: f64,
    period_s: f64,
) -> Vec<f64> {
    assert!(low_qps >= 0.0 && high_qps > low_qps && period_s > 0.0);
    let mid = 0.5 * (low_qps + high_qps);
    let amp = 0.5 * (high_qps - low_qps);
    thinned_arrivals(rng, n, high_qps, |t| {
        mid + amp * (std::f64::consts::TAU * t / period_s).sin()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Rng::new(1);
        let n = 50_000;
        let ts = poisson_arrivals(&mut rng, n, 8.0);
        assert_eq!(ts.len(), n);
        let span = ts[n - 1];
        let measured_qps = n as f64 / span;
        assert!(
            (measured_qps - 8.0).abs() < 0.2,
            "measured qps {measured_qps}"
        );
        // strictly increasing
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn uniform_is_evenly_spaced() {
        let ts = uniform_arrivals(4, 2.0);
        assert_eq!(ts, vec![0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn burst_arrivals_land_inside_burst_windows() {
        let mut rng = Rng::new(7);
        // Zero base rate: every accepted arrival must fall in a window.
        let ts = burst_arrivals(&mut rng, 500, 0.0, 20.0, 60.0, 15.0);
        assert_eq!(ts.len(), 500);
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
        for &t in &ts {
            assert!(t % 60.0 < 15.0, "arrival {t} outside burst window");
        }
    }

    #[test]
    fn diurnal_rate_tracks_the_sinusoid() {
        let mut rng = Rng::new(11);
        let n = 40_000;
        let ts = diurnal_arrivals(&mut rng, n, 2.0, 18.0, 600.0);
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
        // Mean rate over whole periods ≈ midpoint of the swing.
        let span = ts[n - 1];
        let mean_qps = n as f64 / span;
        assert!(
            (mean_qps - 10.0).abs() < 1.0,
            "mean qps {mean_qps} should sit near the 10 qps midpoint"
        );
        // Peak half-periods must be denser than trough half-periods.
        let period = 600.0;
        let (mut peak, mut trough) = (0usize, 0usize);
        for &t in &ts {
            if t % period < period / 2.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak > trough * 2,
            "peak half {peak} vs trough half {trough}"
        );
    }
}
