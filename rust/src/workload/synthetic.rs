//! Fixed-length synthetic workloads (Table 2, Fig. 2's 8000/200 demo).

use crate::request::Request;
use crate::util::rng::Rng;
use crate::workload::arrivals::poisson_arrivals;
use crate::workload::Workload;

/// `n` requests with fixed ISL/OSL arriving as a Poisson process at `qps`.
/// Used for the Fig. 2 motivation benchmark (ISL 8000, OSL 200 — the vLLM
/// disaggregation demo workload) and the Table 2 sensitivity study
/// (ISL 4096, OSL ∈ {64, 1024, 2048}).
pub fn fixed_workload(n: usize, isl: u64, osl: u64, qps: f64, seed: u64) -> Workload {
    let mut rng = Rng::new(seed ^ 0x5717);
    let arrivals = poisson_arrivals(&mut rng, n, qps);
    let requests = arrivals
        .iter()
        .enumerate()
        .map(|(i, &t)| Request::new(i as u64, t, isl, osl))
        .collect();
    Workload {
        name: format!("fixed-{isl}x{osl}"),
        requests,
    }
}

/// Mildly jittered variant (±`jitter` relative) so batches do not align
/// perfectly — used where exact ties would be unrealistically friendly to
/// static partitioning.
pub fn jittered_workload(
    n: usize,
    isl: u64,
    osl: u64,
    jitter: f64,
    qps: f64,
    seed: u64,
) -> Workload {
    let mut rng = Rng::new(seed ^ 0x5718);
    let arrivals = poisson_arrivals(&mut rng, n, qps);
    let requests = arrivals
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            let j = |x: u64, rng: &mut Rng| {
                let f = rng.f64_range(1.0 - jitter, 1.0 + jitter);
                ((x as f64 * f).round() as u64).max(1)
            };
            let p = j(isl, &mut rng);
            let o = j(osl, &mut rng);
            Request::new(i as u64, t, p, o)
        })
        .collect();
    Workload {
        name: format!("jitter-{isl}x{osl}"),
        requests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_lengths_exact() {
        let w = fixed_workload(50, 8000, 200, 4.0, 3);
        assert_eq!(w.requests.len(), 50);
        assert!(w.requests.iter().all(|r| r.prompt_len == 8000));
        assert!(w.requests.iter().all(|r| r.output_len == 200));
    }

    #[test]
    fn jitter_stays_in_bounds() {
        let w = jittered_workload(200, 1000, 100, 0.2, 4.0, 3);
        for r in &w.requests {
            assert!((800..=1200).contains(&r.prompt_len));
            assert!((80..=120).contains(&r.output_len));
        }
    }

    #[test]
    fn arrivals_sorted() {
        let w = fixed_workload(100, 10, 10, 10.0, 9);
        assert!(w
            .requests
            .windows(2)
            .all(|p| p[0].arrival <= p[1].arrival));
    }
}
