//! Fixed-length synthetic workloads (Table 2, Fig. 2's 8000/200 demo)
//! and the burst/diurnal mix the elastic role planner is evaluated on.

use crate::request::{Request, SloClass};
use crate::util::rng::Rng;
use crate::workload::arrivals::{burst_arrivals, diurnal_arrivals, poisson_arrivals};
use crate::workload::Workload;

/// `n` requests with fixed ISL/OSL arriving as a Poisson process at `qps`.
/// Used for the Fig. 2 motivation benchmark (ISL 8000, OSL 200 — the vLLM
/// disaggregation demo workload) and the Table 2 sensitivity study
/// (ISL 4096, OSL ∈ {64, 1024, 2048}).
pub fn fixed_workload(n: usize, isl: u64, osl: u64, qps: f64, seed: u64) -> Workload {
    let mut rng = Rng::new(seed ^ 0x5717);
    let arrivals = poisson_arrivals(&mut rng, n, qps);
    let requests = arrivals
        .iter()
        .enumerate()
        .map(|(i, &t)| Request::new(i as u64, t, isl, osl))
        .collect();
    Workload {
        name: format!("fixed-{isl}x{osl}"),
        requests,
    }
}

/// Mildly jittered variant (±`jitter` relative) so batches do not align
/// perfectly — used where exact ties would be unrealistically friendly to
/// static partitioning.
pub fn jittered_workload(
    n: usize,
    isl: u64,
    osl: u64,
    jitter: f64,
    qps: f64,
    seed: u64,
) -> Workload {
    let mut rng = Rng::new(seed ^ 0x5718);
    let arrivals = poisson_arrivals(&mut rng, n, qps);
    let requests = arrivals
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            let j = |x: u64, rng: &mut Rng| {
                let f = rng.f64_range(1.0 - jitter, 1.0 + jitter);
                ((x as f64 * f).round() as u64).max(1)
            };
            let p = j(isl, &mut rng);
            let o = j(osl, &mut rng);
            Request::new(i as u64, t, p, o)
        })
        .collect();
    Workload {
        name: format!("jitter-{isl}x{osl}"),
        requests,
    }
}

/// Shape of the burst/diurnal mixed workload: a steady stream of short
/// latency-class chats overlaid with periodic bursts of very long
/// batch-class prompts. This is the arrival pattern where any *static*
/// fleet loses: during a burst the prefill side saturates (a unified
/// fleet inflates decode TBT; a static disagg fleet has too few prefill
/// workers), between bursts dedicated prefill workers sit idle.
#[derive(Debug, Clone)]
pub struct BurstProfile {
    /// Short interactive requests (latency class, TTFT + TBT SLOs).
    pub shorts: usize,
    pub short_isl: u64,
    pub short_osl: u64,
    /// Mean short-request rate.
    pub short_qps: f64,
    pub short_slo_ttft: f64,
    pub short_slo_tbt: f64,
    /// Long-prompt requests (batch class, no SLO), arriving only inside
    /// burst windows.
    pub longs: usize,
    pub long_isl: u64,
    pub long_osl: u64,
    /// Long-request rate *inside* a burst window.
    pub long_qps: f64,
    /// Burst window cadence: `burst_s` of longs every `period_s`.
    pub period_s: f64,
    pub burst_s: f64,
    /// Modulate the short stream diurnally (sinusoid between
    /// `0.3 × short_qps` and `short_qps` over `2 × period_s`) instead of
    /// holding it at a flat Poisson rate.
    pub diurnal: bool,
}

impl Default for BurstProfile {
    fn default() -> BurstProfile {
        BurstProfile {
            shorts: 160,
            short_isl: 256,
            short_osl: 64,
            short_qps: 8.0,
            short_slo_ttft: 2.5,
            short_slo_tbt: 0.05,
            longs: 48,
            long_isl: 12_000,
            long_osl: 8,
            long_qps: 4.0,
            period_s: 120.0,
            burst_s: 30.0,
            diurnal: false,
        }
    }
}

/// Generate the [`BurstProfile`] mix: sorted merge of the short
/// latency-class stream and the bursty long batch-class stream. Ids are
/// assigned shorts-first, so equal-arrival ties keep a deterministic
/// order.
pub fn burst_mix_workload(p: &BurstProfile, seed: u64) -> Workload {
    let mut rng = Rng::new(seed ^ 0xB005_7B00);
    let short_ts = if p.diurnal {
        diurnal_arrivals(
            &mut rng,
            p.shorts,
            0.3 * p.short_qps,
            p.short_qps,
            2.0 * p.period_s,
        )
    } else {
        poisson_arrivals(&mut rng, p.shorts, p.short_qps)
    };
    let long_ts = burst_arrivals(&mut rng, p.longs, 0.0, p.long_qps, p.period_s, p.burst_s);
    let mut requests: Vec<Request> = Vec::with_capacity(p.shorts + p.longs);
    for (i, &t) in short_ts.iter().enumerate() {
        requests.push(
            Request::new(i as u64, t, p.short_isl, p.short_osl)
                .with_class(SloClass::Latency)
                .with_slo_ttft(p.short_slo_ttft)
                .with_slo_tbt(p.short_slo_tbt),
        );
    }
    for (i, &t) in long_ts.iter().enumerate() {
        requests.push(
            Request::new((p.shorts + i) as u64, t, p.long_isl, p.long_osl)
                .with_class(SloClass::Batch),
        );
    }
    Workload {
        name: if p.diurnal {
            "diurnal-burst-mix".into()
        } else {
            "burst-mix".into()
        },
        requests,
    }
    .sorted_by_arrival()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_lengths_exact() {
        let w = fixed_workload(50, 8000, 200, 4.0, 3);
        assert_eq!(w.requests.len(), 50);
        assert!(w.requests.iter().all(|r| r.prompt_len == 8000));
        assert!(w.requests.iter().all(|r| r.output_len == 200));
    }

    #[test]
    fn jitter_stays_in_bounds() {
        let w = jittered_workload(200, 1000, 100, 0.2, 4.0, 3);
        for r in &w.requests {
            assert!((800..=1200).contains(&r.prompt_len));
            assert!((80..=120).contains(&r.output_len));
        }
    }

    #[test]
    fn arrivals_sorted() {
        let w = fixed_workload(100, 10, 10, 10.0, 9);
        assert!(w
            .requests
            .windows(2)
            .all(|p| p[0].arrival <= p[1].arrival));
    }

    #[test]
    fn burst_mix_interleaves_classes_in_windows() {
        let p = BurstProfile::default();
        let w = burst_mix_workload(&p, 5);
        assert_eq!(w.requests.len(), p.shorts + p.longs);
        assert!(w
            .requests
            .windows(2)
            .all(|q| q[0].arrival <= q[1].arrival));
        let longs: Vec<_> = w
            .requests
            .iter()
            .filter(|r| r.prompt_len == p.long_isl)
            .collect();
        assert_eq!(longs.len(), p.longs);
        for r in &longs {
            assert_eq!(r.class, crate::request::SloClass::Batch);
            assert!(
                r.arrival % p.period_s < p.burst_s,
                "long request at {} outside burst window",
                r.arrival
            );
        }
        let shorts = w.requests.len() - longs.len();
        assert_eq!(shorts, p.shorts);
        assert!(w
            .requests
            .iter()
            .filter(|r| r.prompt_len == p.short_isl)
            .all(|r| r.class == crate::request::SloClass::Latency
                && r.slo_tbt.is_some()
                && r.slo_ttft.is_some()));
    }

    #[test]
    fn diurnal_variant_changes_short_arrivals_only_in_rate() {
        let mut p = BurstProfile::default();
        p.diurnal = true;
        let w = burst_mix_workload(&p, 5);
        assert_eq!(w.requests.len(), p.shorts + p.longs);
        assert_eq!(w.name, "diurnal-burst-mix");
        // The diurnal stream stretches over a longer horizon than the
        // flat-rate stream at the same mean request count.
        let flat = burst_mix_workload(&BurstProfile::default(), 5);
        let span = |w: &Workload| {
            w.requests
                .iter()
                .filter(|r| r.prompt_len == 256)
                .map(|r| r.arrival)
                .fold(0.0f64, f64::max)
        };
        assert!(span(&w) > span(&flat));
    }
}
