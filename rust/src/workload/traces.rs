//! Synthetic generators calibrated to the paper's Table 1 trace stats.
//!
//! | trace      | #requests | mean ISL | mean OSL |
//! |------------|-----------|----------|----------|
//! | Azure-Code | 19366     | 2047     | 28       |
//! | Azure-Conv | 8819      | 1155     | 211      |
//! | Mooncake   | 1000*     | 12035    | 343      |
//!
//! (*Mooncake sampled to 1000 requests, as in the paper.)
//!
//! The real traces are external downloads (Azure public dataset, Mooncake
//! repo) unavailable offline; what the evaluation depends on is the
//! ISL/OSL marginals and Poisson arrivals, which we reproduce with
//! lognormal length distributions whose mean matches Table 1 and whose
//! coefficient of variation reflects each trace's character (code
//! completions: tight OSL; conversations: heavy-tailed OSL; Mooncake:
//! very long, dispersed prompts).

use crate::request::Request;
use crate::util::rng::Rng;
use crate::workload::arrivals::poisson_arrivals;
use crate::workload::Workload;

/// The three evaluation traces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    AzureCode,
    AzureConv,
    Mooncake,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::AzureCode => "Azure-Code",
            TraceKind::AzureConv => "Azure-Conv",
            TraceKind::Mooncake => "Mooncake",
        }
    }

    /// Table 1 calibration targets: (n_requests, mean ISL, mean OSL,
    /// ISL cv, OSL cv).
    pub fn calibration(&self) -> (usize, f64, f64, f64, f64) {
        match self {
            TraceKind::AzureCode => (19_366, 2047.0, 28.0, 1.3, 0.6),
            TraceKind::AzureConv => (8_819, 1155.0, 211.0, 1.1, 1.0),
            TraceKind::Mooncake => (1_000, 12_035.0, 343.0, 0.9, 0.8),
        }
    }

    pub fn all() -> [TraceKind; 3] {
        [TraceKind::AzureCode, TraceKind::AzureConv, TraceKind::Mooncake]
    }
}

/// Summary statistics in Table 1's shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceStats {
    pub n_requests: usize,
    pub mean_isl: f64,
    pub mean_osl: f64,
}

/// Generate a trace-calibrated workload: `n` requests (None → the trace's
/// published request count) arriving at `qps`.
pub fn generate(kind: TraceKind, n: Option<usize>, qps: f64, seed: u64) -> Workload {
    let (full_n, isl, osl, isl_cv, osl_cv) = kind.calibration();
    let n = n.unwrap_or(full_n);
    let mut rng = Rng::new(seed ^ 0xD0E7);
    let arrivals = poisson_arrivals(&mut rng, n, qps);
    let requests = arrivals
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            let p = rng.lognormal_mean_cv(isl, isl_cv).round().max(1.0) as u64;
            let o = rng.lognormal_mean_cv(osl, osl_cv).round().max(1.0) as u64;
            // Clamp to sane context bounds (Mooncake prompts cap at 128K).
            Request::new(i as u64, t, p.min(131_072), o.min(16_384))
        })
        .collect();
    Workload {
        name: kind.name().to_string(),
        requests,
    }
}

/// Lookup by CLI name.
pub fn trace_by_name(name: &str) -> Option<TraceKind> {
    match name.to_ascii_lowercase().as_str() {
        "azure-code" | "code" => Some(TraceKind::AzureCode),
        "azure-conv" | "conv" => Some(TraceKind::AzureConv),
        "mooncake" => Some(TraceKind::Mooncake),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_means_match_table1() {
        for kind in TraceKind::all() {
            let (_, isl, osl, _, _) = kind.calibration();
            let w = generate(kind, Some(4000), 10.0, 7);
            let s = w.stats();
            assert!(
                (s.mean_isl - isl).abs() / isl < 0.08,
                "{}: isl {} vs target {}",
                kind.name(),
                s.mean_isl,
                isl
            );
            assert!(
                (s.mean_osl - osl).abs() / osl < 0.08,
                "{}: osl {} vs target {}",
                kind.name(),
                s.mean_osl,
                osl
            );
        }
    }

    #[test]
    fn default_counts_match_table1() {
        // Don't generate all 19K for azure-code in a unit test; just check
        // the published count is wired through.
        assert_eq!(TraceKind::AzureCode.calibration().0, 19_366);
        assert_eq!(TraceKind::AzureConv.calibration().0, 8_819);
        assert_eq!(TraceKind::Mooncake.calibration().0, 1_000);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(TraceKind::AzureConv, Some(100), 5.0, 42);
        let b = generate(TraceKind::AzureConv, Some(100), 5.0, 42);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.output_len, y.output_len);
            assert_eq!(x.arrival, y.arrival);
        }
        let c = generate(TraceKind::AzureConv, Some(100), 5.0, 43);
        assert!(a
            .requests
            .iter()
            .zip(&c.requests)
            .any(|(x, y)| x.prompt_len != y.prompt_len));
    }

    #[test]
    fn mooncake_prompts_are_long() {
        let w = generate(TraceKind::Mooncake, Some(500), 2.0, 1);
        let s = w.stats();
        assert!(s.mean_isl > 8000.0, "mooncake is prefill-heavy");
        // code trace has much shorter outputs than conv
        let code = generate(TraceKind::AzureCode, Some(500), 2.0, 1).stats();
        let conv = generate(TraceKind::AzureConv, Some(500), 2.0, 1).stats();
        assert!(code.mean_osl < conv.mean_osl);
    }

    #[test]
    fn name_lookup() {
        assert_eq!(trace_by_name("mooncake"), Some(TraceKind::Mooncake));
        assert_eq!(trace_by_name("Azure-Code"), Some(TraceKind::AzureCode));
        assert_eq!(trace_by_name("nope"), None);
    }
}
