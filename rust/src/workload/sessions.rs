//! Multi-turn session workloads for prefix-cache evaluation.
//!
//! Real conversational traffic (Azure Conversation, Mooncake) is not a
//! stream of independent prompts: turn `k` of a session resends turn
//! `k-1`'s entire context plus the assistant's reply and one new user
//! message, and every session under a tenant opens with the same system
//! prompt. That structure is exactly what block-level prefix caching
//! (`kvcache::prefix`) exploits, so these generators *materialize* prompt
//! token ids deterministically: turn `k`'s token vector is a strict
//! extension of turn `k-1`'s, and same-tenant sessions share their system
//! prefix byte-for-byte. The prefix index then discovers the sharing
//! through content hashes alone — nothing here talks to the cache.
//!
//! Arrivals follow the existing processes: session starts are Poisson
//! ([`poisson_arrivals`]), turns within a session are separated by
//! exponential think times.

use crate::request::Request;
use crate::util::rng::Rng;
use crate::workload::arrivals::poisson_arrivals;
use crate::workload::Workload;

/// Shape of a multi-turn session mix.
#[derive(Debug, Clone)]
pub struct SessionProfile {
    /// Number of concurrent conversation sessions.
    pub sessions: usize,
    /// Turns per session (each turn is one request).
    pub turns: usize,
    /// Shared system-prompt length per tenant, tokens.
    pub system_tokens: u64,
    /// New user-message length per turn, tokens.
    pub user_tokens: u64,
    /// Assistant reply length per turn (the request's `output_len`; the
    /// reply is replayed into the next turn's prompt as history).
    pub output_tokens: u64,
    /// Tenants; session `s` belongs to tenant `s % tenants` and shares its
    /// system prompt with every other session of that tenant.
    pub tenants: usize,
    /// Session-start rate (sessions/second, Poisson).
    pub session_qps: f64,
    /// Mean user think time between a turn's arrival and the next, seconds.
    pub mean_think_s: f64,
}

impl SessionProfile {
    /// A small default mix: 32 sessions × 4 turns, 512-token system
    /// prompts over 4 tenants — enough history growth to exercise reuse
    /// and eviction at modest KV capacities.
    pub fn default_mix() -> SessionProfile {
        SessionProfile {
            sessions: 32,
            turns: 4,
            system_tokens: 512,
            user_tokens: 128,
            output_tokens: 64,
            tenants: 4,
            session_qps: 2.0,
            mean_think_s: 2.0,
        }
    }
}

/// SplitMix64 finalizer — local copy so token-id derivation does not
/// depend on `kvcache` internals.
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Deterministic token id `i` of content stream `stream` (vocab 32000).
fn tok(stream: u64, i: u64) -> i32 {
    (splitmix(splitmix(stream) ^ i) % 32_000) as i32
}

/// Append `n` tokens of content stream `stream` to `buf`.
fn extend_stream(buf: &mut Vec<i32>, stream: u64, n: u64) {
    buf.extend((0..n).map(|i| tok(stream, i)));
}

/// Multi-turn conversations with per-tenant shared system prompts.
///
/// Turn `k` of session `s` (tenant `t = s % tenants`) carries the prompt
/// `system(t) ‖ user(s,0) ‖ reply(s,0) ‖ … ‖ user(s,k)` — a strict token
/// extension of turn `k-1`'s prompt plus that turn's replayed reply. All
/// content is deterministic in `seed`, so reruns are reproducible and the
/// cache-off/cache-on comparison sees identical work.
pub fn session_workload(p: &SessionProfile, seed: u64) -> Workload {
    assert!(p.sessions > 0 && p.turns > 0 && p.tenants > 0);
    assert!(
        p.system_tokens + p.user_tokens > 0,
        "turns need a non-empty prompt"
    );
    let mut rng = Rng::new(seed ^ 0x5e55);
    let starts = poisson_arrivals(&mut rng, p.sessions, p.session_qps);
    let mut requests = Vec::with_capacity(p.sessions * p.turns);
    let mut id = 0u64;
    for (s, &start) in starts.iter().enumerate() {
        let tenant = (s % p.tenants) as u64;
        // Content streams are keyed off the seed so two workloads with
        // different seeds do not accidentally share cache entries.
        let session_key = splitmix(seed) ^ splitmix(0x5e55_0000 + s as u64);
        let mut history: Vec<i32> = Vec::new();
        extend_stream(&mut history, splitmix(seed) ^ tenant, p.system_tokens);
        let mut at = start;
        for turn in 0..p.turns {
            extend_stream(&mut history, session_key ^ (2 * turn as u64), p.user_tokens);
            let prompt = history.clone();
            requests.push(
                Request::new(id, at, prompt.len() as u64, p.output_tokens)
                    .with_prompt_tokens(prompt),
            );
            id += 1;
            // Replay the assistant reply into the next turn's history.
            extend_stream(
                &mut history,
                session_key ^ (2 * turn as u64 + 1),
                p.output_tokens,
            );
            at += rng.exponential(1.0 / p.mean_think_s.max(1e-9));
        }
    }
    Workload {
        name: format!("sessions-{}x{}", p.sessions, p.turns),
        requests,
    }
    .sorted_by_arrival()
}

/// Single-turn requests whose prompts open with a tenant-shared prefix of
/// `shared_tokens` and end with a per-request unique suffix of
/// `unique_tokens` — the bench knob for sweeping prefix-cache hit rates:
/// after warm-up the cacheable fraction of prefill is
/// `shared_tokens / (shared_tokens + unique_tokens)` (rounded down to KV
/// block granularity). `shared_tokens = 0` degenerates to fully disjoint
/// prompts.
pub fn shared_prefix_workload(
    n: usize,
    shared_tokens: u64,
    unique_tokens: u64,
    osl: u64,
    qps: f64,
    tenants: usize,
    seed: u64,
) -> Workload {
    assert!(tenants > 0);
    assert!(shared_tokens + unique_tokens > 0, "empty prompt");
    let mut rng = Rng::new(seed ^ 0x5e56);
    let arrivals = poisson_arrivals(&mut rng, n, qps);
    let requests = arrivals
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            let tenant = (i % tenants) as u64;
            let mut prompt = Vec::with_capacity((shared_tokens + unique_tokens) as usize);
            extend_stream(&mut prompt, splitmix(seed) ^ tenant, shared_tokens);
            extend_stream(
                &mut prompt,
                splitmix(seed ^ 0xffff) ^ splitmix(i as u64 + 1),
                unique_tokens,
            );
            Request::new(i as u64, t, prompt.len() as u64, osl).with_prompt_tokens(prompt)
        })
        .collect();
    Workload {
        name: format!("shared-prefix-{shared_tokens}+{unique_tokens}"),
        requests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn turn_prompts_strictly_extend_previous_turns() {
        let p = SessionProfile {
            sessions: 3,
            turns: 4,
            system_tokens: 32,
            user_tokens: 8,
            output_tokens: 4,
            tenants: 2,
            session_qps: 5.0,
            mean_think_s: 0.5,
        };
        let w = session_workload(&p, 7);
        assert_eq!(w.requests.len(), 12);
        // Group back by session via id order (ids were assigned
        // session-major before the arrival sort).
        let mut by_id = w.requests.clone();
        by_id.sort_by_key(|r| r.id);
        for s in 0..3 {
            let turns = &by_id[s * 4..(s + 1) * 4];
            for k in 1..4 {
                let prev = turns[k - 1].prompt_tokens.as_ref().unwrap();
                let cur = turns[k].prompt_tokens.as_ref().unwrap();
                assert!(cur.starts_with(prev), "turn {k} must extend turn {}", k - 1);
                // history grows by the replayed reply + new user message
                assert_eq!(cur.len(), prev.len() + 4 + 8);
            }
            // turn arrivals are monotone within the session
            for k in 1..4 {
                assert!(turns[k].arrival > turns[k - 1].arrival);
            }
        }
    }

    #[test]
    fn same_tenant_sessions_share_the_system_prompt() {
        let p = SessionProfile {
            sessions: 4,
            turns: 1,
            system_tokens: 64,
            user_tokens: 8,
            output_tokens: 2,
            tenants: 2,
            session_qps: 5.0,
            mean_think_s: 0.5,
        };
        let mut by_id = session_workload(&p, 9).requests;
        by_id.sort_by_key(|r| r.id);
        let sys = |r: &Request| r.prompt_tokens.as_ref().unwrap()[..64].to_vec();
        // sessions 0 and 2 are tenant 0; 1 and 3 are tenant 1
        assert_eq!(sys(&by_id[0]), sys(&by_id[2]));
        assert_eq!(sys(&by_id[1]), sys(&by_id[3]));
        assert_ne!(sys(&by_id[0]), sys(&by_id[1]));
        // user turns differ across sessions even within a tenant
        assert_ne!(
            by_id[0].prompt_tokens.as_ref().unwrap()[64..],
            by_id[2].prompt_tokens.as_ref().unwrap()[64..]
        );
    }

    #[test]
    fn arrivals_sorted_and_ids_unique() {
        let w = session_workload(&SessionProfile::default_mix(), 3);
        assert!(w
            .requests
            .windows(2)
            .all(|p| p[0].arrival <= p[1].arrival));
        let mut ids: Vec<u64> = w.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.requests.len());
    }

    #[test]
    fn shared_prefix_splits_at_the_declared_boundary() {
        let w = shared_prefix_workload(6, 48, 16, 4, 10.0, 2, 11);
        assert_eq!(w.requests.len(), 6);
        for r in &w.requests {
            assert_eq!(r.prompt_len, 64);
            assert_eq!(r.output_len, 4);
        }
        let toks = |i: usize| w.requests[i].prompt_tokens.as_ref().unwrap();
        // same tenant (0 and 2): identical shared prefix, distinct suffix
        assert_eq!(toks(0)[..48], toks(2)[..48]);
        assert_ne!(toks(0)[48..], toks(2)[48..]);
        // different tenants (0 and 1): prefixes differ
        assert_ne!(toks(0)[..48], toks(1)[..48]);
    }

    #[test]
    fn zero_shared_prefix_is_fully_disjoint() {
        let w = shared_prefix_workload(4, 0, 32, 2, 10.0, 2, 13);
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(
                    w.requests[i].prompt_tokens.as_ref().unwrap()[..8],
                    w.requests[j].prompt_tokens.as_ref().unwrap()[..8],
                    "suffix streams must diverge immediately"
                );
            }
        }
    }
}
