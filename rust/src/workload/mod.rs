//! Workload generation: trace-calibrated request streams.
//!
//! The paper evaluates on Azure Code, Azure Conversation (Microsoft 2023)
//! and Mooncake Conversation (Qin et al. 2025) traces. The raw traces are
//! external downloads not available offline, so `traces` provides synthetic
//! generators calibrated to the published Table 1 statistics (mean
//! ISL/OSL, request counts) with lognormal length distributions; arrivals
//! follow a Poisson process, as in the paper (§5.1). `synthetic` provides
//! the fixed-ISL/OSL workloads of Table 2 and the Fig. 2 demo workload.

pub mod arrivals;
pub mod sessions;
pub mod synthetic;
pub mod traces;

pub use arrivals::{burst_arrivals, diurnal_arrivals, poisson_arrivals, thinned_arrivals};
pub use sessions::{session_workload, shared_prefix_workload, SessionProfile};
pub use synthetic::{burst_mix_workload, fixed_workload, BurstProfile};
pub use traces::{trace_by_name, TraceKind, TraceStats};

use crate::request::Request;

/// A generated workload: requests with arrival times, sorted by arrival.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub requests: Vec<Request>,
}

impl Workload {
    /// Published-table-style statistics of this workload.
    pub fn stats(&self) -> TraceStats {
        let n = self.requests.len();
        let isl = self.requests.iter().map(|r| r.prompt_len as f64).sum::<f64>() / n.max(1) as f64;
        let osl = self.requests.iter().map(|r| r.output_len as f64).sum::<f64>() / n.max(1) as f64;
        TraceStats {
            n_requests: n,
            mean_isl: isl,
            mean_osl: osl,
        }
    }

    /// Total prompt + output tokens.
    pub fn total_tokens(&self) -> u64 {
        self.requests
            .iter()
            .map(|r| r.prompt_len + r.output_len)
            .sum()
    }

    /// Keep only the first `n` requests (Mooncake is sampled to 1000 in
    /// the paper).
    pub fn take(mut self, n: usize) -> Workload {
        self.requests.truncate(n);
        self
    }

    /// Enforce the arrival-order invariant the engines' pending queues
    /// rely on. Generators already emit sorted streams; hand-built or
    /// merged workloads (multi-tenant experiments) go through this.
    pub fn sorted_by_arrival(mut self) -> Workload {
        self.requests
            .sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_computed_over_requests() {
        let w = Workload {
            name: "t".into(),
            requests: vec![
                Request::new(0, 0.0, 100, 10),
                Request::new(1, 0.5, 300, 30),
            ],
        };
        let s = w.stats();
        assert_eq!(s.n_requests, 2);
        assert!((s.mean_isl - 200.0).abs() < 1e-9);
        assert!((s.mean_osl - 20.0).abs() < 1e-9);
        assert_eq!(w.total_tokens(), 440);
    }

    #[test]
    fn sorted_by_arrival_orders_requests() {
        let w = Workload {
            name: "t".into(),
            requests: vec![
                Request::new(0, 2.0, 10, 1),
                Request::new(1, 0.5, 10, 1),
                Request::new(2, 1.0, 10, 1),
            ],
        }
        .sorted_by_arrival();
        let order: Vec<u64> = w.requests.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn take_truncates() {
        let w = Workload {
            name: "t".into(),
            requests: (0..10).map(|i| Request::new(i, i as f64, 10, 1)).collect(),
        };
        assert_eq!(w.take(3).requests.len(), 3);
    }
}
