//! GPU hardware presets driving the simulator and the roofline model.
//!
//! The paper's testbed is NVIDIA H100 SXM (80 GB, NVLink); Fig. 1(a) also
//! profiles A100. Only aggregate characteristics matter to the scheduler:
//! peak dense-bf16 FLOP/s, HBM bandwidth, SM/TPC counts, and how achievable
//! throughput/bandwidth scale with the number of *active* SMs (Fig. 3a).

/// Static description of one GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Streaming multiprocessors (H100 SXM: 132).
    pub num_sms: u32,
    /// SMs per TPC — the smallest partitioning unit (2 on H100/A100).
    pub sms_per_tpc: u32,
    /// Peak dense bf16/fp16 tensor-core throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub hbm_bandwidth: f64,
    /// HBM capacity, bytes.
    pub hbm_capacity: f64,
    /// Aggregate unidirectional NVLink bandwidth per GPU, bytes/s.
    pub nvlink_bandwidth: f64,
    /// Ring all-reduce startup latency, seconds (paper: ~3 µs on H100).
    pub allreduce_alpha: f64,
    /// Per-kernel CPU launch overhead, seconds (individual launch).
    pub kernel_launch_overhead: f64,
    /// CUDA-graph-style whole-graph replay overhead, seconds
    /// (paper: < 0.5 ms per decode graph launch).
    pub graph_launch_overhead: f64,
    /// Bandwidth-scaling shape parameter: fraction-of-peak-BW achieved by a
    /// fraction `x` of SMs is `x * (1 + k) / (x + k)` — super-linear, with
    /// k calibrated so 20% of SMs reach ≈60% of peak (paper Fig. 3a).
    pub bw_curve_k: f64,
    /// GEMM saturation constant: large-matmul efficiency reaches 1-1/e of
    /// its asymptote at this many tokens (tile/wave quantization — newer
    /// GPUs with bigger tensor-core tiles saturate later, which is why
    /// the Fig. 1a knee moves from ~2K on A100 to ~8K on H100).
    pub gemm_nhalf: f64,
}

impl GpuSpec {
    /// NVIDIA H100 SXM5 80 GB.
    pub fn h100() -> GpuSpec {
        GpuSpec {
            name: "H100".to_string(),
            num_sms: 132,
            sms_per_tpc: 2,
            peak_flops: 989e12,        // dense bf16
            hbm_bandwidth: 3.35e12,    // HBM3
            hbm_capacity: 80e9,
            nvlink_bandwidth: 450e9,   // NVLink 4 unidirectional
            allreduce_alpha: 3e-6,
            kernel_launch_overhead: 6e-6,
            graph_launch_overhead: 0.4e-3,
            bw_curve_k: 0.2,
            gemm_nhalf: 2700.0,
        }
    }

    /// NVIDIA A100 SXM4 80 GB.
    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "A100".to_string(),
            num_sms: 108,
            sms_per_tpc: 2,
            peak_flops: 312e12,       // dense bf16
            hbm_bandwidth: 2.0e12,    // HBM2e
            hbm_capacity: 80e9,
            nvlink_bandwidth: 300e9,
            allreduce_alpha: 4e-6,
            kernel_launch_overhead: 7e-6,
            graph_launch_overhead: 0.5e-3,
            bw_curve_k: 0.2,
            gemm_nhalf: 680.0,
        }
    }

    /// Hypothetical compute-optimized part (Appendix B's heterogeneous
    /// deployment direction): H100-class MXU throughput, half the HBM
    /// bandwidth — a good *prefill* worker.
    pub fn compute_optimized() -> GpuSpec {
        let mut g = GpuSpec::h100();
        g.name = "C-OPT".to_string();
        g.hbm_bandwidth = 1.7e12;
        g
    }

    /// Hypothetical memory-optimized part: full HBM3 bandwidth, 40% of
    /// the compute — a good *decode* worker.
    pub fn memory_optimized() -> GpuSpec {
        let mut g = GpuSpec::h100();
        g.name = "M-OPT".to_string();
        g.peak_flops = 0.4 * 989e12;
        g
    }

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_lowercase().as_str() {
            "h100" => Some(GpuSpec::h100()),
            "a100" => Some(GpuSpec::a100()),
            "c-opt" | "compute" => Some(GpuSpec::compute_optimized()),
            "m-opt" | "memory" => Some(GpuSpec::memory_optimized()),
            _ => None,
        }
    }

    /// Number of TPCs (partitioning units). H100: 66.
    pub fn num_tpcs(&self) -> u32 {
        self.num_sms / self.sms_per_tpc
    }

    /// Achievable compute throughput (FLOP/s) with `s` active SMs.
    /// FLOPs scale ~linearly with SM count (Fig. 3a), with TPC-granular
    /// quantization applied by the caller.
    pub fn pi_sm(&self, s: u32) -> f64 {
        let s = s.min(self.num_sms);
        self.peak_flops * s as f64 / self.num_sms as f64
    }

    /// Achievable HBM bandwidth (bytes/s) with `s` active SMs.
    /// Super-linear saturating curve: x(1+k)/(x+k); 20% of SMs already
    /// reach ≈60% of peak with k = 0.2 (paper Fig. 3a).
    pub fn b_hbm(&self, s: u32) -> f64 {
        let s = s.min(self.num_sms);
        if s == 0 {
            return 0.0;
        }
        let x = s as f64 / self.num_sms as f64;
        let k = self.bw_curve_k;
        self.hbm_bandwidth * x * (1.0 + k) / (x + k)
    }

    /// Ridge point in FLOP/byte for the full GPU: ops per byte at which a
    /// kernel transitions from memory- to compute-bound.
    pub fn ridge(&self) -> f64 {
        self.peak_flops / self.hbm_bandwidth
    }

    /// Achieved fraction of large-GEMM efficiency at `n` tokens:
    /// `1 - exp(-n / gemm_nhalf)`. Reaches ~95% at ≈3·nhalf, putting the
    /// Fig. 1a knees near 2K (A100) and 8K (H100) tokens.
    pub fn gemm_eff(&self, n_tokens: u64) -> f64 {
        1.0 - (-(n_tokens as f64) / self.gemm_nhalf).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_has_66_tpcs() {
        assert_eq!(GpuSpec::h100().num_tpcs(), 66);
    }

    #[test]
    fn flops_scale_linearly() {
        let g = GpuSpec::h100();
        let half = g.pi_sm(66);
        assert!((half / g.peak_flops - 0.5).abs() < 1e-9);
        assert_eq!(g.pi_sm(132), g.peak_flops);
        // clamped above num_sms
        assert_eq!(g.pi_sm(500), g.peak_flops);
    }

    #[test]
    fn bandwidth_superlinear_20pct_gives_60pct() {
        let g = GpuSpec::h100();
        let s20 = (g.num_sms as f64 * 0.2).round() as u32;
        let frac = g.b_hbm(s20) / g.hbm_bandwidth;
        assert!(
            (frac - 0.6).abs() < 0.02,
            "20% SMs should give ~60% bandwidth, got {frac}"
        );
        // full allocation reaches peak
        assert!((g.b_hbm(g.num_sms) / g.hbm_bandwidth - 1.0).abs() < 1e-9);
        assert_eq!(g.b_hbm(0), 0.0);
    }

    #[test]
    fn bandwidth_monotone_in_sms() {
        let g = GpuSpec::h100();
        let mut prev = 0.0;
        for s in 1..=g.num_sms {
            let b = g.b_hbm(s);
            assert!(b > prev);
            prev = b;
        }
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(GpuSpec::by_name("h100").unwrap().name, "H100");
        assert_eq!(GpuSpec::by_name("A100").unwrap().name, "A100");
        assert!(GpuSpec::by_name("tpu").is_none());
    }

    #[test]
    fn ridge_point_orders_generations() {
        // H100's ridge (flops/byte) exceeds A100's — the knee moves right,
        // which is exactly the Fig. 1(a) observation (2K -> 8K tokens).
        assert!(GpuSpec::h100().ridge() > GpuSpec::a100().ridge());
    }
}
