//! Serving-engine configuration: scheduler policy, SLOs, budgets.

use crate::config::gpu::GpuSpec;
use crate::config::model::ModelSpec;

/// Default divergence horizon in engine-clock seconds per epoch
/// (historically `engine::MAX_SIM_TIME`). A core whose *epoch-local*
/// clock passes this has diverged (arrival rate above capacity with an
/// unbounded queue) and drains. Long-lived serving re-bases the clock to
/// a new epoch whenever the topology goes fully idle, re-arming this
/// guard — see `ServingConfig::max_engine_time` and the engine-epoch
/// machinery in `engine::core`.
pub const DEFAULT_MAX_ENGINE_TIME: f64 = 3.0e4;

/// Which scheduling policy an engine runs. Mirrors the paper's baselines
/// (§5.1) plus the ablation configurations (Appendix A).
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// vLLM v0.10-style chunked prefill with a fixed token budget.
    VllmChunked,
    /// SGLang default: throughput-oriented, runs prefill-only batches
    /// opportunistically before draining decodes.
    SglangDefault,
    /// SGLang with `enable-mixed-chunk` (Sarathi-style chunked prefill).
    SglangChunked,
    /// Dynamo-style PD disaggregation (1 prefill GPU + 1 decode GPU, KV
    /// transfer between them).
    DisaggPD { prefill_gpus: u32, decode_gpus: u32 },
    /// DuetServe: chunked prefill + roofline TBT check + adaptive SM
    /// partitioning (Algorithm 1).
    Duet,
    /// Ablation: spatial multiplexing with a *static* SM split
    /// (`Sd<d>-Sp<p>` in Fig. 9), in TPC units.
    StaticPartition { decode_tpcs: u32, prefill_tpcs: u32 },
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::VllmChunked => "vLLM".into(),
            Policy::SglangDefault => "SGLang-Default".into(),
            Policy::SglangChunked => "SGLang-Chunked".into(),
            Policy::DisaggPD {
                prefill_gpus,
                decode_gpus,
            } => format!("Dynamo-{prefill_gpus}P{decode_gpus}D"),
            Policy::Duet => "DuetServe".into(),
            Policy::StaticPartition {
                decode_tpcs,
                prefill_tpcs,
            } => format!("Sd{decode_tpcs}-Sp{prefill_tpcs}"),
        }
    }
}

/// Full engine configuration.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    /// Tensor-parallel degree (GPUs in the aggregated group).
    pub tp: u32,
    pub policy: Policy,
    /// Chunked-prefill token budget (paper: 8192 on H100, 2048 on A100).
    pub token_budget: u32,
    /// Decode TBT SLO in seconds (paper uses 100 ms as "typical").
    pub tbt_slo: f64,
    /// Maximum running batch size (paper baseline config: 1024).
    pub max_batch: u32,
    /// Fraction of HBM usable for KV cache after weights (paper: 0.9
    /// utilization ratio overall).
    pub gpu_mem_util: f64,
    /// Paged KV cache block size in tokens (vLLM default 16).
    pub kv_block_tokens: u32,
    /// Upper bound on the look-ahead decode steps `k`.
    pub max_lookahead: u32,
    /// Scheduler admission: stop admitting prefill when free KV blocks drop
    /// below this fraction.
    pub kv_watermark: f64,
    /// Per-epoch divergence horizon, engine-clock seconds
    /// ([`DEFAULT_MAX_ENGINE_TIME`]). Overridable (hidden
    /// `--max-engine-time` CLI flag) so CI soak tests can exercise
    /// epoch re-basing without simulating 3·10⁴ engine-seconds.
    pub max_engine_time: f64,
    /// Block-level prefix caching (`kvcache::prefix`): finished requests
    /// decay their prompt KV blocks into a cached LRU pool and admission
    /// seeds new requests with the longest cached prefix. Off by default
    /// — reuse only helps when prompts actually overlap, and the
    /// zero-overlap equivalence tests pin the off-path behavior.
    pub prefix_cache: bool,
    /// Class-aware QoS preemption in the duet scheduler: when a
    /// latency-class decode faces a predicted TBT violation that even
    /// Algorithm 1 cannot solve, shed lower-class prefill chunks before
    /// shedding everything. On by default; with a single class or no SLO
    /// pressure the scheduler's decisions are bitwise-unchanged, so the
    /// flag only matters for mixed-class traffic (and for pinning the
    /// FCFS baseline in benches).
    pub qos_preemption: bool,
}

impl ServingConfig {
    /// Paper's default: Qwen3-8B on one H100, DuetServe policy.
    pub fn default_8b() -> ServingConfig {
        ServingConfig {
            model: ModelSpec::qwen3_8b(),
            gpu: GpuSpec::h100(),
            tp: 1,
            policy: Policy::Duet,
            token_budget: 8192,
            tbt_slo: 0.100,
            max_batch: 1024,
            gpu_mem_util: 0.9,
            kv_block_tokens: 16,
            max_lookahead: 16,
            kv_watermark: 0.02,
            max_engine_time: DEFAULT_MAX_ENGINE_TIME,
            prefix_cache: false,
            qos_preemption: true,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> ServingConfig {
        self.policy = policy;
        self
    }

    pub fn with_model(mut self, model: ModelSpec, tp: u32) -> ServingConfig {
        self.model = model;
        self.tp = tp;
        self
    }

    pub fn with_prefix_cache(mut self, on: bool) -> ServingConfig {
        self.prefix_cache = on;
        self
    }

    pub fn with_qos(mut self, on: bool) -> ServingConfig {
        self.qos_preemption = on;
        self
    }

    /// KV-cache capacity in tokens on this GPU group: (mem_util × HBM −
    /// weights) / kv-bytes-per-token, across `tp` GPUs (cache is sharded by
    /// kv-head under TP, so capacity scales with tp).
    pub fn kv_capacity_tokens(&self) -> u64 {
        let per_gpu_budget = self.gpu.hbm_capacity * self.gpu_mem_util;
        let weights = self.model.weight_bytes_per_gpu(self.tp) as f64;
        let free = (per_gpu_budget - weights).max(0.0) * self.tp as f64;
        // Reserve ~5% for activations / workspace.
        let usable = free * 0.95;
        (usable / self.model.kv_bytes_per_token() as f64) as u64
    }

    /// Total KV blocks available.
    pub fn kv_capacity_blocks(&self) -> u64 {
        self.kv_capacity_tokens() / self.kv_block_tokens as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_capacity_positive_for_8b_on_h100() {
        let c = ServingConfig::default_8b();
        let toks = c.kv_capacity_tokens();
        // 0.9*80GB - ~16.4GB weights ≈ 55GB → /147456 B/token ≈ ~350K tokens
        assert!(
            (200_000..600_000).contains(&toks),
            "kv capacity tokens = {toks}"
        );
    }

    #[test]
    fn tp2_increases_capacity() {
        let c1 = ServingConfig::default_8b().with_model(ModelSpec::qwen3_14b(), 1);
        let c2 = ServingConfig::default_8b().with_model(ModelSpec::qwen3_14b(), 2);
        assert!(c2.kv_capacity_tokens() > c1.kv_capacity_tokens());
    }

    #[test]
    fn policy_names() {
        assert_eq!(Policy::Duet.name(), "DuetServe");
        assert_eq!(
            Policy::DisaggPD {
                prefill_gpus: 1,
                decode_gpus: 1
            }
            .name(),
            "Dynamo-1P1D"
        );
        assert_eq!(
            Policy::StaticPartition {
                decode_tpcs: 22,
                prefill_tpcs: 44
            }
            .name(),
            "Sd22-Sp44"
        );
    }

    #[test]
    fn blocks_are_tokens_over_block_size() {
        let c = ServingConfig::default_8b();
        assert_eq!(
            c.kv_capacity_blocks(),
            c.kv_capacity_tokens() / c.kv_block_tokens as u64
        );
    }
}
