//! Transformer model-shape presets.
//!
//! The serving system's behaviour depends only on tensor shapes (embedding
//! dim, layer count, GQA head counts, FFN width, vocab), not weights. The
//! paper evaluates Qwen3-8B (TP=1), Qwen3-14B (TP=2) and Qwen3-32B (TP=8);
//! `tiny()` is the ~25M-parameter model actually executed on the CPU PJRT
//! path (examples/e2e_serve).

/// Architecture hyper-parameters of a dense decoder-only transformer
/// (Qwen3/Llama-style: GQA attention + SwiGLU MLP).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Embedding / hidden dimension `d`.
    pub hidden: u32,
    /// Number of transformer blocks `L`.
    pub layers: u32,
    /// Query heads `h_q`.
    pub heads: u32,
    /// Key/value heads `h_kv` (GQA).
    pub kv_heads: u32,
    /// Per-head dimension `d_h`.
    pub head_dim: u32,
    /// FFN intermediate dimension `m`.
    pub intermediate: u32,
    /// Vocabulary size (drives the final classifier cost).
    pub vocab: u32,
    /// Bytes per element (2 = bf16).
    pub elem_bytes: u32,
}

impl ModelSpec {
    pub fn qwen3_8b() -> ModelSpec {
        ModelSpec {
            name: "Qwen3-8B".into(),
            hidden: 4096,
            layers: 36,
            heads: 32,
            kv_heads: 8,
            head_dim: 128,
            intermediate: 12288,
            vocab: 151_936,
            elem_bytes: 2,
        }
    }

    pub fn qwen3_14b() -> ModelSpec {
        ModelSpec {
            name: "Qwen3-14B".into(),
            hidden: 5120,
            layers: 40,
            heads: 40,
            kv_heads: 8,
            head_dim: 128,
            intermediate: 17408,
            vocab: 151_936,
            elem_bytes: 2,
        }
    }

    pub fn qwen3_32b() -> ModelSpec {
        ModelSpec {
            name: "Qwen3-32B".into(),
            hidden: 5120,
            layers: 64,
            heads: 64,
            kv_heads: 8,
            head_dim: 128,
            intermediate: 25600,
            vocab: 151_936,
            elem_bytes: 2,
        }
    }

    /// The tiny Qwen3-style model that is actually compiled through
    /// JAX/Pallas and served via PJRT on CPU. Shapes must match
    /// `python/compile/model.py::TINY`.
    pub fn tiny() -> ModelSpec {
        ModelSpec {
            name: "Tiny-25M".into(),
            hidden: 256,
            layers: 4,
            heads: 8,
            kv_heads: 4,
            head_dim: 32,
            intermediate: 1024,
            vocab: 2048,
            elem_bytes: 4, // f32 on the CPU PJRT path
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name.to_ascii_lowercase().as_str() {
            "qwen3-8b" | "8b" => Some(ModelSpec::qwen3_8b()),
            "qwen3-14b" | "14b" => Some(ModelSpec::qwen3_14b()),
            "qwen3-32b" | "32b" => Some(ModelSpec::qwen3_32b()),
            "tiny" | "tiny-25m" => Some(ModelSpec::tiny()),
            _ => None,
        }
    }

    /// Total parameter count (embedding + blocks + classifier).
    pub fn param_count(&self) -> u64 {
        let d = self.hidden as u64;
        let m = self.intermediate as u64;
        let dh = self.head_dim as u64;
        let hq = self.heads as u64;
        let hkv = self.kv_heads as u64;
        let attn = d * hq * dh       // W_q
            + 2 * d * hkv * dh       // W_k, W_v
            + hq * dh * d;           // W_o
        let mlp = 3 * d * m;         // gate, up, down
        let norms = 2 * d;
        let block = attn + mlp + norms;
        let emb = self.vocab as u64 * d;
        emb + self.layers as u64 * block + d /* final norm */ + emb /* lm head */
    }

    /// KV-cache bytes per token (all layers, both K and V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.layers as u64
            * self.kv_heads as u64
            * self.head_dim as u64
            * self.elem_bytes as u64
    }

    /// Weight bytes on one GPU under tensor parallel degree `tp`
    /// (weights divided; embeddings replicated for simplicity).
    pub fn weight_bytes_per_gpu(&self, tp: u32) -> u64 {
        let params = self.param_count();
        let emb = 2 * self.vocab as u64 * self.hidden as u64;
        let sharded = (params - emb) / tp as u64;
        (sharded + emb) * self.elem_bytes as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen3_8b_param_count_in_range() {
        let p = ModelSpec::qwen3_8b().param_count();
        // ~8.2B params
        assert!(
            (7.0e9..9.5e9).contains(&(p as f64)),
            "Qwen3-8B params = {p}"
        );
    }

    #[test]
    fn qwen3_14b_param_count_in_range() {
        let p = ModelSpec::qwen3_14b().param_count();
        assert!((13.0e9..16.5e9).contains(&(p as f64)), "14B params = {p}");
    }

    #[test]
    fn tiny_model_is_tiny() {
        let p = ModelSpec::tiny().param_count();
        assert!((1e6..5e7).contains(&(p as f64)), "tiny params = {p}");
    }

    #[test]
    fn kv_bytes_per_token_8b() {
        // 2 * 36 layers * 8 kv heads * 128 dim * 2 bytes = 147456 B/token
        assert_eq!(ModelSpec::qwen3_8b().kv_bytes_per_token(), 147_456);
    }

    #[test]
    fn head_dims_consistent() {
        for m in [
            ModelSpec::qwen3_8b(),
            ModelSpec::qwen3_14b(),
            ModelSpec::qwen3_32b(),
            ModelSpec::tiny(),
        ] {
            assert_eq!(m.heads % m.kv_heads, 0, "{}: GQA ratio integral", m.name);
        }
    }

    #[test]
    fn tp_reduces_weight_footprint() {
        let m = ModelSpec::qwen3_14b();
        assert!(m.weight_bytes_per_gpu(2) < m.weight_bytes_per_gpu(1));
        // 14B bf16 on one GPU ~29 GB > H100 would still fit in 80GB
        assert!(m.weight_bytes_per_gpu(1) > 25_000_000_000);
    }

    #[test]
    fn by_name_aliases() {
        assert_eq!(ModelSpec::by_name("8b").unwrap().name, "Qwen3-8B");
        assert_eq!(ModelSpec::by_name("TINY").unwrap().name, "Tiny-25M");
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }
}
