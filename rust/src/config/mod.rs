//! Configuration layer: hardware presets, model-shape presets, serving
//! policy config, and the launcher's TOML-subset parser.

pub mod gpu;
pub mod model;
pub mod parse;
pub mod serving;

pub use gpu::GpuSpec;
pub use model::ModelSpec;
pub use parse::{Config, Value};
pub use serving::{Policy, ServingConfig, DEFAULT_MAX_ENGINE_TIME};
