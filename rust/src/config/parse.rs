//! Minimal TOML-subset parser for launcher config files (no serde in the
//! offline vendor set).
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (`"..."`), integer, float, and boolean values, `#` comments, blank
//! lines. That covers everything the launcher needs; nested tables and
//! arrays are intentionally out of scope.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: `section.key` → value. Keys before any `[section]` live
/// in the "" (root) section.
#[derive(Debug, Clone, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

/// Parse failure with its 1-based line number. (Display/Error are
/// hand-implemented — no `thiserror` in the offline vendor set.)
#[derive(Debug, PartialEq)]
pub enum ParseError {
    BadSection(usize),
    BadLine(usize),
    BadString(usize),
    BadValue(usize, String),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::BadSection(l) => write!(f, "line {l}: malformed section header"),
            ParseError::BadLine(l) => write!(f, "line {l}: expected `key = value`"),
            ParseError::BadString(l) => write!(f, "line {l}: unterminated string"),
            ParseError::BadValue(l, v) => write!(f, "line {l}: unparseable value `{v}`"),
        }
    }
}

impl std::error::Error for ParseError {}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ParseError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or(ParseError::BadSection(lineno))?
                    .trim();
                if name.is_empty() || name.contains(['[', ']']) {
                    return Err(ParseError::BadSection(lineno));
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or(ParseError::BadLine(lineno))?;
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            if key.is_empty() || val.is_empty() {
                return Err(ParseError::BadLine(lineno));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            map.insert(full_key, parse_value(val, lineno)?);
        }
        Ok(Config { map })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }
    pub fn int(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_int)
    }
    pub fn float(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_float)
    }
    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.int(key).unwrap_or(default)
    }
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.float(key).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.bool(key).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<Value, ParseError> {
    if let Some(rest) = v.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or(ParseError::BadString(lineno))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match v {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError::BadValue(lineno, v.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            r#"
            # launcher config
            name = "duet"          # inline comment
            [engine]
            token_budget = 8192
            tbt_slo = 0.1
            adaptive = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.str("name"), Some("duet"));
        assert_eq!(cfg.int("engine.token_budget"), Some(8192));
        assert_eq!(cfg.float("engine.tbt_slo"), Some(0.1));
        assert_eq!(cfg.bool("engine.adaptive"), Some(true));
    }

    #[test]
    fn int_promotes_to_float() {
        let cfg = Config::parse("x = 3").unwrap();
        assert_eq!(cfg.float("x"), Some(3.0));
    }

    #[test]
    fn defaults() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.int_or("missing", 7), 7);
        assert_eq!(cfg.str_or("missing", "d"), "d");
        assert!(cfg.bool_or("missing", true));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let cfg = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(cfg.str("tag"), Some("a#b"));
    }

    #[test]
    fn error_reports_line() {
        assert_eq!(
            Config::parse("a = 1\nbad line\n").unwrap_err(),
            ParseError::BadLine(2)
        );
        assert_eq!(
            Config::parse("[open\n").unwrap_err(),
            ParseError::BadSection(1)
        );
        assert_eq!(
            Config::parse("s = \"oops\n").unwrap_err(),
            ParseError::BadString(1)
        );
        assert!(matches!(
            Config::parse("v = 1.2.3\n").unwrap_err(),
            ParseError::BadValue(1, _)
        ));
    }

    #[test]
    fn later_keys_override() {
        let cfg = Config::parse("x = 1\nx = 2\n").unwrap();
        assert_eq!(cfg.int("x"), Some(2));
    }
}
