//! GPU partitioning configuration optimizer — Algorithm 1 (§4.2).
//!
//! Given the decode and prefill sub-batches of a mixed iteration whose
//! aggregated latency would violate the TBT SLO, enumerate decode
//! partition sizes `S_d` (TPC granularity), keep those satisfying
//! `t_d(S_d) ≤ τ_TBT`, and for each evaluate `k ∈ {⌊t_p/t_d⌋, ⌊t_p/t_d⌋+1}`
//! look-ahead decode steps, maximizing token throughput
//! `ρ = (k·T_decode + T_prefill) / max(k·t_d, t_p)`.

use crate::config::GpuSpec;
use crate::hw::PartitionPlan;
use crate::roofline::{BatchShape, Predictor};

/// Solve Algorithm 1 with the realized-gap strengthening (see below).
/// Returns `None` when no feasible split exists (no `S_d` keeps decode
/// under the SLO, or either side is empty).
pub fn optimize_partition(
    pred: &Predictor,
    decode: &BatchShape,
    prefill: &BatchShape,
    tbt_slo: f64,
    max_k: u32,
) -> Option<PartitionPlan> {
    optimize_partition_impl(pred, decode, prefill, tbt_slo, max_k, true)
}

/// Algorithm 1 exactly as printed in the paper: the only latency
/// constraint is `t_d(S_d) <= tau` (line 10). Kept for the ablation bench
/// — it can select configs whose realized inter-token gap (span/k)
/// exceeds the SLO.
pub fn optimize_partition_verbatim(
    pred: &Predictor,
    decode: &BatchShape,
    prefill: &BatchShape,
    tbt_slo: f64,
    max_k: u32,
) -> Option<PartitionPlan> {
    optimize_partition_impl(pred, decode, prefill, tbt_slo, max_k, false)
}

fn optimize_partition_impl(
    pred: &Predictor,
    decode: &BatchShape,
    prefill: &BatchShape,
    tbt_slo: f64,
    max_k: u32,
    realized_gap_constraint: bool,
) -> Option<PartitionPlan> {
    if decode.is_empty() || prefill.is_empty() {
        return None;
    }
    let spec: &GpuSpec = &pred.gpu;
    let total_tpcs = spec.num_tpcs();
    let t_decode_tokens = decode.decode_tokens_per_step() as f64;
    let t_prefill_tokens = prefill.n_tokens as f64;

    let mut best: Option<PartitionPlan> = None;
    let mut best_rho = 0.0f64;

    // Enumerate S_d in SM steps of one TPC: `for S_d in range(2, S+1, 2)`
    // (line 8 operates in SMs; leave ≥1 TPC for prefill).
    for d_tpcs in 1..total_tpcs {
        let sd_sms = d_tpcs * spec.sms_per_tpc;
        let t_d = pred.predict_total(decode, sd_sms);
        if t_d > tbt_slo {
            continue; // line 10-12: violates TBT constraint
        }
        let p_tpcs = total_tpcs - d_tpcs;
        let sp_sms = p_tpcs * spec.sms_per_tpc;
        let t_p = pred.predict_total(prefill, sp_sms);

        let k_floor = if t_d > 0.0 {
            ((t_p / t_d).floor() as u32).max(1)
        } else {
            1
        };
        for k in [k_floor, k_floor + 1] {
            let k = k.clamp(1, max_k.max(1));
            let span = (k as f64 * t_d).max(t_p);
            if span <= 0.0 {
                continue;
            }
            // The *realized* decode inter-token gap is span/k (tokens are
            // spaced t_d apart while the decode side is busy, but the
            // iteration only rejoins at the synchronization point). A
            // config whose realized gap exceeds the SLO would satisfy
            // line 10's per-step check yet still violate TBT in practice
            // — reject it. (Strengthening of Algorithm 1; see DESIGN.md.)
            if realized_gap_constraint && span / k as f64 > tbt_slo {
                continue;
            }
            let rho = (k as f64 * t_decode_tokens + t_prefill_tokens) / span;
            if rho > best_rho {
                best_rho = rho;
                let mut plan = PartitionPlan::split(spec, d_tpcs, k);
                plan.t_decode = t_d;
                plan.t_prefill = t_p;
                plan.rho = rho;
                best = Some(plan);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::model::AttnShape;

    fn pred() -> Predictor {
        Predictor::new(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1)
    }

    fn decode_batch(n: u64, ctx: u64) -> BatchShape {
        BatchShape::from_shapes((0..n).map(|_| AttnShape { q: 1, c: ctx }).collect())
    }

    fn prefill_batch(tokens: u64) -> BatchShape {
        BatchShape::from_shapes(vec![AttnShape { q: tokens, c: 0 }])
    }

    #[test]
    fn finds_feasible_plan_under_contention() {
        let p = pred();
        let dec = decode_batch(32, 4096);
        let pre = prefill_batch(8192);
        let plan = optimize_partition(&p, &dec, &pre, 0.100, 16).expect("feasible");
        assert!(plan.is_valid(&p.gpu));
        // decode side must satisfy the SLO
        assert!(plan.t_decode <= 0.100);
        assert!(plan.k >= 1);
        assert!(plan.rho > 0.0);
    }

    #[test]
    fn favors_prefill_heavy_allocation() {
        // §4.2: "naturally favors allocating more SMs to prefill ... since
        // prefill contributes more substantially to total throughput".
        let p = pred();
        let dec = decode_batch(16, 2048);
        let pre = prefill_batch(8192);
        let plan = optimize_partition(&p, &dec, &pre, 0.100, 16).unwrap();
        assert!(
            plan.prefill.n_tpcs > plan.decode.n_tpcs,
            "prefill {} vs decode {} TPCs",
            plan.prefill.n_tpcs,
            plan.decode.n_tpcs
        );
    }

    #[test]
    fn infeasible_slo_returns_none() {
        let p = pred();
        // Huge decode batch at very long context with an absurdly tight SLO.
        let dec = decode_batch(512, 64 * 1024);
        let pre = prefill_batch(8192);
        assert!(optimize_partition(&p, &dec, &pre, 1e-5, 16).is_none());
    }

    #[test]
    fn empty_side_returns_none() {
        let p = pred();
        let dec = decode_batch(8, 1024);
        let pre = prefill_batch(4096);
        assert!(optimize_partition(&p, &BatchShape::default(), &pre, 0.1, 16).is_none());
        assert!(optimize_partition(&p, &dec, &BatchShape::default(), 0.1, 16).is_none());
    }

    #[test]
    fn k_balances_sides() {
        // k should roughly bridge t_p / t_d so neither side idles long.
        let p = pred();
        let dec = decode_batch(32, 4096);
        let pre = prefill_batch(8192);
        let plan = optimize_partition(&p, &dec, &pre, 0.100, 64).unwrap();
        let ratio = plan.t_prefill / plan.t_decode;
        assert!(
            (plan.k as f64 - ratio).abs() <= 1.5,
            "k={} ratio={ratio}",
            plan.k
        );
    }

    #[test]
    fn respects_max_k() {
        let p = pred();
        let dec = decode_batch(4, 512); // tiny decode -> huge t_p/t_d ratio
        let pre = prefill_batch(8192);
        let plan = optimize_partition(&p, &dec, &pre, 0.100, 8).unwrap();
        assert!(plan.k <= 8);
    }

    #[test]
    fn tighter_slo_means_more_decode_tpcs() {
        let p = pred();
        let dec = decode_batch(64, 8192);
        let pre = prefill_batch(8192);
        let loose = optimize_partition(&p, &dec, &pre, 0.300, 16).unwrap();
        let tight = optimize_partition(&p, &dec, &pre, 0.060, 16).unwrap();
        assert!(
            tight.decode.n_tpcs >= loose.decode.n_tpcs,
            "tight {} >= loose {}",
            tight.decode.n_tpcs,
            loose.decode.n_tpcs
        );
    }
}
