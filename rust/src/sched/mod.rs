//! Iteration-level schedulers.
//!
//! All engines run continuous batching (ORCA-style): at every iteration a
//! scheduler inspects the running/waiting requests and emits an
//! [`IterationPlan`]. The plans differ by policy:
//!
//! - [`chunked::ChunkedScheduler`] — the Sarathi-Serve / vLLM token-budget
//!   policy (decode-first, then prefill chunks filling the budget).
//! - [`sglang::SglangDefaultScheduler`] — throughput-oriented: prefill-only
//!   batches run opportunistically before decode drains.
//! - [`duet::DuetScheduler`] — the paper's contribution: chunked prefill +
//!   attention-aware roofline TBT check + Algorithm 1 partition optimizer
//!   emitting spatial iterations.
//! - [`duet::StaticPartitionScheduler`] — Fig. 9 ablation: always-spatial
//!   with a fixed TPC split.
//! - [`prefill_only::PrefillOnlyScheduler`] — prompt-only chunked
//!   scheduling for prefill-role cluster workers (disaggregation).
//!
//! PD disaggregation (Dynamo baseline) is an *engine topology*, not a
//! scheduler — see [`crate::engine::disagg`].

pub mod budget;
pub mod chunked;
pub mod duet;
pub mod optimizer;
pub mod prefill_only;
pub mod sglang;

pub use budget::{knee_budget, slo_budget};
pub use chunked::ChunkedScheduler;
pub use duet::{DuetScheduler, StaticPartitionScheduler};
pub use optimizer::{optimize_partition, optimize_partition_verbatim};
pub use prefill_only::PrefillOnlyScheduler;
pub use sglang::SglangDefaultScheduler;

use crate::hw::PartitionPlan;
use crate::request::{Phase, Request, RequestId};

/// Scheduler's view of engine state at an iteration boundary.
pub struct SchedInput<'a> {
    /// Admitted requests (phase Prefill or Decode), scheduling order.
    pub running: &'a [Request],
    /// Waiting queue (FCFS order), arrivals ≤ now only.
    pub waiting: &'a [Request],
    /// Free KV tokens available for new allocations.
    pub kv_free_tokens: u64,
    /// KV watermark: keep this fraction of tokens free when admitting.
    pub kv_total_tokens: u64,
}

/// Prefill work assignment: `tokens` prompt tokens of request `id` this
/// iteration (`admit` marks requests pulled from the waiting queue).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillChunk {
    pub id: RequestId,
    pub tokens: u64,
    pub admit: bool,
}

/// One iteration's work.
#[derive(Debug, Clone, PartialEq)]
pub enum IterationPlan {
    /// Nothing schedulable (queues empty or KV exhausted).
    Idle,
    /// PD-aggregated iteration: decode steps + prefill chunks execute as
    /// one synchronous batch on the full device.
    Aggregated {
        decode: Vec<RequestId>,
        prefill: Vec<PrefillChunk>,
    },
    /// Spatially-multiplexed iteration (§4.2/4.3): decode batch runs k
    /// look-ahead steps on `plan.decode` TPCs while prefill chunks run on
    /// `plan.prefill` TPCs.
    Spatial {
        decode: Vec<RequestId>,
        prefill: Vec<PrefillChunk>,
        plan: PartitionPlan,
    },
}

impl IterationPlan {
    pub fn is_idle(&self) -> bool {
        matches!(self, IterationPlan::Idle)
    }

    pub fn prefill_chunks(&self) -> &[PrefillChunk] {
        match self {
            IterationPlan::Idle => &[],
            IterationPlan::Aggregated { prefill, .. } => prefill,
            IterationPlan::Spatial { prefill, .. } => prefill,
        }
    }

    pub fn decode_ids(&self) -> &[RequestId] {
        match self {
            IterationPlan::Idle => &[],
            IterationPlan::Aggregated { decode, .. } => decode,
            IterationPlan::Spatial { decode, .. } => decode,
        }
    }

    /// Total scheduled tokens (decode count + prefill chunk tokens).
    pub fn scheduled_tokens(&self) -> u64 {
        self.decode_ids().len() as u64
            + self
                .prefill_chunks()
                .iter()
                .map(|c| c.tokens)
                .sum::<u64>()
    }
}

/// Common trait so engines are policy-generic.
pub trait Scheduler {
    fn plan(&mut self, input: &SchedInput<'_>) -> IterationPlan;
    fn name(&self) -> String;
    /// Drain the count of prefill chunks shed by class-aware QoS
    /// preemption since the last call. Engines fold this into
    /// `Recorder::qos_preemptions` after each `plan`. Schedulers without
    /// QoS awareness report zero.
    fn take_qos_preemptions(&mut self) -> u64 {
        0
    }
    /// Spare prefill capacity this worker advertises to the elastic
    /// planner, as a fraction of its token budget (1.0 = fully idle for
    /// prompt work, 0.0 = saturated / decode-only). Duet workers track a
    /// running average of unclaimed budget; pure-decode role schedulers
    /// report 0. The neutral default assumes half the budget is spare.
    fn prefill_headroom(&self) -> f64 {
        0.5
    }
}

/// Build the scheduler for a config's policy. Shared by the single-GPU
/// engine constructor and the cluster topologies (every unified worker
/// gets its own scheduler instance).
///
/// # Panics
/// On `Policy::DisaggPD`: disaggregation is an engine *topology*
/// (role-tagged workers over the cluster loop), not an iteration policy.
pub fn scheduler_for(cfg: &crate::config::ServingConfig) -> Box<dyn Scheduler> {
    use crate::config::Policy;
    use crate::roofline::Predictor;

    let pred = Predictor::new(cfg.model.clone(), cfg.gpu.clone(), cfg.tp);
    match &cfg.policy {
        Policy::VllmChunked => Box::new(
            ChunkedScheduler::new(
                cfg.token_budget as u64,
                cfg.max_batch as usize,
                cfg.kv_watermark,
            )
            .labeled("vLLM"),
        ),
        Policy::SglangChunked => Box::new(
            ChunkedScheduler::new(
                cfg.token_budget as u64,
                cfg.max_batch as usize,
                cfg.kv_watermark,
            )
            .labeled("SGLang-Chunked"),
        ),
        Policy::SglangDefault => Box::new(SglangDefaultScheduler::new(
            2 * cfg.token_budget as u64,
            cfg.max_batch as usize,
        )),
        Policy::Duet => Box::new(
            DuetScheduler::new(
                pred,
                cfg.token_budget as u64,
                cfg.max_batch as usize,
                cfg.kv_watermark,
                cfg.tbt_slo,
                cfg.max_lookahead,
            )
            .with_qos(cfg.qos_preemption),
        ),
        Policy::StaticPartition {
            decode_tpcs,
            prefill_tpcs,
        } => Box::new(StaticPartitionScheduler::new(
            pred,
            cfg.token_budget as u64,
            cfg.max_batch as usize,
            *decode_tpcs,
            *prefill_tpcs,
        )),
        Policy::DisaggPD { .. } => {
            panic!("DisaggPD is an engine topology, not a scheduler policy")
        }
    }
}

/// Shared helper: the Sarathi/vLLM chunked-prefill batch construction.
/// Decode requests are rescheduled first (one budget token each), then
/// running prefills continue, then waiting requests are admitted to fill
/// the remaining budget, chunking the final one. Admission respects the
/// KV watermark and `max_batch`.
pub fn build_chunked_batch(
    input: &SchedInput<'_>,
    token_budget: u64,
    max_batch: usize,
    kv_watermark: f64,
) -> (Vec<RequestId>, Vec<PrefillChunk>) {
    let mut budget = token_budget;
    let mut decode = Vec::new();
    let mut prefill = Vec::new();
    let mut batch_slots = max_batch;

    // 1. Ongoing decodes: highest priority, one token each.
    for r in input.running.iter().filter(|r| r.phase == Phase::Decode) {
        if budget == 0 || batch_slots == 0 {
            break;
        }
        decode.push(r.id);
        budget -= 1;
        batch_slots -= 1;
    }

    // 2. Running (partially prefilled) requests continue.
    for r in input.running.iter().filter(|r| r.phase == Phase::Prefill) {
        if budget == 0 || batch_slots == 0 {
            break;
        }
        let take = r.remaining_prompt().min(budget);
        if take > 0 {
            prefill.push(PrefillChunk {
                id: r.id,
                tokens: take,
                admit: false,
            });
            budget -= take;
            batch_slots -= 1;
        }
    }

    // 3. Admit waiting requests while budget and KV headroom remain.
    let watermark_tokens = (input.kv_total_tokens as f64 * kv_watermark) as u64;
    let mut kv_free = input.kv_free_tokens;
    for r in input.waiting {
        if budget == 0 || batch_slots == 0 {
            break;
        }
        // Admission control: the not-yet-prefilled prompt suffix (plus one
        // output token) must fit above the watermark, otherwise admitting
        // risks thrashing. With prefix caching a seeded request's cached
        // prefix is already resident, so only the suffix costs KV.
        let need = r.remaining_prompt() + 1;
        if need > kv_free || kv_free - need < watermark_tokens {
            break; // FCFS: do not skip ahead of a blocked head-of-line
        }
        let take = r.remaining_prompt().min(budget);
        prefill.push(PrefillChunk {
            id: r.id,
            tokens: take,
            admit: true,
        });
        kv_free -= need;
        budget -= take;
        batch_slots -= 1;
    }

    (decode, prefill)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Request;

    fn reqs(specs: &[(u64, u64, u64)]) -> Vec<Request> {
        // (id, prompt, prefilled)
        specs
            .iter()
            .map(|&(id, prompt, prefilled)| {
                let mut r = Request::new(id, 0.0, prompt, 10);
                if prefilled > 0 {
                    r.advance_prefill(prefilled);
                }
                r
            })
            .collect()
    }

    #[test]
    fn decode_first_then_prefill_chunks() {
        let running = reqs(&[(0, 100, 100), (1, 100, 100), (2, 500, 200)]);
        let waiting = reqs(&[(3, 10_000, 0)]);
        let input = SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 1_000_000,
            kv_total_tokens: 1_000_000,
        };
        let (dec, pre) = build_chunked_batch(&input, 512, 1024, 0.0);
        assert_eq!(dec, vec![0, 1]);
        // req2 continues with its remaining 300, then req3 fills 210
        assert_eq!(
            pre,
            vec![
                PrefillChunk { id: 2, tokens: 300, admit: false },
                PrefillChunk { id: 3, tokens: 210, admit: true },
            ]
        );
        // budget fully consumed
        assert_eq!(2 + 300 + 210, 512);
    }

    #[test]
    fn budget_bounds_total_tokens() {
        let running = reqs(&[(0, 4000, 1000)]);
        let waiting = reqs(&[(1, 9000, 0), (2, 50, 0)]);
        let input = SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 1_000_000,
            kv_total_tokens: 1_000_000,
        };
        let (dec, pre) = build_chunked_batch(&input, 2048, 1024, 0.0);
        let total: u64 = dec.len() as u64 + pre.iter().map(|c| c.tokens).sum::<u64>();
        assert!(total <= 2048);
        assert_eq!(total, 2048);
    }

    #[test]
    fn kv_watermark_blocks_admission_fcfs() {
        let running = vec![];
        let waiting = reqs(&[(0, 5000, 0), (1, 10, 0)]);
        let input = SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 4000,
            kv_total_tokens: 100_000,
        };
        // head-of-line needs 5001 > 4000 free: nothing admitted (no
        // skip-ahead — FCFS fairness)
        let (_, pre) = build_chunked_batch(&input, 8192, 1024, 0.0);
        assert!(pre.is_empty());
    }

    #[test]
    fn max_batch_limits_slots() {
        let running = reqs(&[(0, 10, 10), (1, 10, 10), (2, 10, 10)]);
        let waiting = reqs(&[(3, 100, 0)]);
        let input = SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 1_000_000,
            kv_total_tokens: 1_000_000,
        };
        let (dec, pre) = build_chunked_batch(&input, 8192, 3, 0.0);
        assert_eq!(dec.len(), 3);
        assert!(pre.is_empty());
    }

    #[test]
    fn plan_accessors() {
        let plan = IterationPlan::Aggregated {
            decode: vec![1, 2],
            prefill: vec![PrefillChunk { id: 3, tokens: 100, admit: true }],
        };
        assert_eq!(plan.scheduled_tokens(), 102);
        assert_eq!(plan.decode_ids(), &[1, 2]);
        assert!(!plan.is_idle());
        assert!(IterationPlan::Idle.is_idle());
    }
}
