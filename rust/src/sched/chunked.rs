//! Sarathi-Serve / vLLM chunked-prefill scheduler (the paper's §3
//! baseline and the first stage of DuetServe's own scheduling).

use super::{build_chunked_batch, IterationPlan, SchedInput, Scheduler};

/// Token-budget scheduler: every iteration packs ongoing decodes first,
/// then fills the remaining budget with (possibly chunked) prefill.
/// This is `vLLM` / `SGLang-Chunked` in the evaluation.
#[derive(Debug, Clone)]
pub struct ChunkedScheduler {
    pub token_budget: u64,
    pub max_batch: usize,
    pub kv_watermark: f64,
    pub label: String,
}

impl ChunkedScheduler {
    pub fn new(token_budget: u64, max_batch: usize, kv_watermark: f64) -> ChunkedScheduler {
        ChunkedScheduler {
            token_budget,
            max_batch,
            kv_watermark,
            label: "vLLM".into(),
        }
    }

    pub fn labeled(mut self, label: &str) -> ChunkedScheduler {
        self.label = label.to_string();
        self
    }
}

impl Scheduler for ChunkedScheduler {
    fn plan(&mut self, input: &SchedInput<'_>) -> IterationPlan {
        let (decode, prefill) =
            build_chunked_batch(input, self.token_budget, self.max_batch, self.kv_watermark);
        if decode.is_empty() && prefill.is_empty() {
            IterationPlan::Idle
        } else {
            IterationPlan::Aggregated { decode, prefill }
        }
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Request;

    #[test]
    fn idle_when_no_work() {
        let mut s = ChunkedScheduler::new(8192, 1024, 0.02);
        let plan = s.plan(&SchedInput {
            running: &[],
            waiting: &[],
            kv_free_tokens: 100,
            kv_total_tokens: 100,
        });
        assert!(plan.is_idle());
    }

    #[test]
    fn emits_aggregated_plan() {
        let mut s = ChunkedScheduler::new(100, 1024, 0.0);
        let waiting = vec![Request::new(0, 0.0, 250, 5)];
        let plan = s.plan(&SchedInput {
            running: &[],
            waiting: &waiting,
            kv_free_tokens: 100_000,
            kv_total_tokens: 100_000,
        });
        match plan {
            IterationPlan::Aggregated { decode, prefill } => {
                assert!(decode.is_empty());
                assert_eq!(prefill.len(), 1);
                assert_eq!(prefill[0].tokens, 100); // chunked to budget
            }
            other => panic!("expected aggregated, got {other:?}"),
        }
    }
}
