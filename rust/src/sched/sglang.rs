//! SGLang-Default-style throughput-oriented scheduler.
//!
//! §5.1: "employs a throughput-oriented scheduler that opportunistically
//! executes prefill-only batches when sufficient GPU memory is available
//! for several consecutive iterations, before switching to decode-only
//! iterations to drain pending requests."
//!
//! The consequence the paper measures (Fig. 6/7): unbounded TBT growth,
//! because prefill-only batches repeatedly interrupt decode generation.

use super::{IterationPlan, PrefillChunk, SchedInput, Scheduler};
use crate::request::Phase;

#[derive(Debug, Clone)]
pub struct SglangDefaultScheduler {
    /// Max prompt tokens packed into one prefill-only batch.
    pub prefill_batch_tokens: u64,
    pub max_batch: usize,
    /// Stop admitting prefill when free-KV fraction drops below this.
    pub mem_threshold: f64,
}

impl SglangDefaultScheduler {
    pub fn new(prefill_batch_tokens: u64, max_batch: usize) -> SglangDefaultScheduler {
        SglangDefaultScheduler {
            prefill_batch_tokens,
            max_batch,
            mem_threshold: 0.10,
        }
    }
}

impl Scheduler for SglangDefaultScheduler {
    fn plan(&mut self, input: &SchedInput<'_>) -> IterationPlan {
        let free_frac = input.kv_free_tokens as f64 / input.kv_total_tokens.max(1) as f64;

        // Opportunistic prefill: if requests wait and memory is plentiful,
        // run a prefill-only batch of whole prompts (no chunking).
        if !input.waiting.is_empty() && free_frac > self.mem_threshold {
            let mut tokens = 0u64;
            let mut kv_free = input.kv_free_tokens;
            let mut prefill = Vec::new();
            for r in input.waiting {
                if prefill.len() >= self.max_batch {
                    break;
                }
                // Prefix-seeded requests only need KV (and prefill work)
                // for the uncached prompt suffix.
                let need = r.remaining_prompt() + 1;
                if need > kv_free || tokens + r.remaining_prompt() > self.prefill_batch_tokens {
                    break;
                }
                prefill.push(PrefillChunk {
                    id: r.id,
                    tokens: r.remaining_prompt(),
                    admit: true,
                });
                tokens += r.remaining_prompt();
                kv_free -= need;
            }
            // Unfinished running prefills also continue here.
            for r in input.running.iter().filter(|r| r.phase == Phase::Prefill) {
                prefill.push(PrefillChunk {
                    id: r.id,
                    tokens: r.remaining_prompt(),
                    admit: false,
                });
            }
            if !prefill.is_empty() {
                return IterationPlan::Aggregated {
                    decode: Vec::new(), // decode is INTERRUPTED — the TBT pathology
                    prefill,
                };
            }
        }

        // Otherwise: decode-only drain.
        let decode: Vec<_> = input
            .running
            .iter()
            .filter(|r| r.phase == Phase::Decode)
            .take(self.max_batch)
            .map(|r| r.id)
            .collect();
        // Running prefills must finish even when memory is tight.
        let leftover: Vec<_> = input
            .running
            .iter()
            .filter(|r| r.phase == Phase::Prefill)
            .map(|r| PrefillChunk {
                id: r.id,
                tokens: r.remaining_prompt(),
                admit: false,
            })
            .collect();
        if decode.is_empty() && leftover.is_empty() {
            IterationPlan::Idle
        } else {
            IterationPlan::Aggregated {
                decode,
                prefill: leftover,
            }
        }
    }

    fn name(&self) -> String {
        "SGLang-Default".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Request;
    use crate::sched::Scheduler;

    #[test]
    fn prefill_only_batch_interrupts_decode() {
        let mut s = SglangDefaultScheduler::new(16_384, 1024);
        let mut running = vec![Request::new(0, 0.0, 10, 5)];
        running[0].advance_prefill(10); // now decoding
        let waiting = vec![Request::new(1, 0.0, 4000, 5)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 90_000,
            kv_total_tokens: 100_000,
        });
        match plan {
            IterationPlan::Aggregated { decode, prefill } => {
                assert!(decode.is_empty(), "decode interrupted by prefill batch");
                assert_eq!(prefill[0].tokens, 4000, "whole prompt, not chunked");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn drains_decode_when_memory_tight() {
        let mut s = SglangDefaultScheduler::new(16_384, 1024);
        let mut running = vec![Request::new(0, 0.0, 10, 5)];
        running[0].advance_prefill(10);
        let waiting = vec![Request::new(1, 0.0, 4000, 5)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 5_000, // 5% free < 10% threshold
            kv_total_tokens: 100_000,
        });
        match plan {
            IterationPlan::Aggregated { decode, prefill } => {
                assert_eq!(decode, vec![0]);
                assert!(prefill.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }
}
