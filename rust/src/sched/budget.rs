//! Token-budget auto-tuning (§3 of the paper).
//!
//! Chunked-prefill deployments pick the token budget at the "knee" of the
//! linear-layer roofline (vLLM: 2048 on A100, 8192 on H100). This module
//! derives that knee from the hardware model instead of hard-coding it,
//! and also exposes the SLO-aware budget DuetServe's aggregated mode
//! would need (the budget at which a prefill-only iteration still meets
//! the TBT bound) — the tension Observation 1 describes.

use crate::config::{GpuSpec, ModelSpec};
use crate::model::ops::{linear_bytes, linear_flops};
use crate::model::AttnShape;
use crate::roofline::{BatchShape, Predictor};

/// Achieved linear throughput (FLOP/s) for an `d x d` GEMM over `n`
/// tokens, including the small-GEMM saturation curve.
fn linear_throughput(gpu: &GpuSpec, n: u64, d: u64) -> f64 {
    let f = linear_flops(n, d, d) as f64;
    let b = linear_bytes(n, d, d, 2) as f64;
    let t = (f / (gpu.peak_flops * gpu.gemm_eff(n))).max(b / gpu.hbm_bandwidth);
    f / t
}

/// The utilization-knee budget: the smallest power-of-two token count at
/// which a d×d linear reaches `frac` (e.g. 0.95) of its asymptotic
/// throughput. This is how vLLM-style defaults are derived.
pub fn knee_budget(gpu: &GpuSpec, hidden: u64, frac: f64) -> u64 {
    let asymptote = linear_throughput(gpu, 1 << 20, hidden);
    let mut n = 256u64;
    while n < (1 << 17) {
        if linear_throughput(gpu, n, hidden) >= frac * asymptote {
            return n;
        }
        n *= 2;
    }
    1 << 17
}

/// The largest budget whose *prefill-only* iteration latency stays under
/// `tbt_slo` on the full device (Observation 1: this is far below the
/// knee on modern GPUs, which is why budget tuning alone cannot fix TBT).
pub fn slo_budget(model: &ModelSpec, gpu: &GpuSpec, tp: u32, tbt_slo: f64) -> u64 {
    let pred = Predictor::new(model.clone(), gpu.clone(), tp);
    // Binary search over the budget.
    let fits = |n: u64| {
        let b = BatchShape::from_shapes(vec![AttnShape { q: n, c: 0 }]);
        pred.predict_full(&b) <= tbt_slo
    };
    if !fits(64) {
        return 0;
    }
    let (mut lo, mut hi) = (64u64, 1u64 << 17);
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};

    #[test]
    fn knee_matches_vllm_defaults() {
        // Paper/vLLM: 2048 on A100, 8192 on H100 for a 4096-wide linear.
        assert_eq!(knee_budget(&GpuSpec::a100(), 4096, 0.95), 2048);
        assert_eq!(knee_budget(&GpuSpec::h100(), 4096, 0.95), 8192);
    }

    #[test]
    fn slo_budget_below_knee_on_h100() {
        // Observation 1: the 100 ms-compatible budget is well below the
        // 8192-token utilization knee — the core tension of §3.
        let b = slo_budget(&ModelSpec::qwen3_8b(), &GpuSpec::h100(), 1, 0.100);
        assert!(b > 512, "b={b}");
        assert!(b < 8192, "b={b}");
    }

    #[test]
    fn slo_budget_monotone_in_slo() {
        let m = ModelSpec::qwen3_8b();
        let g = GpuSpec::h100();
        let tight = slo_budget(&m, &g, 1, 0.050);
        let loose = slo_budget(&m, &g, 1, 0.200);
        assert!(loose > tight);
    }

    #[test]
    fn impossible_slo_returns_zero() {
        assert_eq!(
            slo_budget(&ModelSpec::qwen3_8b(), &GpuSpec::h100(), 1, 1e-9),
            0
        );
    }
}
