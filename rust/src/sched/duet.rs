//! DuetServe's adaptive scheduler (§4, Algorithm 1) and the
//! static-partition ablation (Appendix A, Fig. 9).

use super::optimizer::optimize_partition_verbatim;
use super::{build_chunked_batch, optimize_partition, IterationPlan, SchedInput, Scheduler};
use crate::hw::PartitionPlan;
use crate::model::AttnShape;
use crate::request::{Phase, Request, RequestId, SloClass};
use crate::roofline::{BatchShape, Predictor};

/// Build the (decode, prefill) batch shapes for a candidate plan, looking
/// request state up in the scheduler input.
fn shapes_of(
    input: &SchedInput<'_>,
    decode: &[RequestId],
    prefill: &[super::PrefillChunk],
) -> (BatchShape, BatchShape) {
    let find = |id: RequestId| -> Option<&Request> {
        input
            .running
            .iter()
            .chain(input.waiting.iter())
            .find(|r| r.id == id)
    };
    let dec_shapes = decode
        .iter()
        .filter_map(|&id| find(id))
        .map(|r| AttnShape {
            q: 1,
            c: r.context_len(),
        })
        .collect();
    let pre_shapes = prefill
        .iter()
        .filter_map(|c| find(c.id).map(|r| (r, c.tokens)))
        .map(|(r, q)| AttnShape {
            q,
            c: r.context_len(),
        })
        .collect();
    (
        BatchShape::from_shapes(dec_shapes),
        BatchShape::from_shapes(pre_shapes),
    )
}

/// The DuetServe scheduler:
/// 1. build the conventional chunked-prefill batch;
/// 2. predict its aggregated latency with the attention-aware roofline;
/// 3. if within the TBT SLO → aggregated (temporal-sharing) iteration;
/// 4. else split phases and solve Algorithm 1 for `(S_p, S_d, k)` →
///    spatial iteration; if no feasible split exists, fall back to
///    aggregated with decode-only (shed the prefill to protect TBT).
#[derive(Debug, Clone)]
pub struct DuetScheduler {
    pub predictor: Predictor,
    pub token_budget: u64,
    pub max_batch: usize,
    pub kv_watermark: f64,
    pub tbt_slo: f64,
    pub max_lookahead: u32,
    /// Count of iterations that went spatial (telemetry / Fig. 10).
    pub spatial_iterations: u64,
    pub total_iterations: u64,
    /// Ablation switch: run Algorithm 1 exactly as printed (no
    /// realized-gap constraint). See `bench ablation_design`.
    pub verbatim_alg1: bool,
    /// Class-aware QoS: tighten the effective TBT SLO to the strictest
    /// latency-class decode request and, when no partition is feasible,
    /// shed lower-class prefill chunks before shedding everything.
    pub qos_preemption: bool,
    /// Prefill chunks shed specifically to protect a latency-class
    /// decode (drained by [`Scheduler::take_qos_preemptions`]).
    qos_preempted: u64,
    /// Running average of the token-budget fraction left unclaimed by
    /// prefill chunks — the spare prefill capacity this worker advertises
    /// to the elastic planner via [`Scheduler::prefill_headroom`].
    headroom_ema: f64,
}

impl DuetScheduler {
    pub fn new(
        predictor: Predictor,
        token_budget: u64,
        max_batch: usize,
        kv_watermark: f64,
        tbt_slo: f64,
        max_lookahead: u32,
    ) -> DuetScheduler {
        DuetScheduler {
            predictor,
            token_budget,
            max_batch,
            kv_watermark,
            tbt_slo,
            max_lookahead,
            spatial_iterations: 0,
            total_iterations: 0,
            verbatim_alg1: false,
            qos_preemption: true,
            qos_preempted: 0,
            headroom_ema: 1.0,
        }
    }

    pub fn with_qos(mut self, on: bool) -> DuetScheduler {
        self.qos_preemption = on;
        self
    }

    /// The SLO the iteration must meet: the configured TBT SLO, tightened
    /// to the strictest per-request SLO among latency-class decodes when
    /// QoS is on. Standard/batch-class SLOs never tighten scheduling —
    /// they are recorded, not enforced — so legacy (classless) traffic
    /// schedules exactly as before.
    fn effective_slo(&self, input: &SchedInput<'_>) -> f64 {
        let mut slo = self.tbt_slo;
        if self.qos_preemption {
            for r in input.running.iter().filter(|r| {
                r.phase == Phase::Decode && r.class == SloClass::Latency
            }) {
                if let Some(s) = r.slo_tbt {
                    if s < slo {
                        slo = s;
                    }
                }
            }
        }
        slo
    }
}

/// Class of the request behind a scheduled id (Standard when unknown).
fn class_of(input: &SchedInput<'_>, id: RequestId) -> SloClass {
    input
        .running
        .iter()
        .chain(input.waiting.iter())
        .find(|r| r.id == id)
        .map(|r| r.class)
        .unwrap_or_default()
}

impl Scheduler for DuetScheduler {
    fn plan(&mut self, input: &SchedInput<'_>) -> IterationPlan {
        let (decode, prefill) =
            build_chunked_batch(input, self.token_budget, self.max_batch, self.kv_watermark);
        if decode.is_empty() && prefill.is_empty() {
            self.headroom_ema = 0.9 * self.headroom_ema + 0.1;
            return IterationPlan::Idle;
        }
        self.total_iterations += 1;
        let claimed: u64 = prefill.iter().map(|c| c.tokens).sum();
        let spare =
            1.0 - (claimed as f64 / self.token_budget.max(1) as f64).min(1.0);
        self.headroom_ema = 0.9 * self.headroom_ema + 0.1 * spare;

        let (dec_shape, pre_shape) = shapes_of(input, &decode, &prefill);
        // The SLO this iteration must meet (== tbt_slo for classless
        // traffic, tightened by latency-class decode SLOs under QoS).
        let eff_slo = self.effective_slo(input);
        // Line 2-4: predict the mixed batch on the full device.
        let mut mixed = dec_shape.shapes.clone();
        mixed.extend(pre_shape.shapes.iter().copied());
        let t_mixed = self
            .predictor
            .predict_full(&BatchShape::from_shapes(mixed));
        if t_mixed <= eff_slo || decode.is_empty() || prefill.is_empty() {
            return IterationPlan::Aggregated { decode, prefill };
        }

        // Line 5-22: spatial multiplexing via Algorithm 1.
        let solve = if self.verbatim_alg1 {
            optimize_partition_verbatim
        } else {
            optimize_partition
        };
        match solve(
            &self.predictor,
            &dec_shape,
            &pre_shape,
            eff_slo,
            self.max_lookahead,
        ) {
            Some(plan) => {
                self.spatial_iterations += 1;
                IterationPlan::Spatial {
                    decode,
                    prefill,
                    plan,
                }
            }
            // No feasible split: protect decode TBT by postponing prefill.
            // Under QoS with a latency-class decode present, lower-class
            // chunks are shed *first* (counted as qos preemptions); the
            // surviving latency-class prefill rides along only if the
            // roofline says the combined batch still meets the SLO.
            None => {
                let mut kept: Vec<super::PrefillChunk> = Vec::new();
                if self.qos_preemption {
                    let latency_decode = input.running.iter().any(|r| {
                        r.phase == Phase::Decode && r.class == SloClass::Latency
                    });
                    let lower = prefill
                        .iter()
                        .filter(|c| class_of(input, c.id) != SloClass::Latency)
                        .count();
                    if latency_decode && lower > 0 {
                        self.qos_preempted += lower as u64;
                        kept = prefill
                            .iter()
                            .copied()
                            .filter(|c| class_of(input, c.id) == SloClass::Latency)
                            .collect();
                        if !kept.is_empty() {
                            let (_, kept_shape) = shapes_of(input, &[], &kept);
                            let mut m = dec_shape.shapes.clone();
                            m.extend(kept_shape.shapes.iter().copied());
                            let t_kept = self
                                .predictor
                                .predict_full(&BatchShape::from_shapes(m));
                            if t_kept > eff_slo {
                                kept.clear();
                            }
                        }
                    }
                }
                IterationPlan::Aggregated {
                    decode,
                    prefill: kept,
                }
            }
        }
    }

    fn name(&self) -> String {
        "DuetServe".into()
    }

    fn take_qos_preemptions(&mut self) -> u64 {
        std::mem::take(&mut self.qos_preempted)
    }

    fn prefill_headroom(&self) -> f64 {
        self.headroom_ema
    }
}

/// Fig. 9 ablation: spatial multiplexing with a FIXED TPC split whenever
/// both phases are present; k chosen by the roofline ratio.
#[derive(Debug, Clone)]
pub struct StaticPartitionScheduler {
    pub predictor: Predictor,
    pub token_budget: u64,
    pub max_batch: usize,
    pub kv_watermark: f64,
    pub decode_tpcs: u32,
    pub prefill_tpcs: u32,
    pub max_lookahead: u32,
}

impl StaticPartitionScheduler {
    pub fn new(
        predictor: Predictor,
        token_budget: u64,
        max_batch: usize,
        decode_tpcs: u32,
        prefill_tpcs: u32,
    ) -> StaticPartitionScheduler {
        assert!(
            decode_tpcs + prefill_tpcs <= predictor.gpu.num_tpcs(),
            "static split exceeds device"
        );
        StaticPartitionScheduler {
            predictor,
            token_budget,
            max_batch,
            kv_watermark: 0.02,
            decode_tpcs,
            prefill_tpcs,
            max_lookahead: 16,
        }
    }
}

impl Scheduler for StaticPartitionScheduler {
    fn plan(&mut self, input: &SchedInput<'_>) -> IterationPlan {
        let (decode, prefill) =
            build_chunked_batch(input, self.token_budget, self.max_batch, self.kv_watermark);
        if decode.is_empty() && prefill.is_empty() {
            return IterationPlan::Idle;
        }
        if decode.is_empty() || prefill.is_empty() {
            // Only one phase present: run it on the whole device.
            return IterationPlan::Aggregated { decode, prefill };
        }
        let (dec_shape, pre_shape) = shapes_of(input, &decode, &prefill);
        let sd = self.decode_tpcs * self.predictor.gpu.sms_per_tpc;
        let sp = self.prefill_tpcs * self.predictor.gpu.sms_per_tpc;
        let t_d = self.predictor.predict_total(&dec_shape, sd);
        let t_p = self.predictor.predict_total(&pre_shape, sp);
        let k = if t_d > 0.0 {
            (((t_p / t_d).floor() as u32).max(1)).min(self.max_lookahead)
        } else {
            1
        };
        let mut plan = PartitionPlan::split(&self.predictor.gpu, self.decode_tpcs, k);
        // Static split may leave TPCs unused if d+p < total; give the rest
        // to prefill (matches how a static deployment would configure it).
        plan.prefill = crate::hw::SmMask::tpcs(
            self.decode_tpcs,
            self.predictor.gpu.num_tpcs() - self.decode_tpcs,
        );
        plan.t_decode = t_d;
        plan.t_prefill = t_p;
        IterationPlan::Spatial {
            decode,
            prefill,
            plan,
        }
    }

    fn name(&self) -> String {
        format!("Sd{}-Sp{}", self.decode_tpcs, self.prefill_tpcs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};

    fn predictor() -> Predictor {
        Predictor::new(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1)
    }

    fn decoding(id: RequestId, ctx: u64) -> Request {
        let mut r = Request::new(id, 0.0, ctx, 100);
        r.advance_prefill(ctx);
        r
    }

    #[test]
    fn small_mixed_batch_stays_aggregated() {
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 0.100, 16);
        let running = vec![decoding(0, 512)];
        let waiting = vec![Request::new(1, 0.0, 256, 10)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 1_000_000,
            kv_total_tokens: 1_000_000,
        });
        assert!(matches!(plan, IterationPlan::Aggregated { .. }), "{plan:?}");
        assert_eq!(s.spatial_iterations, 0);
    }

    #[test]
    fn tbt_threat_triggers_spatial() {
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 0.100, 16);
        // 32 long-context decodes + an 8K prefill: mixed latency >> 100ms.
        let running: Vec<_> = (0..32).map(|i| decoding(i, 8192)).collect();
        let waiting = vec![Request::new(99, 0.0, 8192, 10)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 10_000_000,
            kv_total_tokens: 10_000_000,
        });
        match &plan {
            IterationPlan::Spatial { decode, prefill, plan } => {
                assert_eq!(decode.len(), 32);
                assert!(!prefill.is_empty());
                assert!(plan.t_decode <= 0.100);
                assert!(plan.k >= 1);
            }
            other => panic!("expected spatial, got {other:?}"),
        }
        assert_eq!(s.spatial_iterations, 1);
    }

    #[test]
    fn decode_only_never_spatial() {
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 0.001, 16);
        // Even with an impossible SLO, no prefill side -> aggregated.
        let running: Vec<_> = (0..64).map(|i| decoding(i, 16384)).collect();
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &[],
            kv_free_tokens: 10_000_000,
            kv_total_tokens: 10_000_000,
        });
        assert!(matches!(plan, IterationPlan::Aggregated { .. }));
    }

    #[test]
    fn infeasible_split_sheds_prefill() {
        // Tight SLO that no partition can satisfy: decode-only iteration.
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 1e-6, 16);
        let running: Vec<_> = (0..8).map(|i| decoding(i, 8192)).collect();
        let waiting = vec![Request::new(99, 0.0, 8192, 10)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 10_000_000,
            kv_total_tokens: 10_000_000,
        });
        match plan {
            IterationPlan::Aggregated { decode, prefill } => {
                assert_eq!(decode.len(), 8);
                assert!(prefill.is_empty(), "prefill postponed to protect TBT");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn qos_sheds_lower_class_prefill_and_counts() {
        // Infeasible SLO with a latency-class decode present: the batch-
        // class prefill chunk is shed and counted as a qos preemption.
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 1e-6, 16);
        let running: Vec<_> = (0..8)
            .map(|i| decoding(i, 8192).with_class(SloClass::Latency))
            .collect();
        let waiting = vec![Request::new(99, 0.0, 8192, 10).with_class(SloClass::Batch)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 10_000_000,
            kv_total_tokens: 10_000_000,
        });
        match plan {
            IterationPlan::Aggregated { decode, prefill } => {
                assert_eq!(decode.len(), 8);
                assert!(prefill.is_empty());
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.take_qos_preemptions(), 1);
        assert_eq!(s.take_qos_preemptions(), 0, "counter drains");
    }

    #[test]
    fn qos_counter_stays_zero_without_latency_decode_or_with_qos_off() {
        // Same pressure, but every request is batch-class: the shed is the
        // pre-existing protect-decode behavior, not a qos preemption.
        let running: Vec<_> = (0..8)
            .map(|i| decoding(i, 8192).with_class(SloClass::Batch))
            .collect();
        let waiting = vec![Request::new(99, 0.0, 8192, 10).with_class(SloClass::Batch)];
        let input = SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 10_000_000,
            kv_total_tokens: 10_000_000,
        };
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 1e-6, 16);
        let plan = s.plan(&input);
        assert!(plan.prefill_chunks().is_empty());
        assert_eq!(s.take_qos_preemptions(), 0);

        // Latency decode present but qos disabled: also zero.
        let running: Vec<_> = (0..8)
            .map(|i| decoding(i, 8192).with_class(SloClass::Latency))
            .collect();
        let input = SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 10_000_000,
            kv_total_tokens: 10_000_000,
        };
        let mut s =
            DuetScheduler::new(predictor(), 8192, 1024, 0.0, 1e-6, 16).with_qos(false);
        let plan = s.plan(&input);
        assert!(plan.prefill_chunks().is_empty());
        assert_eq!(s.take_qos_preemptions(), 0);
    }

    #[test]
    fn latency_slo_tightens_effective_slo() {
        // A latency-class decode declaring a 1ms TBT SLO forces the
        // scheduler off the aggregated path even though the configured SLO
        // (100ms) would have allowed it.
        let running = vec![
            decoding(0, 512).with_class(SloClass::Latency).with_slo_tbt(1e-6),
        ];
        let waiting = vec![Request::new(1, 0.0, 256, 10).with_class(SloClass::Batch)];
        let input = SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 1_000_000,
            kv_total_tokens: 1_000_000,
        };
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 0.100, 16);
        let plan = s.plan(&input);
        assert!(
            plan.prefill_chunks().is_empty(),
            "batch prefill shed under tightened SLO: {plan:?}"
        );
        assert_eq!(s.take_qos_preemptions(), 1);

        // Identical input with qos off reproduces today's aggregated plan.
        let mut base =
            DuetScheduler::new(predictor(), 8192, 1024, 0.0, 0.100, 16).with_qos(false);
        let plan = base.plan(&input);
        assert!(matches!(plan, IterationPlan::Aggregated { .. }));
        assert_eq!(plan.prefill_chunks().len(), 1);
    }

    #[test]
    fn static_scheduler_always_spatial_when_mixed() {
        let mut s = StaticPartitionScheduler::new(predictor(), 8192, 1024, 22, 44);
        let running = vec![decoding(0, 512)];
        let waiting = vec![Request::new(1, 0.0, 256, 10)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 1_000_000,
            kv_total_tokens: 1_000_000,
        });
        match plan {
            IterationPlan::Spatial { plan, .. } => {
                assert_eq!(plan.decode.n_tpcs, 22);
                assert_eq!(plan.prefill.n_tpcs, 44);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.name(), "Sd22-Sp44");
    }

    #[test]
    #[should_panic(expected = "static split exceeds device")]
    fn static_oversub_panics() {
        StaticPartitionScheduler::new(predictor(), 8192, 1024, 40, 40);
    }

    #[test]
    fn headroom_tracks_spare_prefill_budget() {
        let mut s = DuetScheduler::new(predictor(), 8192, 1024, 0.0, 0.100, 16);
        assert!((s.prefill_headroom() - 1.0).abs() < 1e-12, "idle start = full headroom");
        // A prompt far larger than the budget claims the whole budget each
        // iteration: headroom decays toward zero.
        let waiting = vec![Request::new(0, 0.0, 100_000, 10)];
        for _ in 0..50 {
            s.plan(&SchedInput {
                running: &[],
                waiting: &waiting,
                kv_free_tokens: 10_000_000,
                kv_total_tokens: 10_000_000,
            });
        }
        assert!(s.prefill_headroom() < 0.1, "{}", s.prefill_headroom());
        // Idle iterations recover it.
        for _ in 0..50 {
            s.plan(&SchedInput {
                running: &[],
                waiting: &[],
                kv_free_tokens: 10_000_000,
                kv_total_tokens: 10_000_000,
            });
        }
        assert!(s.prefill_headroom() > 0.9, "{}", s.prefill_headroom());
    }
}
