//! Prefill-only scheduler for role-tagged cluster workers.
//!
//! Prefill workers in a disaggregated (Dynamo-style) topology used to
//! bypass the [`Scheduler`] trait entirely — the cluster packed their
//! batches by hand. This scheduler closes that gap: a prefill worker now
//! runs the exact same [`EngineCore::step_once`] path as every other
//! worker, with a policy that continues in-flight prompt chunks first and
//! then admits waiting prompts under the token budget and KV watermark
//! (FCFS, no skip-ahead). The cluster extracts requests whose prompt
//! completed (phase transitioned to Decode) after each step and hands
//! their KV to a decode worker through the transfer queue.
//!
//! [`EngineCore::step_once`]: crate::engine::EngineCore::step_once

use super::{build_chunked_batch, IterationPlan, SchedInput, Scheduler};

/// Chunked prompt processing with no decode scheduling.
#[derive(Debug, Clone)]
pub struct PrefillOnlyScheduler {
    pub token_budget: u64,
    pub max_batch: usize,
    pub kv_watermark: f64,
}

impl PrefillOnlyScheduler {
    pub fn new(token_budget: u64, max_batch: usize, kv_watermark: f64) -> PrefillOnlyScheduler {
        PrefillOnlyScheduler {
            token_budget,
            max_batch,
            kv_watermark,
        }
    }
}

impl Scheduler for PrefillOnlyScheduler {
    fn plan(&mut self, input: &SchedInput<'_>) -> IterationPlan {
        // The shared batch builder already prioritizes running prefills
        // and admits FCFS under the watermark. Decode-phase requests are
        // transient on a prefill worker (extracted right after the step
        // that completes their prompt), so the decode side is normally
        // empty; if a straggler exists it is carried along harmlessly.
        let (decode, prefill) =
            build_chunked_batch(input, self.token_budget, self.max_batch, self.kv_watermark);
        if decode.is_empty() && prefill.is_empty() {
            IterationPlan::Idle
        } else {
            IterationPlan::Aggregated { decode, prefill }
        }
    }

    fn name(&self) -> String {
        "prefill-only".to_string()
    }

    /// A prefill-role worker's whole budget is prompt capacity.
    fn prefill_headroom(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Request;

    #[test]
    fn idle_on_empty_queues() {
        let mut s = PrefillOnlyScheduler::new(8192, 64, 0.02);
        let plan = s.plan(&SchedInput {
            running: &[],
            waiting: &[],
            kv_free_tokens: 1000,
            kv_total_tokens: 1000,
        });
        assert!(plan.is_idle());
        assert_eq!(s.name(), "prefill-only");
    }

    #[test]
    fn continues_running_chunk_before_admitting() {
        let mut s = PrefillOnlyScheduler::new(1000, 64, 0.0);
        let mut running = vec![Request::new(0, 0.0, 2000, 4)];
        running[0].advance_prefill(600);
        let waiting = vec![Request::new(1, 0.0, 300, 4)];
        let plan = s.plan(&SchedInput {
            running: &running,
            waiting: &waiting,
            kv_free_tokens: 100_000,
            kv_total_tokens: 100_000,
        });
        let chunks = plan.prefill_chunks();
        assert_eq!(chunks.len(), 1, "budget consumed by the running prompt");
        assert_eq!(chunks[0].id, 0);
        assert_eq!(chunks[0].tokens, 1000);
        assert!(!chunks[0].admit);
        assert!(plan.decode_ids().is_empty());
    }

    #[test]
    fn admission_is_fcfs_under_kv_pressure() {
        let mut s = PrefillOnlyScheduler::new(8192, 64, 0.0);
        // Head prompt does not fit free KV: nothing is admitted, even
        // though the second prompt would fit (no skip-ahead).
        let waiting = vec![Request::new(0, 0.0, 5000, 4), Request::new(1, 0.0, 10, 4)];
        let plan = s.plan(&SchedInput {
            running: &[],
            waiting: &waiting,
            kv_free_tokens: 4000,
            kv_total_tokens: 100_000,
        });
        assert!(plan.is_idle());
    }
}
