//! Request lifecycle types shared by all engines.

/// Unique request id.
pub type RequestId = u64;

/// Lifecycle of a request inside an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Arrived, not yet admitted (waiting queue).
    Waiting,
    /// Prompt partially or fully unprocessed (chunked prefill in flight).
    Prefill,
    /// Prompt done; generating tokens.
    Decode,
    /// All output tokens produced.
    Finished,
}

/// One inference request as tracked by the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time on the engine clock, seconds.
    pub arrival: f64,
    /// Prompt length (input sequence length).
    pub prompt_len: u64,
    /// Number of output tokens the request will generate. In a real
    /// deployment this is unknown a priori; the trace supplies it and the
    /// engine only *observes* it when EOS fires.
    pub output_len: u64,
    pub phase: Phase,
    /// Prompt tokens already prefilled (chunked prefill progress).
    pub prefilled: u64,
    /// Output tokens generated so far.
    pub generated: u64,
    /// Time the first output token was produced (TTFT = first_token -
    /// arrival).
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub finished_at: Option<f64>,
    /// Timestamps of each generated token, for TBT accounting.
    pub token_times: Vec<f64>,
}

impl Request {
    pub fn new(id: RequestId, arrival: f64, prompt_len: u64, output_len: u64) -> Request {
        assert!(prompt_len >= 1, "empty prompt");
        assert!(output_len >= 1, "must generate at least one token");
        Request {
            id,
            arrival,
            prompt_len,
            output_len,
            phase: Phase::Waiting,
            prefilled: 0,
            generated: 0,
            first_token_at: None,
            finished_at: None,
            token_times: Vec::new(),
        }
    }

    /// Prompt tokens not yet prefilled.
    pub fn remaining_prompt(&self) -> u64 {
        self.prompt_len - self.prefilled
    }

    /// Context length currently held in KV cache (prefilled prompt +
    /// generated tokens).
    pub fn context_len(&self) -> u64 {
        self.prefilled + self.generated
    }

    /// Record `n` prompt tokens prefilled; transitions to Decode when the
    /// prompt completes.
    pub fn advance_prefill(&mut self, n: u64) {
        assert!(n <= self.remaining_prompt(), "prefill overrun");
        self.prefilled += n;
        self.phase = if self.prefilled == self.prompt_len {
            Phase::Decode
        } else {
            Phase::Prefill
        };
    }

    /// Record one generated token at time `now`. Returns true if the
    /// request just finished.
    pub fn advance_decode(&mut self, now: f64) -> bool {
        assert_eq!(self.phase, Phase::Decode, "decode before prefill done");
        assert!(self.generated < self.output_len);
        self.generated += 1;
        if self.first_token_at.is_none() {
            self.first_token_at = Some(now);
        }
        self.token_times.push(now);
        if self.generated == self.output_len {
            self.phase = Phase::Finished;
            self.finished_at = Some(now);
            true
        } else {
            false
        }
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// Mean time between tokens (excluding the first token, which is TTFT
    /// territory). None until ≥2 tokens.
    pub fn mean_tbt(&self) -> Option<f64> {
        if self.token_times.len() < 2 {
            return None;
        }
        let spans: f64 = self
            .token_times
            .windows(2)
            .map(|w| w[1] - w[0])
            .sum();
        Some(spans / (self.token_times.len() - 1) as f64)
    }

    /// All inter-token gaps.
    pub fn tbt_samples(&self) -> Vec<f64> {
        self.token_times.windows(2).map(|w| w[1] - w[0]).collect()
    }

    pub fn e2e_latency(&self) -> Option<f64> {
        self.finished_at.map(|t| t - self.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_transitions() {
        let mut r = Request::new(1, 0.0, 100, 3);
        assert_eq!(r.phase, Phase::Waiting);
        r.advance_prefill(60);
        assert_eq!(r.phase, Phase::Prefill);
        assert_eq!(r.remaining_prompt(), 40);
        r.advance_prefill(40);
        assert_eq!(r.phase, Phase::Decode);
        assert!(!r.advance_decode(1.0));
        assert!(!r.advance_decode(1.1));
        assert!(r.advance_decode(1.2));
        assert_eq!(r.phase, Phase::Finished);
        assert_eq!(r.ttft(), Some(1.0));
        assert!((r.mean_tbt().unwrap() - 0.1).abs() < 1e-9);
        assert_eq!(r.e2e_latency(), Some(1.2));
    }

    #[test]
    fn context_len_tracks_both_phases() {
        let mut r = Request::new(1, 0.0, 10, 5);
        r.advance_prefill(10);
        r.advance_decode(0.1);
        r.advance_decode(0.2);
        assert_eq!(r.context_len(), 12);
    }

    #[test]
    #[should_panic(expected = "prefill overrun")]
    fn prefill_overrun_panics() {
        let mut r = Request::new(1, 0.0, 10, 1);
        r.advance_prefill(11);
    }

    #[test]
    #[should_panic(expected = "decode before prefill done")]
    fn decode_before_prefill_panics() {
        let mut r = Request::new(1, 0.0, 10, 1);
        r.advance_decode(0.5);
    }

    #[test]
    fn tbt_none_for_single_token() {
        let mut r = Request::new(1, 0.0, 4, 1);
        r.advance_prefill(4);
        r.advance_decode(0.5);
        assert!(r.mean_tbt().is_none());
        assert!(r.tbt_samples().is_empty());
    }
}
