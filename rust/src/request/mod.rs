//! Request lifecycle types shared by all engines.

/// Unique request id.
pub type RequestId = u64;

/// SLO class of a request — the QoS tier the scheduler orders and
/// preempts by. Ordered by urgency: `Latency < Standard < Batch`, so
/// sorting ascending puts the most latency-sensitive work first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum SloClass {
    /// Interactive traffic: admission front-of-cohort; the duet
    /// scheduler tightens its TBT forecast to this class's declared SLO
    /// and preempts lower-class prefill on predicted violation.
    Latency,
    /// The default tier; legacy submissions without a class land here.
    #[default]
    Standard,
    /// Throughput work: admitted last within a cohort (subject to
    /// aging), first to be preempted under latency-class TBT pressure.
    Batch,
}

impl SloClass {
    /// Number of classes (per-class metric arrays are indexed by
    /// [`SloClass::index`]).
    pub const COUNT: usize = 3;

    /// Dense index for per-class accounting arrays.
    pub fn index(self) -> usize {
        match self {
            SloClass::Latency => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    /// Wire / display name.
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Latency => "latency",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    /// Strict wire-name parse (unknown names are the caller's 400).
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "latency" => Some(SloClass::Latency),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }

    /// All classes in urgency order (index order).
    pub fn all() -> [SloClass; SloClass::COUNT] {
        [SloClass::Latency, SloClass::Standard, SloClass::Batch]
    }
}

/// Lifecycle of a request inside an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Arrived, not yet admitted (waiting queue).
    Waiting,
    /// Prompt partially or fully unprocessed (chunked prefill in flight).
    Prefill,
    /// Prompt done; generating tokens.
    Decode,
    /// All output tokens produced.
    Finished,
}

/// One inference request as tracked by the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time on the engine clock, seconds.
    pub arrival: f64,
    /// Prompt length (input sequence length).
    pub prompt_len: u64,
    /// Number of output tokens the request will generate. In a real
    /// deployment this is unknown a priori; the trace supplies it and the
    /// engine only *observes* it when EOS fires.
    pub output_len: u64,
    pub phase: Phase,
    /// Prompt tokens already prefilled (chunked prefill progress).
    pub prefilled: u64,
    /// Output tokens generated so far.
    pub generated: u64,
    /// Time the first output token was produced (TTFT = first_token -
    /// arrival).
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub finished_at: Option<f64>,
    /// Timestamps of each generated token, for TBT accounting.
    pub token_times: Vec<f64>,
    /// Actual prompt token ids, when serving real traffic through the
    /// front-end (real execution backends need the values). Simulated
    /// requests carry only `prompt_len`.
    pub prompt_tokens: Option<Vec<i32>>,
    /// Per-request decode TBT SLO in seconds, when the submitter set one
    /// (attainment is accounted in `metrics::Recorder`).
    pub slo_tbt: Option<f64>,
    /// Per-request TTFT SLO in seconds, when the submitter set one
    /// (feeds per-class attainment accounting only).
    pub slo_ttft: Option<f64>,
    /// QoS tier: orders admission within an arrival-due cohort and
    /// selects preemption victims under latency-class TBT pressure.
    pub class: SloClass,
    /// Synthetic prefix identity (tenant / shared-system-prompt class)
    /// for workloads that carry no real token payload: two requests with
    /// the same `prefix_id` are treated as sharing their entire common
    /// prompt prefix by the prefix cache (`kvcache::prefix::block_keys`).
    /// Ignored when `prompt_tokens` is present.
    pub prefix_id: Option<u64>,
}

impl Request {
    pub fn new(id: RequestId, arrival: f64, prompt_len: u64, output_len: u64) -> Request {
        assert!(prompt_len >= 1, "empty prompt");
        assert!(output_len >= 1, "must generate at least one token");
        Request {
            id,
            arrival,
            prompt_len,
            output_len,
            phase: Phase::Waiting,
            prefilled: 0,
            generated: 0,
            first_token_at: None,
            finished_at: None,
            token_times: Vec::new(),
            prompt_tokens: None,
            slo_tbt: None,
            slo_ttft: None,
            class: SloClass::default(),
            prefix_id: None,
        }
    }

    /// Attach the actual prompt token ids (serving path). The declared
    /// `prompt_len` must match the payload.
    pub fn with_prompt_tokens(mut self, tokens: Vec<i32>) -> Request {
        assert_eq!(
            tokens.len() as u64,
            self.prompt_len,
            "prompt payload length must match prompt_len"
        );
        self.prompt_tokens = Some(tokens);
        self
    }

    /// Attach a per-request decode TBT SLO (seconds).
    pub fn with_slo_tbt(mut self, slo: f64) -> Request {
        self.slo_tbt = Some(slo);
        self
    }

    /// Attach a per-request TTFT SLO (seconds).
    pub fn with_slo_ttft(mut self, slo: f64) -> Request {
        self.slo_ttft = Some(slo);
        self
    }

    /// Set the request's SLO class (defaults to [`SloClass::Standard`]).
    pub fn with_class(mut self, class: SloClass) -> Request {
        self.class = class;
        self
    }

    /// Attach a synthetic prefix identity (see [`Request::prefix_id`]).
    pub fn with_prefix_id(mut self, prefix_id: u64) -> Request {
        self.prefix_id = Some(prefix_id);
        self
    }

    /// A fresh copy for recompute-style retry (preemption, role
    /// reconfiguration): identity and payload survive, all progress is
    /// discarded.
    pub fn reset_for_retry(&self) -> Request {
        let mut fresh = Request::new(self.id, self.arrival, self.prompt_len, self.output_len);
        fresh.prompt_tokens = self.prompt_tokens.clone();
        fresh.slo_tbt = self.slo_tbt;
        fresh.slo_ttft = self.slo_ttft;
        fresh.class = self.class;
        fresh.prefix_id = self.prefix_id;
        fresh
    }

    /// Has this request met every SLO it declared? Requests that declared
    /// none are trivially attained (their class's goodput equals its
    /// throughput). Meaningful once finished.
    pub fn slo_attained(&self) -> bool {
        if let Some(slo) = self.slo_tbt {
            if self.tbt_samples().iter().any(|&gap| gap > slo) {
                return false;
            }
        }
        if let (Some(slo), Some(ttft)) = (self.slo_ttft, self.ttft()) {
            if ttft > slo {
                return false;
            }
        }
        true
    }

    /// Prompt tokens not yet prefilled.
    pub fn remaining_prompt(&self) -> u64 {
        self.prompt_len - self.prefilled
    }

    /// Context length currently held in KV cache (prefilled prompt +
    /// generated tokens).
    pub fn context_len(&self) -> u64 {
        self.prefilled + self.generated
    }

    /// Record `n` prompt tokens prefilled; transitions to Decode when the
    /// prompt completes.
    pub fn advance_prefill(&mut self, n: u64) {
        assert!(n <= self.remaining_prompt(), "prefill overrun");
        self.prefilled += n;
        self.phase = if self.prefilled == self.prompt_len {
            Phase::Decode
        } else {
            Phase::Prefill
        };
    }

    /// Record one generated token at time `now`. Returns true if the
    /// request just finished.
    pub fn advance_decode(&mut self, now: f64) -> bool {
        assert_eq!(self.phase, Phase::Decode, "decode before prefill done");
        assert!(self.generated < self.output_len);
        self.generated += 1;
        if self.first_token_at.is_none() {
            self.first_token_at = Some(now);
        }
        self.token_times.push(now);
        if self.generated == self.output_len {
            self.phase = Phase::Finished;
            self.finished_at = Some(now);
            true
        } else {
            false
        }
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// Mean time between tokens (excluding the first token, which is TTFT
    /// territory). None until ≥2 tokens.
    pub fn mean_tbt(&self) -> Option<f64> {
        if self.token_times.len() < 2 {
            return None;
        }
        let spans: f64 = self
            .token_times
            .windows(2)
            .map(|w| w[1] - w[0])
            .sum();
        Some(spans / (self.token_times.len() - 1) as f64)
    }

    /// All inter-token gaps.
    pub fn tbt_samples(&self) -> Vec<f64> {
        self.token_times.windows(2).map(|w| w[1] - w[0]).collect()
    }

    pub fn e2e_latency(&self) -> Option<f64> {
        self.finished_at.map(|t| t - self.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_transitions() {
        let mut r = Request::new(1, 0.0, 100, 3);
        assert_eq!(r.phase, Phase::Waiting);
        r.advance_prefill(60);
        assert_eq!(r.phase, Phase::Prefill);
        assert_eq!(r.remaining_prompt(), 40);
        r.advance_prefill(40);
        assert_eq!(r.phase, Phase::Decode);
        assert!(!r.advance_decode(1.0));
        assert!(!r.advance_decode(1.1));
        assert!(r.advance_decode(1.2));
        assert_eq!(r.phase, Phase::Finished);
        assert_eq!(r.ttft(), Some(1.0));
        assert!((r.mean_tbt().unwrap() - 0.1).abs() < 1e-9);
        assert_eq!(r.e2e_latency(), Some(1.2));
    }

    #[test]
    fn context_len_tracks_both_phases() {
        let mut r = Request::new(1, 0.0, 10, 5);
        r.advance_prefill(10);
        r.advance_decode(0.1);
        r.advance_decode(0.2);
        assert_eq!(r.context_len(), 12);
    }

    #[test]
    #[should_panic(expected = "prefill overrun")]
    fn prefill_overrun_panics() {
        let mut r = Request::new(1, 0.0, 10, 1);
        r.advance_prefill(11);
    }

    #[test]
    #[should_panic(expected = "decode before prefill done")]
    fn decode_before_prefill_panics() {
        let mut r = Request::new(1, 0.0, 10, 1);
        r.advance_decode(0.5);
    }

    #[test]
    fn reset_for_retry_keeps_identity_drops_progress() {
        let mut r = Request::new(3, 1.5, 4, 8)
            .with_prompt_tokens(vec![9, 8, 7, 6])
            .with_slo_tbt(0.1)
            .with_slo_ttft(0.5)
            .with_class(SloClass::Latency)
            .with_prefix_id(42);
        r.advance_prefill(4);
        r.advance_decode(2.0);
        let fresh = r.reset_for_retry();
        assert_eq!(fresh.id, 3);
        assert_eq!(fresh.prefix_id, Some(42));
        assert_eq!(fresh.slo_ttft, Some(0.5));
        assert_eq!(fresh.class, SloClass::Latency);
        assert_eq!(fresh.arrival, 1.5);
        assert_eq!(fresh.prompt_len, 4);
        assert_eq!(fresh.output_len, 8);
        assert_eq!(fresh.prompt_tokens.as_deref(), Some(&[9, 8, 7, 6][..]));
        assert_eq!(fresh.slo_tbt, Some(0.1));
        assert_eq!(fresh.phase, Phase::Waiting);
        assert_eq!(fresh.generated, 0);
        assert!(fresh.token_times.is_empty());
    }

    #[test]
    #[should_panic(expected = "prompt payload length must match")]
    fn prompt_payload_length_mismatch_panics() {
        let _ = Request::new(1, 0.0, 3, 1).with_prompt_tokens(vec![1, 2]);
    }

    #[test]
    fn slo_class_parse_roundtrip_and_order() {
        for c in SloClass::all() {
            assert_eq!(SloClass::parse(c.name()), Some(c));
        }
        assert_eq!(SloClass::parse("gold"), None);
        assert_eq!(SloClass::parse("Latency"), None); // strict: lowercase only
        assert!(SloClass::Latency < SloClass::Standard);
        assert!(SloClass::Standard < SloClass::Batch);
        assert_eq!(SloClass::default(), SloClass::Standard);
        assert_eq!(SloClass::Batch.index(), 2);
    }

    #[test]
    fn slo_attained_checks_declared_gates_only() {
        let mut r = Request::new(1, 0.0, 4, 3);
        r.advance_prefill(4);
        r.advance_decode(1.0);
        r.advance_decode(1.2);
        r.advance_decode(1.4);
        // No declared SLO: trivially attained.
        assert!(r.slo_attained());
        // TBT gate: gaps are 0.2s.
        assert!(r.clone().with_slo_tbt(0.25).slo_attained());
        assert!(!r.clone().with_slo_tbt(0.1).slo_attained());
        // TTFT gate: first token at 1.0s after arrival 0.0.
        assert!(r.clone().with_slo_ttft(1.5).slo_attained());
        assert!(!r.clone().with_slo_ttft(0.5).slo_attained());
        // Both gates must hold.
        assert!(!r.clone().with_slo_tbt(0.25).with_slo_ttft(0.5).slo_attained());
    }

    #[test]
    fn tbt_none_for_single_token() {
        let mut r = Request::new(1, 0.0, 4, 1);
        r.advance_prefill(4);
        r.advance_decode(0.5);
        assert!(r.mean_tbt().is_none());
        assert!(r.tbt_samples().is_empty());
    }
}
