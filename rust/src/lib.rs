//! # DuetServe
//!
//! Reproduction of *"DuetServe: Harmonizing Prefill and Decode for LLM
//! Serving via Adaptive GPU Multiplexing"* as a three-layer Rust + JAX +
//! Pallas system. See `DESIGN.md` for the system inventory and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! Layer map:
//! - L3 (this crate): serving coordinator — schedulers, roofline
//!   predictor, SM-partition optimizer, paged KV cache, engines,
//!   baselines, simulated-GPU substrate, PJRT runtime.
//! - L2 (`python/compile/model.py`): JAX transformer lowered AOT to HLO
//!   text in `artifacts/`.
//! - L1 (`python/compile/kernels/`): Pallas attention kernels called by
//!   L2 (interpret mode on CPU).

pub mod cli;
pub mod config;
pub mod hw;
pub mod kvcache;
pub mod model;
pub mod request;
pub mod engine;
pub mod metrics;
pub mod sched;
pub mod server;
pub mod sim;
pub mod roofline;
pub mod runtime;
pub mod util;
pub mod workload;
