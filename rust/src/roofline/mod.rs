//! Attention-aware roofline analytical model (paper §4.1).
//!
//! Estimates model-forward latency from operator-level compute and memory
//! characteristics: token-level operators (linear/norm/act — depend only
//! on the total scheduled token count), sequence-level operators
//! (attention — per-request `(q, c)` shapes), and communication operators
//! (ring AllReduce under tensor parallelism). The scheduler uses this
//! predictor to (a) detect imminent TBT violations and (b) drive the
//! partitioning optimizer of Algorithm 1.
//!
//! The predictor is intentionally *idealized*: no kernel-launch overheads
//! and no per-operator efficiency de-rating. The simulated hardware
//! (`crate::sim`) models those, which is what produces the Fig. 8
//! predictor-vs-profiled gap (conservative on small decode partitions).

pub mod batch;

pub use batch::BatchShape;

use crate::config::{GpuSpec, ModelSpec};
use crate::model::{block_cost, classifier_cost, ops::allreduce_latency};

/// Latency breakdown of one iteration, seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyBreakdown {
    pub token_ops: f64,
    pub attention: f64,
    pub comm: f64,
    pub classifier: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.token_ops + self.attention + self.comm + self.classifier
    }
}

/// The attention-aware roofline predictor. Π_SM(S) and B_HBM(S) are
/// memoized per SM count at construction ("at initialization, DuetServe
/// profiles the achievable compute throughput and memory bandwidth for
/// each possible SM partition size" — §4.2).
#[derive(Debug, Clone)]
pub struct Predictor {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    pub tp: u32,
    /// pi[s] = Π_SM(s) for s active SMs, s in 0..=num_sms.
    pi: Vec<f64>,
    /// bw[s] = B_HBM(s).
    bw: Vec<f64>,
}

impl Predictor {
    pub fn new(model: ModelSpec, gpu: GpuSpec, tp: u32) -> Predictor {
        let n = gpu.num_sms as usize;
        let pi = (0..=n).map(|s| gpu.pi_sm(s as u32)).collect();
        let bw = (0..=n).map(|s| gpu.b_hbm(s as u32)).collect();
        Predictor {
            model,
            gpu,
            tp,
            pi,
            bw,
        }
    }

    #[inline]
    pub fn pi_sm(&self, sms: u32) -> f64 {
        self.pi[(sms as usize).min(self.pi.len() - 1)]
    }

    #[inline]
    pub fn b_hbm(&self, sms: u32) -> f64 {
        self.bw[(sms as usize).min(self.bw.len() - 1)]
    }

    /// Roofline latency of one operator: `max(F/Π, B/B_HBM)`.
    #[inline]
    fn op_latency(&self, flops: u64, bytes: u64, pi: f64, bw: f64) -> f64 {
        let tc = flops as f64 / pi;
        let tm = bytes as f64 / bw;
        tc.max(tm)
    }

    /// Predict one full model forward over `batch` executing on `sms`
    /// active SMs (per GPU, under `self.tp` tensor parallelism).
    pub fn predict(&self, batch: &BatchShape, sms: u32) -> LatencyBreakdown {
        if batch.is_empty() {
            return LatencyBreakdown::default();
        }
        let pi = self.pi_sm(sms);
        let bw = self.b_hbm(sms);
        if pi == 0.0 || bw == 0.0 {
            return LatencyBreakdown {
                token_ops: f64::INFINITY,
                ..Default::default()
            };
        }
        let cost = block_cost(&self.model, batch.n_tokens, &batch.shapes, self.tp);

        // Token-level: fused groups execute sequentially; each takes its
        // roofline max.
        let t_tok: f64 = cost
            .token_ops
            .iter()
            .map(|o| self.op_latency(o.flops, o.bytes, pi, bw))
            .sum();

        // Sequence-level: "the estimator iterates over the batch, applies
        // the roofline model to compute attention latency of each request
        // and aggregates" (§4.1).
        let t_attn: f64 = cost
            .attn_ops
            .iter()
            .map(|o| self.op_latency(o.flops, o.bytes, pi, bw))
            .sum();

        // Communication: two AllReduces per block under TP.
        let t_comm = if self.tp > 1 {
            allreduce_latency(
                self.tp,
                cost.allreduce_bytes,
                self.gpu.allreduce_alpha,
                self.gpu.nvlink_bandwidth,
                pi,
            )
        } else {
            0.0
        };

        let l = self.model.layers as f64;
        let cls = classifier_cost(&self.model, batch.n_seqs, self.tp);
        let t_cls = self.op_latency(cls.flops, cls.bytes, pi, bw);

        LatencyBreakdown {
            token_ops: l * t_tok,
            attention: l * t_attn,
            comm: l * t_comm,
            classifier: t_cls,
        }
    }

    /// Total-latency convenience: `t_total = L·t_block + t_cls` (§4.1).
    pub fn predict_total(&self, batch: &BatchShape, sms: u32) -> f64 {
        self.predict(batch, sms).total()
    }

    /// Predict with the full device.
    pub fn predict_full(&self, batch: &BatchShape) -> f64 {
        self.predict_total(batch, self.gpu.num_sms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::model::AttnShape;

    fn pred() -> Predictor {
        Predictor::new(ModelSpec::qwen3_8b(), GpuSpec::h100(), 1)
    }

    fn prefill_batch(tokens: u64) -> BatchShape {
        BatchShape::from_shapes(vec![AttnShape { q: tokens, c: 0 }])
    }

    fn decode_batch(n: u64, ctx: u64) -> BatchShape {
        BatchShape::from_shapes((0..n).map(|_| AttnShape { q: 1, c: ctx }).collect())
    }

    #[test]
    fn empty_batch_is_zero() {
        assert_eq!(pred().predict_full(&BatchShape::default()), 0.0);
    }

    #[test]
    fn prefill_8192_exceeds_typical_tbt_slo() {
        // Fig. 1(b): a full 8192-token prefill iteration on H100 takes
        // >100ms (paper measures ~180ms+; ideal roofline gives a lower
        // bound above the 100ms SLO).
        let t = pred().predict_full(&prefill_batch(8192));
        assert!(t > 0.08, "t={t}");
        assert!(t < 0.5, "t={t}");
    }

    #[test]
    fn attention_share_grows_with_prompt() {
        // Fig. 1(b): with one 8192-token prefill, attention ≈ 25% of
        // forward latency; at short prompts it's negligible.
        let p = pred();
        let short = p.predict(&prefill_batch(512), 132);
        let long = p.predict(&prefill_batch(8192), 132);
        let share_short = short.attention / short.total();
        let share_long = long.attention / long.total();
        assert!(share_short < 0.08, "share_short={share_short}");
        assert!(
            (0.1..0.45).contains(&share_long),
            "share_long={share_long}"
        );
        assert!(share_long > 2.0 * share_short);
    }

    #[test]
    fn decode_latency_grows_with_context_at_fixed_budget() {
        // Fig. 1(c): same token budget (8 decodes), >4x latency spread as
        // context goes 1K -> 32K.
        let p = pred();
        let t1 = p.predict_full(&decode_batch(8, 1024));
        let t2 = p.predict_full(&decode_batch(8, 32 * 1024));
        assert!(t2 / t1 > 2.0, "ratio={}", t2 / t1);
    }

    #[test]
    fn more_sms_never_slower() {
        let p = pred();
        let b = prefill_batch(4096);
        let mut prev = f64::INFINITY;
        for sms in (2..=132).step_by(2) {
            let t = p.predict_total(&b, sms);
            assert!(t <= prev + 1e-12, "sms={sms}");
            prev = t;
        }
    }

    #[test]
    fn decode_saturates_earlier_than_prefill() {
        // Decode is bandwidth-bound: because B_HBM(S) is super-linear,
        // decode at 40% of SMs should be within ~25% of full-device decode,
        // while prefill at 40% of SMs is ~2.5x slower than full-device.
        let p = pred();
        let dec = decode_batch(64, 4096);
        let pre = prefill_batch(8192);
        let frac40 = (132.0_f64 * 0.4) as u32;
        let dec_ratio = p.predict_total(&dec, frac40) / p.predict_full(&dec);
        let pre_ratio = p.predict_total(&pre, frac40) / p.predict_full(&pre);
        assert!(dec_ratio < 1.5, "dec_ratio={dec_ratio}");
        assert!(pre_ratio > 2.0, "pre_ratio={pre_ratio}");
    }

    #[test]
    fn tp2_adds_comm_but_reduces_compute() {
        let m = ModelSpec::qwen3_14b();
        let p1 = Predictor::new(m.clone(), GpuSpec::h100(), 1);
        let p2 = Predictor::new(m, GpuSpec::h100(), 2);
        let b = prefill_batch(8192);
        let l1 = p1.predict(&b, 132);
        let l2 = p2.predict(&b, 132);
        assert_eq!(l1.comm, 0.0);
        assert!(l2.comm > 0.0);
        assert!(l2.token_ops < l1.token_ops);
        // net: TP=2 faster per GPU for a large prefill
        assert!(l2.total() < l1.total());
    }

    #[test]
    fn zero_sms_is_infinite() {
        let p = pred();
        assert!(p.predict_total(&prefill_batch(128), 0).is_infinite());
    }

    #[test]
    fn mixed_batch_additive() {
        // Mixed batch ≈ prefill part + decode part (token ops merge, so
        // only approximately).
        let p = pred();
        let mut shapes = vec![AttnShape { q: 2048, c: 0 }];
        shapes.extend((0..32).map(|_| AttnShape { q: 1, c: 2048 }));
        let mixed = BatchShape::from_shapes(shapes);
        let t_mixed = p.predict_full(&mixed);
        let t_pre = p.predict_full(&prefill_batch(2048));
        assert!(t_mixed > t_pre);
    }
}
