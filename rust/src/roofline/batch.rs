//! Batch shape descriptor handed from the scheduler to the predictor and
//! executor.

use crate::model::AttnShape;
use crate::request::{Phase, Request};

/// The shape of one scheduled iteration: per-request attention shapes plus
/// aggregate token counts. Weights/activations are irrelevant — only
/// shapes drive cost.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatchShape {
    pub shapes: Vec<AttnShape>,
    /// Total scheduled tokens (Σ q).
    pub n_tokens: u64,
    /// Number of sequences needing logits this iteration.
    pub n_seqs: u64,
}

impl BatchShape {
    pub fn from_shapes(shapes: Vec<AttnShape>) -> BatchShape {
        let n_tokens = shapes.iter().map(|s| s.q).sum();
        let n_seqs = shapes.len() as u64;
        BatchShape {
            shapes,
            n_tokens,
            n_seqs,
        }
    }

    /// Build from scheduled (request, scheduled_tokens) pairs.
    pub fn from_schedule(items: &[(&Request, u64)]) -> BatchShape {
        let shapes = items
            .iter()
            .map(|(r, q)| AttnShape {
                q: *q,
                c: r.context_len(),
            })
            .collect();
        BatchShape::from_shapes(shapes)
    }

    pub fn is_empty(&self) -> bool {
        self.n_tokens == 0
    }

    /// Split into (decode-only, prefill-only) sub-batches. Decode entries
    /// are q == 1 with context (the paper splits R_mixed the same way in
    /// Algorithm 1 line 6).
    pub fn split_phases(&self) -> (BatchShape, BatchShape) {
        let (dec, pre): (Vec<AttnShape>, Vec<AttnShape>) = self
            .shapes
            .iter()
            .partition(|s| s.q == 1 && s.c > 0);
        (
            BatchShape::from_shapes(dec),
            BatchShape::from_shapes(pre),
        )
    }

    /// Decode tokens produced per step in this batch (`T_decode` in §4.2):
    /// one per decode sequence.
    pub fn decode_tokens_per_step(&self) -> u64 {
        self.shapes.iter().filter(|s| s.q == 1 && s.c > 0).count() as u64
    }

    /// Prefill tokens in this batch (`T_prefill` in §4.2).
    pub fn prefill_tokens(&self) -> u64 {
        self.shapes
            .iter()
            .filter(|s| !(s.q == 1 && s.c > 0))
            .map(|s| s.q)
            .sum()
    }
}

/// Helper: batch shape of a set of running decode requests.
pub fn decode_batch_of(requests: &[&Request]) -> BatchShape {
    let shapes = requests
        .iter()
        .filter(|r| r.phase == Phase::Decode)
        .map(|r| AttnShape {
            q: 1,
            c: r.context_len(),
        })
        .collect();
    BatchShape::from_shapes(shapes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_computed() {
        let b = BatchShape::from_shapes(vec![
            AttnShape { q: 100, c: 0 },
            AttnShape { q: 1, c: 500 },
            AttnShape { q: 1, c: 900 },
        ]);
        assert_eq!(b.n_tokens, 102);
        assert_eq!(b.n_seqs, 3);
        assert_eq!(b.decode_tokens_per_step(), 2);
        assert_eq!(b.prefill_tokens(), 100);
    }

    #[test]
    fn split_phases_partitions() {
        let b = BatchShape::from_shapes(vec![
            AttnShape { q: 64, c: 32 }, // chunked prefill continuation
            AttnShape { q: 1, c: 500 },
            AttnShape { q: 200, c: 0 },
        ]);
        let (dec, pre) = b.split_phases();
        assert_eq!(dec.n_seqs, 1);
        assert_eq!(pre.n_seqs, 2);
        assert_eq!(dec.n_tokens + pre.n_tokens, b.n_tokens);
    }

    #[test]
    fn from_schedule_uses_context() {
        let mut r = Request::new(1, 0.0, 100, 5);
        r.advance_prefill(40);
        let b = BatchShape::from_schedule(&[(&r, 60)]);
        assert_eq!(b.shapes[0], AttnShape { q: 60, c: 40 });
    }
}
