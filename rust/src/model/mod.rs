//! Operator-level FLOP / memory-byte formulas (§4.1 of the paper).
//!
//! Operators are categorized as *token-level* (cost depends only on total
//! token count: linear projections, norms, activations), *sequence-level*
//! (attention: depends on per-request query length q and cached length c),
//! and *communication* (tensor-parallel AllReduce). The same formulas feed
//! the scheduler's roofline predictor (`roofline`) and the simulated GPU
//! executor (`sim`); the two differ only in efficiency/overhead modelling.

use crate::config::ModelSpec;

pub mod ops;

pub use ops::{attn_bytes, attn_flops, linear_bytes, linear_flops, norm_bytes, OpCost, OpKind};

/// Per-request attention workload descriptor: `q` scheduled query tokens
/// against `c` cached KV tokens. Prefill: q>1,c=0; chunked prefill:
/// q>1,c>0; decode: q=1,c>0.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnShape {
    pub q: u64,
    pub c: u64,
}

/// The full per-layer cost breakdown for a batch, used to build iteration
/// latency estimates.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BlockCost {
    /// Token-level operator costs (one entry per fused op group).
    pub token_ops: Vec<OpCost>,
    /// Sequence-level (attention) cost per request.
    pub attn_ops: Vec<OpCost>,
    /// Output bytes of the two TP-synchronized linears (attn-out, mlp-down),
    /// needed by the AllReduce model.
    pub allreduce_bytes: u64,
}

/// Compute the cost of one transformer block for a batch with `n_tokens`
/// total scheduled tokens and the given per-request attention shapes,
/// under tensor-parallel degree `tp` (weights and heads sharded).
pub fn block_cost(spec: &ModelSpec, n_tokens: u64, shapes: &[AttnShape], tp: u32) -> BlockCost {
    let tp = tp.max(1) as u64;
    let d = spec.hidden as u64;
    let m = spec.intermediate as u64;
    let b = spec.elem_bytes as u64;
    let hq = spec.heads as u64 / tp;
    let hkv = (spec.kv_heads as u64 / tp).max(1);
    let dh = spec.head_dim as u64;
    let n = n_tokens;

    let mut token_ops = Vec::with_capacity(6);
    // QKV projection: d -> (hq + 2*hkv) * dh  (sharded over tp)
    let qkv_out = (hq + 2 * hkv) * dh;
    token_ops.push(OpCost {
        kind: OpKind::LinearQkv,
        flops: linear_flops(n, d, qkv_out),
        bytes: linear_bytes(n, d, qkv_out, b),
    });
    // Output projection: hq*dh -> d
    token_ops.push(OpCost {
        kind: OpKind::LinearO,
        flops: linear_flops(n, hq * dh, d),
        bytes: linear_bytes(n, hq * dh, d, b),
    });
    // Gate+Up projection: d -> 2m/tp
    token_ops.push(OpCost {
        kind: OpKind::LinearGateUp,
        flops: linear_flops(n, d, 2 * m / tp),
        bytes: linear_bytes(n, d, 2 * m / tp, b),
    });
    // Down projection: m/tp -> d
    token_ops.push(OpCost {
        kind: OpKind::LinearDown,
        flops: linear_flops(n, m / tp, d),
        bytes: linear_bytes(n, m / tp, d, b),
    });
    // Two RMSNorms + residual adds + SiLU: memory-bound elementwise traffic.
    token_ops.push(OpCost {
        kind: OpKind::NormAct,
        flops: 10 * n * d, // a few flops per element across norm/act/residual
        bytes: norm_bytes(n, d, b) * 2 + 2 * n * (m / tp) * b,
    });

    let attn_ops = shapes
        .iter()
        .map(|s| OpCost {
            kind: OpKind::Attention,
            flops: attn_flops(s.q, s.c, hq, dh),
            bytes: attn_bytes(s.q, s.c, hq, hkv, dh, b),
        })
        .collect();

    BlockCost {
        token_ops,
        attn_ops,
        // attn-out (n×d) and mlp-down (n×d) outputs are AllReduced under TP.
        allreduce_bytes: 2 * n * d * b,
    }
}

/// Final-classifier cost: linear d -> vocab over `n_logit_tokens`
/// (only the last token of each sequence needs logits at serving time).
pub fn classifier_cost(spec: &ModelSpec, n_logit_tokens: u64, tp: u32) -> OpCost {
    let tp = tp.max(1) as u64;
    let d = spec.hidden as u64;
    let v = spec.vocab as u64 / tp;
    let b = spec.elem_bytes as u64;
    OpCost {
        kind: OpKind::Classifier,
        flops: linear_flops(n_logit_tokens, d, v),
        bytes: linear_bytes(n_logit_tokens, d, v, b),
    }
}

/// Total FLOPs of one block (convenience for utilization accounting).
pub fn block_flops(cost: &BlockCost) -> f64 {
    cost.token_ops.iter().map(|o| o.flops as f64).sum::<f64>()
        + cost.attn_ops.iter().map(|o| o.flops as f64).sum::<f64>()
}

/// Total HBM bytes of one block.
pub fn block_bytes(cost: &BlockCost) -> f64 {
    cost.token_ops.iter().map(|o| o.bytes as f64).sum::<f64>()
        + cost.attn_ops.iter().map(|o| o.bytes as f64).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    #[test]
    fn prefill_flops_dominated_by_linears_at_short_context() {
        let spec = ModelSpec::qwen3_8b();
        let shapes = [AttnShape { q: 512, c: 0 }];
        let c = block_cost(&spec, 512, &shapes, 1);
        let lin: u64 = c.token_ops.iter().map(|o| o.flops).sum();
        let attn: u64 = c.attn_ops.iter().map(|o| o.flops).sum();
        assert!(lin > 10 * attn, "lin={lin} attn={attn}");
    }

    #[test]
    fn attention_grows_quadratically_in_prompt() {
        let spec = ModelSpec::qwen3_8b();
        let f1 = attn_flops(1024, 0, spec.heads as u64, spec.head_dim as u64);
        let f2 = attn_flops(2048, 0, spec.heads as u64, spec.head_dim as u64);
        let ratio = f2 as f64 / f1 as f64;
        assert!((3.8..4.2).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn decode_attention_bytes_scale_with_context() {
        let spec = ModelSpec::qwen3_8b();
        let hq = spec.heads as u64;
        let hkv = spec.kv_heads as u64;
        let dh = spec.head_dim as u64;
        let b1 = attn_bytes(1, 1024, hq, hkv, dh, 2);
        let b2 = attn_bytes(1, 8192, hq, hkv, dh, 2);
        assert!(b2 as f64 / b1 as f64 > 6.0, "KV reads dominate decode");
    }

    #[test]
    fn tp_divides_work() {
        let spec = ModelSpec::qwen3_14b();
        let shapes = [AttnShape { q: 256, c: 0 }];
        let c1 = block_cost(&spec, 256, &shapes, 1);
        let c2 = block_cost(&spec, 256, &shapes, 2);
        let f1 = block_flops(&c1);
        let f2 = block_flops(&c2);
        assert!(
            (f1 / f2 - 2.0).abs() < 0.2,
            "TP=2 should halve per-GPU flops: {f1} vs {f2}"
        );
    }

    #[test]
    fn end_to_end_prefill_flops_sane() {
        // Qwen3-8B, 2048-token prefill: ~2*8.2e9*2048 ≈ 3.4e13 total
        // (block-level; embeddings excluded).
        let spec = ModelSpec::qwen3_8b();
        let shapes = [AttnShape { q: 2048, c: 0 }];
        let c = block_cost(&spec, 2048, &shapes, 1);
        let total = block_flops(&c) * spec.layers as f64;
        let expect = 2.0 * 7.5e9 * 2048.0; // 2*N*T with non-embedding params
        assert!(
            (total / expect - 1.0).abs() < 0.35,
            "total={total:.3e} expect≈{expect:.3e}"
        );
    }

    #[test]
    fn classifier_cost_uses_vocab() {
        let spec = ModelSpec::qwen3_8b();
        let c = classifier_cost(&spec, 4, 1);
        assert_eq!(
            c.flops,
            2 * 4 * spec.hidden as u64 * spec.vocab as u64
        );
    }

    #[test]
    fn allreduce_bytes_track_tokens() {
        let spec = ModelSpec::qwen3_8b();
        let c = block_cost(&spec, 100, &[AttnShape { q: 100, c: 0 }], 2);
        assert_eq!(
            c.allreduce_bytes,
            2 * 100 * spec.hidden as u64 * spec.elem_bytes as u64
        );
    }
}
