//! Primitive operator cost formulas (paper §4.1).

/// Operator classes distinguished by the simulator's efficiency model and
/// by the utilization accounting in Fig. 3(b,c) / Fig. 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    LinearQkv,
    LinearO,
    LinearGateUp,
    LinearDown,
    NormAct,
    Attention,
    Classifier,
    /// KV-cache block copy (disaggregated transfer / preemption swap).
    KvTransfer,
}

impl OpKind {
    pub fn is_linear(&self) -> bool {
        matches!(
            self,
            OpKind::LinearQkv | OpKind::LinearO | OpKind::LinearGateUp | OpKind::LinearDown
                | OpKind::Classifier
        )
    }
}

/// FLOPs + HBM bytes of one operator instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    pub kind: OpKind,
    pub flops: u64,
    pub bytes: u64,
}

impl OpCost {
    /// Arithmetic intensity in FLOP/byte.
    pub fn intensity(&self) -> f64 {
        if self.bytes == 0 {
            f64::INFINITY
        } else {
            self.flops as f64 / self.bytes as f64
        }
    }
}

/// Linear layer FLOPs: `F_lin = 2 n d_i d_o` (paper §4.1).
pub fn linear_flops(n: u64, d_i: u64, d_o: u64) -> u64 {
    2 * n * d_i * d_o
}

/// Linear layer bytes: `B_lin = n d_i b + d_i d_o b + n d_o b`
/// (input + full weight + output; the weight term is what makes small-n
/// linears memory-bound and produces the roofline knee of Fig. 1a).
pub fn linear_bytes(n: u64, d_i: u64, d_o: u64, b: u64) -> u64 {
    n * d_i * b + d_i * d_o * b + n * d_o * b
}

/// Attention FLOPs for one request (paper §4.1):
/// `F = 4 h_q q (q+c) d_h + 2 h_q q (q+c)`.
/// First term: QK^T and PV matmuls; second: softmax/scaling elementwise.
pub fn attn_flops(q: u64, c: u64, h_q: u64, d_h: u64) -> u64 {
    4 * h_q * q * (q + c) * d_h + 2 * h_q * q * (q + c)
}

/// Attention HBM bytes for one request (paper §4.1):
/// `B = 2 h_q q d_h b + 2 h_kv (q+c) d_h b`.
/// Q read + O write, plus K and V reads over the whole context — the term
/// that dominates decode at long context (Fig. 1c).
pub fn attn_bytes(q: u64, c: u64, h_q: u64, h_kv: u64, d_h: u64, b: u64) -> u64 {
    2 * h_q * q * d_h * b + 2 * h_kv * (q + c) * d_h * b
}

/// Elementwise norm/residual traffic for n tokens of width d: read+write
/// a couple of activations.
pub fn norm_bytes(n: u64, d: u64, b: u64) -> u64 {
    4 * n * d * b
}

/// Ring AllReduce latency (paper §4.1):
/// `t = 2(N-1)α + 2(N-1)B/(N·B_nvlink) + N(N-1)B/Π_SM`
/// The last term models the local reduction flops; the paper folds it in
/// with Π_SM in FLOP/s — B here is bytes, reduced at ~1 FLOP/byte.
pub fn allreduce_latency(
    n_gpus: u32,
    bytes: u64,
    alpha: f64,
    nvlink_bw: f64,
    pi_sm: f64,
) -> f64 {
    if n_gpus <= 1 {
        return 0.0;
    }
    let n = n_gpus as f64;
    let b = bytes as f64;
    2.0 * (n - 1.0) * alpha + 2.0 * (n - 1.0) * b / (n * nvlink_bw) + n * (n - 1.0) * b / pi_sm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_formulas_match_paper() {
        // n=100, di=4096, do=4096, b=2
        assert_eq!(linear_flops(100, 4096, 4096), 2 * 100 * 4096 * 4096);
        assert_eq!(
            linear_bytes(100, 4096, 4096, 2),
            100 * 4096 * 2 + 4096 * 4096 * 2 + 100 * 4096 * 2
        );
    }

    #[test]
    fn linear_intensity_grows_with_n_then_saturates() {
        // Arithmetic intensity rises with n (weight amortization) — the
        // mechanism behind the token-budget knee.
        let c = |n| OpCost {
            kind: OpKind::LinearQkv,
            flops: linear_flops(n, 4096, 4096),
            bytes: linear_bytes(n, 4096, 4096, 2),
        };
        assert!(c(64).intensity() < c(1024).intensity());
        assert!(c(1024).intensity() < c(8192).intensity());
        // asymptote ~ 1/b * 1/(1/do + 1/di)... just check < 2048
        assert!(c(1_000_000).intensity() < 2048.0);
    }

    #[test]
    fn attn_formulas_match_paper() {
        let (q, c, hq, hkv, dh, b) = (8u64, 120u64, 32u64, 8u64, 128u64, 2u64);
        assert_eq!(
            attn_flops(q, c, hq, dh),
            4 * hq * q * (q + c) * dh + 2 * hq * q * (q + c)
        );
        assert_eq!(
            attn_bytes(q, c, hq, hkv, dh, b),
            2 * hq * q * dh * b + 2 * hkv * (q + c) * dh * b
        );
    }

    #[test]
    fn decode_attention_is_memory_bound() {
        // q=1 decode at 8K context: intensity should be way below any GPU
        // ridge (~295 for H100).
        let cost = OpCost {
            kind: OpKind::Attention,
            flops: attn_flops(1, 8192, 32, 128),
            bytes: attn_bytes(1, 8192, 32, 8, 128, 2),
        };
        assert!(cost.intensity() < 40.0, "intensity={}", cost.intensity());
    }

    #[test]
    fn prefill_attention_is_compute_bound() {
        let cost = OpCost {
            kind: OpKind::Attention,
            flops: attn_flops(8192, 0, 32, 128),
            bytes: attn_bytes(8192, 0, 32, 8, 128, 2),
        };
        assert!(cost.intensity() > 400.0, "intensity={}", cost.intensity());
    }

    #[test]
    fn allreduce_zero_for_single_gpu() {
        assert_eq!(allreduce_latency(1, 1 << 30, 3e-6, 450e9, 989e12), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes_and_gpus() {
        let t2 = allreduce_latency(2, 1 << 20, 3e-6, 450e9, 989e12);
        let t2_big = allreduce_latency(2, 1 << 24, 3e-6, 450e9, 989e12);
        let t8 = allreduce_latency(8, 1 << 20, 3e-6, 450e9, 989e12);
        assert!(t2_big > t2);
        assert!(t8 > t2);
        // startup term alone for N=2 is 2*alpha = 6us
        assert!(t2 > 6e-6);
    }
}
