//! The PJRT wrapper: compile the AOT HLO-text artifacts once, then run
//! prefill / decode forwards from the rust hot path.
//!
//! Design notes:
//! - HLO **text** is the interchange (xla_extension 0.5.1 rejects jax≥0.5
//!   serialized protos — 64-bit instruction ids).
//! - The decode path always executes the `decode_b{MAX_SLOTS}` variant
//!   with inactive slots masked via `lengths == 0`, mirroring how CUDA
//!   Graph serving pads decode batches to captured sizes (§4.3).
//! - The crate's `execute` returns a single *tuple* buffer, so the KV
//!   cache round-trips through host literals each step; the rust engine
//!   owns the authoritative cache memory and writes prefill K/V into
//!   batch slots itself (the coordinator manages KV memory, as L3 should).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifacts::{artifacts_dir, ArtifactMeta, WeightManifest};

/// Number of decode slots the serving runtime batches over.
pub const MAX_SLOTS: usize = 8;

/// Outcome of one prefill call.
pub struct PrefillOut {
    /// argmax token at the last valid prompt position.
    pub next_token: i32,
    /// K cache rows [layers, prefill_seq, kv_heads, head_dim], flattened.
    pub k: Vec<f32>,
    /// V cache rows, same shape.
    pub v: Vec<f32>,
}

/// The compiled tiny-model runtime.
pub struct TinyRuntime {
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    /// Weights as DEVICE-RESIDENT buffers, uploaded once at load time and
    /// reused by every `execute_b` call (§Perf: re-uploading the ~20 MB
    /// of weights per decode step dominated the serving hot path).
    weights: Vec<xla::PjRtBuffer>,
    /// Authoritative KV cache [layers, MAX_SLOTS, max_context, kv_heads,
    /// head_dim] — owned by rust, updated from decode outputs.
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))
}

impl TinyRuntime {
    /// Load artifacts from the default directory (`DUET_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<TinyRuntime> {
        Self::load(&artifacts_dir())
    }

    pub fn load(dir: &Path) -> Result<TinyRuntime> {
        let meta = ArtifactMeta::load(&dir.join("artifacts.meta.txt"))?;
        if !meta.decode_batches.contains(&MAX_SLOTS) {
            bail!("artifacts lack a decode_b{MAX_SLOTS} variant");
        }
        let manifest = WeightManifest::load(&dir.join("weights.manifest.txt"))?;
        if manifest.entries.len() != meta.n_weights {
            bail!(
                "manifest has {} weights, meta says {}",
                manifest.entries.len(),
                meta.n_weights
            );
        }
        let blob = std::fs::read(dir.join("weights.bin")).context("weights.bin")?;
        if blob.len() != manifest.total_bytes() {
            bail!("weights.bin size mismatch");
        }

        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        let prefill_exe = compile(&client, &dir.join(format!("prefill_s{}.hlo.txt", meta.prefill_seq)))?;
        let decode_exe = compile(&client, &dir.join(format!("decode_b{MAX_SLOTS}.hlo.txt")))?;

        // Slice the blob into weight tensors and upload them to the
        // device ONCE (manifest order == HLO parameter order).
        let mut weights = Vec::with_capacity(manifest.entries.len());
        for e in &manifest.entries {
            let bytes = &blob[e.offset..e.offset + e.size_bytes];
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer(&floats, &e.shape, None)
                .map_err(|e2| anyhow::anyhow!("upload {}: {e2:?}", e.name))?;
            weights.push(buf);
        }

        let cache_elems =
            meta.layers * MAX_SLOTS * meta.max_context * meta.kv_heads * meta.head_dim;
        Ok(TinyRuntime {
            meta,
            client,
            prefill_exe,
            decode_exe,
            weights,
            k_cache: vec![0.0; cache_elems],
            v_cache: vec![0.0; cache_elems],
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run prefill over a prompt (≤ prefill_seq tokens; right-padded).
    /// Returns the next token and the K/V rows to install into a slot.
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillOut> {
        let s = self.meta.prefill_seq;
        if prompt.is_empty() || prompt.len() > s {
            bail!("prompt length {} outside (0, {s}]", prompt.len());
        }
        let mut toks = vec![0i32; s];
        toks[..prompt.len()].copy_from_slice(prompt);
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&toks, &[s], None)
            .map_err(|e| anyhow::anyhow!("tokens upload: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_buf);
        let result = self
            .prefill_exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("prefill execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("prefill download: {e:?}"))?;
        let (logits, k, v) = result
            .to_tuple3()
            .map_err(|e| anyhow::anyhow!("prefill untuple: {e:?}"))?;
        let logits: Vec<f32> = logits
            .to_vec()
            .map_err(|e| anyhow::anyhow!("logits: {e:?}"))?;
        let v_sz = self.meta.vocab;
        let last = prompt.len() - 1;
        let row = &logits[last * v_sz..(last + 1) * v_sz];
        let next_token = argmax(row);
        Ok(PrefillOut {
            next_token,
            k: k.to_vec().map_err(|e| anyhow::anyhow!("k: {e:?}"))?,
            v: v.to_vec().map_err(|e| anyhow::anyhow!("v: {e:?}"))?,
        })
    }

    /// Install prefill K/V rows into decode-cache slot `slot` (positions
    /// `0..len`). Pure rust memory management — the L3 coordinator owns
    /// the cache.
    pub fn install_slot(&mut self, slot: usize, len: usize, k: &[f32], v: &[f32]) {
        assert!(slot < MAX_SLOTS);
        assert!(len <= self.meta.prefill_seq);
        let m = &self.meta;
        let row = m.kv_heads * m.head_dim; // elems per position
        let s = m.prefill_seq;
        for layer in 0..m.layers {
            for pos in 0..len {
                let src = (layer * s + pos) * row;
                let dst = ((layer * MAX_SLOTS + slot) * m.max_context + pos) * row;
                self.k_cache[dst..dst + row].copy_from_slice(&k[src..src + row]);
                self.v_cache[dst..dst + row].copy_from_slice(&v[src..src + row]);
            }
        }
    }

    /// Clear a slot (request finished).
    pub fn clear_slot(&mut self, slot: usize) {
        let m = &self.meta;
        let row = m.kv_heads * m.head_dim;
        for layer in 0..m.layers {
            let dst = ((layer * MAX_SLOTS + slot) * m.max_context) * row;
            let n = m.max_context * row;
            self.k_cache[dst..dst + n].fill(0.0);
            self.v_cache[dst..dst + n].fill(0.0);
        }
    }

    /// One decode step over all MAX_SLOTS slots. `tokens[i]` is the input
    /// token for slot i; `lengths[i]` the valid cache length (0 = slot
    /// inactive — output ignored). Returns per-slot argmax tokens.
    /// The KV cache advances in place for every active slot.
    pub fn decode_step(
        &mut self,
        tokens: &[i32; MAX_SLOTS],
        lengths: &[i32; MAX_SLOTS],
    ) -> Result<[i32; MAX_SLOTS]> {
        let m = &self.meta;
        let cache_dims = [m.layers, MAX_SLOTS, m.max_context, m.kv_heads, m.head_dim];
        let up = |data: &[f32], dims: &[usize]| {
            self.client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
        };
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&tokens[..], &[MAX_SLOTS], None)
            .map_err(|e| anyhow::anyhow!("tokens upload: {e:?}"))?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&lengths[..], &[MAX_SLOTS], None)
            .map_err(|e| anyhow::anyhow!("lengths upload: {e:?}"))?;
        let kc = up(&self.k_cache, &cache_dims)?;
        let vc = up(&self.v_cache, &cache_dims)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&kc);
        args.push(&vc);
        args.push(&len_buf);
        let result = self
            .decode_exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("decode execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("decode download: {e:?}"))?;
        let (logits, kc2, vc2) = result
            .to_tuple3()
            .map_err(|e| anyhow::anyhow!("decode untuple: {e:?}"))?;
        self.k_cache = kc2.to_vec().map_err(|e| anyhow::anyhow!("kc': {e:?}"))?;
        self.v_cache = vc2.to_vec().map_err(|e| anyhow::anyhow!("vc': {e:?}"))?;
        let logits: Vec<f32> = logits
            .to_vec()
            .map_err(|e| anyhow::anyhow!("logits: {e:?}"))?;
        let v_sz = m.vocab;
        let mut out = [0i32; MAX_SLOTS];
        for (i, o) in out.iter_mut().enumerate() {
            *o = argmax(&logits[i * v_sz..(i + 1) * v_sz]);
        }
        Ok(out)
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
