//! Artifact-directory parsing: `weights.manifest.txt`, `weights.bin`,
//! `artifacts.meta.txt` (all written by `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::parse::Config;

/// One tensor in the weights blob.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size_bytes: usize,
}

/// Parsed `weights.manifest.txt`.
#[derive(Debug, Clone, Default)]
pub struct WeightManifest {
    pub entries: Vec<WeightEntry>,
}

impl WeightManifest {
    pub fn parse(text: &str) -> Result<WeightManifest> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 fields", i + 1);
            }
            let shape = parts[1]
                .split('x')
                .map(|d| d.parse::<usize>().context("bad shape dim"))
                .collect::<Result<Vec<_>>>()?;
            entries.push(WeightEntry {
                name: parts[0].to_string(),
                shape,
                offset: parts[2].parse()?,
                size_bytes: parts[3].parse()?,
            });
        }
        // Entries must tile the blob contiguously.
        let mut expect = 0usize;
        for e in &entries {
            if e.offset != expect {
                bail!("manifest not contiguous at {}", e.name);
            }
            let elems: usize = e.shape.iter().product();
            if elems * 4 != e.size_bytes {
                bail!("{}: shape/size mismatch", e.name);
            }
            expect += e.size_bytes;
        }
        Ok(WeightManifest { entries })
    }

    pub fn load(path: &Path) -> Result<WeightManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        WeightManifest::parse(&text)
    }

    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.size_bytes).sum()
    }
}

/// Parsed `artifacts.meta.txt` — the model constants the runtime needs.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub prefill_seq: usize,
    pub max_context: usize,
    pub decode_batches: Vec<usize>,
    pub n_weights: usize,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let cfg = Config::parse(text).map_err(|e| anyhow::anyhow!("meta parse: {e}"))?;
        let need = |k: &str| -> Result<usize> {
            cfg.int(k)
                .map(|v| v as usize)
                .with_context(|| format!("meta missing `{k}`"))
        };
        let batches = cfg
            .str("decode_batches")
            .context("meta missing decode_batches")?
            .split(',')
            .map(|s| s.trim().parse::<usize>().context("bad batch"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactMeta {
            hidden: need("hidden")?,
            layers: need("layers")?,
            heads: need("heads")?,
            kv_heads: need("kv_heads")?,
            head_dim: need("head_dim")?,
            vocab: need("vocab")?,
            prefill_seq: need("prefill_seq")?,
            max_context: need("max_context")?,
            decode_batches: batches,
            n_weights: need("n_weights")?,
        })
    }

    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        ArtifactMeta::parse(&text)
    }
}

/// Locate the artifacts directory: $DUET_ARTIFACTS or ./artifacts
/// (relative to the workspace root).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("DUET_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // CARGO_MANIFEST_DIR works for tests/examples; fall back to cwd.
    if let Ok(root) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = PathBuf::from(root).join("artifacts");
        if p.exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// Do the artifacts exist (so tests can skip gracefully before
/// `make artifacts`)?
pub fn artifacts_available() -> bool {
    artifacts_dir().join("artifacts.meta.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_validates() {
        let m = WeightManifest::parse(
            "# comment\ntok 4x2 0 32\nw1 2x2 32 16\n",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].shape, vec![4, 2]);
        assert_eq!(m.total_bytes(), 48);
    }

    #[test]
    fn manifest_rejects_gaps_and_bad_sizes() {
        assert!(WeightManifest::parse("a 2x2 4 16\n").is_err()); // gap at 0
        assert!(WeightManifest::parse("a 2x2 0 15\n").is_err()); // size mismatch
        assert!(WeightManifest::parse("a 2x2 0\n").is_err()); // fields
    }

    #[test]
    fn meta_parses() {
        let meta = ArtifactMeta::parse(
            "hidden = 256\nlayers = 4\nheads = 8\nkv_heads = 4\nhead_dim = 32\n\
             intermediate = 1024\nvocab = 2048\nprefill_seq = 64\nmax_context = 320\n\
             decode_batches = \"1,2,4,8\"\nn_weights = 39\n",
        )
        .unwrap();
        assert_eq!(meta.vocab, 2048);
        assert_eq!(meta.decode_batches, vec![1, 2, 4, 8]);
    }

    #[test]
    fn meta_missing_key_errors() {
        assert!(ArtifactMeta::parse("hidden = 256\n").is_err());
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        if !artifacts_available() {
            return; // `make artifacts` not run yet
        }
        let dir = artifacts_dir();
        let meta = ArtifactMeta::load(&dir.join("artifacts.meta.txt")).unwrap();
        let man = WeightManifest::load(&dir.join("weights.manifest.txt")).unwrap();
        assert_eq!(man.entries.len(), meta.n_weights);
        let blob = std::fs::metadata(dir.join("weights.bin")).unwrap();
        assert_eq!(blob.len() as usize, man.total_bytes());
    }
}
