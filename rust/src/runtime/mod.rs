//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and serve the tiny model from rust — Python is never on this path.
//!
//! - [`artifacts`]: manifest/meta/weights-blob parsing.
//! - [`pjrt`]: the `xla`-crate wrapper — compile HLO text once per model
//!   variant, execute prefill / decode steps. Behind the `xla-pjrt`
//!   feature (the offline vendor set has no `xla` crate); the default
//!   build uses an API-compatible stub whose `load` fails, and callers
//!   skip gracefully via `artifacts::artifacts_available()`.
//! - [`backend`]: the [`PjrtBackend`] adapter implementing the engine's
//!   `ExecutionBackend` seam over [`TinyRuntime`]. Real serving goes
//!   through the unified front-end (`server::Server` over an
//!   `EngineCore`) with this backend plugged in — the crate has exactly
//!   one request lifecycle, simulated or real.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "xla-pjrt")]
#[path = "pjrt_xla.rs"]
pub mod pjrt;
#[cfg(not(feature = "xla-pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{ArtifactMeta, WeightManifest};
pub use backend::PjrtBackend;
pub use pjrt::TinyRuntime;
