//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and serve the tiny model from rust — Python is never on this path.
//!
//! - [`artifacts`]: manifest/meta/weights-blob parsing.
//! - [`pjrt`]: the `xla`-crate wrapper — compile HLO text once per model
//!   variant, execute prefill / decode steps.
//! - [`serving`]: a real continuous-batching engine over the runtime with
//!   DuetServe-style decode-priority + look-ahead scheduling.

pub mod artifacts;
pub mod pjrt;
pub mod serving;

pub use artifacts::{ArtifactMeta, WeightManifest};
pub use pjrt::TinyRuntime;
pub use serving::{RealEngine, RealPolicy, RealRequest, RealStats};
