//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and serve the tiny model from rust — Python is never on this path.
//!
//! - [`artifacts`]: manifest/meta/weights-blob parsing.
//! - [`pjrt`]: the `xla`-crate wrapper — compile HLO text once per model
//!   variant, execute prefill / decode steps. Behind the `xla-pjrt`
//!   feature (the offline vendor set has no `xla` crate); the default
//!   build uses an API-compatible stub whose `load` fails, and callers
//!   skip gracefully via `artifacts::artifacts_available()`.
//! - [`serving`]: a real continuous-batching engine over the runtime with
//!   DuetServe-style decode-priority + look-ahead scheduling.

pub mod artifacts;
#[cfg(feature = "xla-pjrt")]
#[path = "pjrt_xla.rs"]
pub mod pjrt;
#[cfg(not(feature = "xla-pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod serving;

pub use artifacts::{ArtifactMeta, WeightManifest};
pub use pjrt::TinyRuntime;
pub use serving::{RealEngine, RealPolicy, RealRequest, RealStats};
