//! Stub PJRT runtime, compiled when the `xla-pjrt` feature is off.
//!
//! The offline vendor set does not ship the `xla` crate, so the default
//! build replaces [`pjrt_xla`](super) with this API-compatible stand-in:
//! the types and signatures match, but [`TinyRuntime::load`] always
//! fails. Callers already gate on `artifacts::artifacts_available()` (and
//! artifacts can only be produced where the real toolchain exists), so
//! tests and examples skip gracefully instead of hitting this error.

use std::path::Path;

use anyhow::{bail, Result};

use super::artifacts::{artifacts_dir, ArtifactMeta};

/// Number of decode slots the serving runtime batches over.
pub const MAX_SLOTS: usize = 8;

/// Outcome of one prefill call.
pub struct PrefillOut {
    /// argmax token at the last valid prompt position.
    pub next_token: i32,
    /// K cache rows [layers, prefill_seq, kv_heads, head_dim], flattened.
    pub k: Vec<f32>,
    /// V cache rows, same shape.
    pub v: Vec<f32>,
}

/// The compiled tiny-model runtime (stub: construction always fails).
#[derive(Debug)]
pub struct TinyRuntime {
    pub meta: ArtifactMeta,
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
}

impl TinyRuntime {
    /// Load artifacts from the default directory (`DUET_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<TinyRuntime> {
        Self::load(&artifacts_dir())
    }

    pub fn load(_dir: &Path) -> Result<TinyRuntime> {
        bail!(
            "this build has no PJRT backend: rebuild with `--features xla-pjrt` \
             in an environment that provides the `xla` crate"
        )
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Run prefill over a prompt (unreachable in the stub: no instance of
    /// [`TinyRuntime`] can be constructed).
    pub fn prefill(&self, _prompt: &[i32]) -> Result<PrefillOut> {
        bail!("PJRT stub: no backend")
    }

    pub fn install_slot(&mut self, _slot: usize, _len: usize, _k: &[f32], _v: &[f32]) {}

    pub fn clear_slot(&mut self, _slot: usize) {}

    /// One decode step over all MAX_SLOTS slots (unreachable, see above).
    pub fn decode_step(
        &mut self,
        _tokens: &[i32; MAX_SLOTS],
        _lengths: &[i32; MAX_SLOTS],
    ) -> Result<[i32; MAX_SLOTS]> {
        bail!("PJRT stub: no backend")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_guidance() {
        let err = TinyRuntime::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("xla-pjrt"), "{err}");
    }
}
