//! The PJRT execution backend: the real-runtime adapter behind the
//! [`ExecutionBackend`] seam.
//!
//! This wraps [`TinyRuntime`] (the AOT-compiled tiny model served through
//! PJRT) so the *same* `EngineCore` + `server::Server` lifecycle that
//! drives the simulated evaluation also drives real tokens:
//!
//! - a prefill chunk that completes a prompt runs `TinyRuntime::prefill`
//!   over the whole prompt and installs the K/V rows into a decode slot
//!   (`install_slot`); the prefill logits' argmax becomes the request's
//!   first output token;
//! - each decode entry advances one step through the batched
//!   `decode_step` (one runtime call per iteration covers every scheduled
//!   slot, exactly like CUDA-Graph replay over a captured batch);
//! - iteration latency is *measured wall clock*, so the engine's clock,
//!   TTFT and TBT all come from the same `metrics` structs as the
//!   simulations — but reflect real execution.
//!
//! Capability notes:
//! - The runtime owns no SM partitions, so `supports_spatial()` is false
//!   and the core degrades spatial plans to aggregated execution (logged
//!   once). On the default build `TinyRuntime` is the stub whose `load`
//!   fails, so this backend can only be constructed where `make
//!   artifacts` has run (`--features xla-pjrt` for the real runtime).
//! - The runtime batches over at most [`MAX_SLOTS`] sequences; configure
//!   the serving path with `max_batch <= MAX_SLOTS`
//!   ([`PjrtBackend::tune_config`] does this).
//! - Chunked prefill cannot be split across runtime calls (the AOT
//!   executable prefills a whole prompt); non-completing chunks advance
//!   only engine-side accounting and the full prompt executes at the
//!   completing chunk.
//!
//! [`ExecutionBackend`]: crate::engine::ExecutionBackend

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::{ExecutionBackend, IterationBatch};
use crate::hw::PartitionPlan;
use crate::request::RequestId;
use crate::sim::{DispatchMode, ExecResult, SpatialResult};

use super::pjrt::{TinyRuntime, MAX_SLOTS};

/// [`ExecutionBackend`] over the PJRT-compiled tiny model.
pub struct PjrtBackend {
    rt: TinyRuntime,
    /// Decode slot index per in-flight request.
    slots: HashMap<RequestId, usize>,
    free_slots: Vec<usize>,
    /// Produced-but-not-yet-popped token values per request (FIFO).
    out: HashMap<RequestId, VecDeque<i32>>,
    /// Per-slot mirrors of the runtime's decode inputs.
    slot_token: [i32; MAX_SLOTS],
    slot_len: [i32; MAX_SLOTS],
}

impl PjrtBackend {
    pub fn new(rt: TinyRuntime) -> PjrtBackend {
        PjrtBackend {
            rt,
            slots: HashMap::new(),
            free_slots: (0..MAX_SLOTS).rev().collect(),
            out: HashMap::new(),
            slot_token: [0; MAX_SLOTS],
            slot_len: [0; MAX_SLOTS],
        }
    }

    /// Load the AOT artifacts from the default directory. Fails on the
    /// stub build (no `xla` crate) or when `make artifacts` has not run.
    pub fn load_default() -> Result<PjrtBackend> {
        Ok(PjrtBackend::new(TinyRuntime::load_default()?))
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    /// Clamp a serving config to what the runtime can batch: at most
    /// [`MAX_SLOTS`] concurrent sequences.
    pub fn tune_config(&self, mut cfg: ServingConfig) -> ServingConfig {
        cfg.max_batch = cfg.max_batch.min(MAX_SLOTS as u32);
        cfg
    }

    fn prefill_request(&mut self, id: RequestId, prompt: &[i32]) {
        assert!(
            prompt.len() < self.rt.meta.max_context,
            "pjrt backend: prompt of {} tokens exceeds compiled max_context {} (request {id})",
            prompt.len(),
            self.rt.meta.max_context
        );
        let slot = self
            .free_slots
            .pop()
            .expect("pjrt backend out of decode slots: configure max_batch <= MAX_SLOTS");
        let pre = self
            .rt
            .prefill(prompt)
            .expect("pjrt prefill failed (artifacts missing or runtime error)");
        self.rt.install_slot(slot, prompt.len(), &pre.k, &pre.v);
        self.slot_token[slot] = pre.next_token;
        self.slot_len[slot] = prompt.len() as i32;
        self.slots.insert(id, slot);
        self.out.entry(id).or_default().push_back(pre.next_token);
    }

    fn decode_batch(&mut self, ids: &[RequestId]) {
        // One batched step over the scheduled slots; unscheduled slots
        // are masked with length 0 (the runtime treats them as inactive,
        // mirroring CUDA-Graph padding).
        let mut tokens = [0i32; MAX_SLOTS];
        let mut lengths = [0i32; MAX_SLOTS];
        for id in ids {
            let Some(&slot) = self.slots.get(id) else { continue };
            // The step appends K/V at position `length`; past max_context
            // it would silently write into the next slot's cache rows.
            // The serving front-end rejects submissions that could get
            // here (`max_context()`), so this is a hard invariant.
            assert!(
                (self.slot_len[slot] as usize) < self.rt.meta.max_context,
                "pjrt backend: slot {slot} reached compiled max_context {} (request {id})",
                self.rt.meta.max_context
            );
            tokens[slot] = self.slot_token[slot];
            lengths[slot] = self.slot_len[slot];
        }
        let next = self
            .rt
            .decode_step(&tokens, &lengths)
            .expect("pjrt decode step failed");
        for id in ids {
            let Some(&slot) = self.slots.get(id) else { continue };
            self.slot_token[slot] = next[slot];
            self.slot_len[slot] += 1;
            self.out.entry(*id).or_default().push_back(next[slot]);
        }
    }
}

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supports_spatial(&self) -> bool {
        false // no SM partitioning on this runtime
    }

    /// The compiled KV cache holds `max_context` positions per slot;
    /// prompt + generated tokens must stay within it.
    fn max_context(&self) -> Option<u64> {
        Some(self.rt.meta.max_context as u64)
    }

    fn run_aggregated(
        &mut self,
        batch: &IterationBatch<'_>,
        _sms: u32,
        _mode: DispatchMode,
    ) -> ExecResult {
        let t0 = Instant::now();
        // Prompt-completing chunks run the whole prompt now (see module
        // docs); earlier chunks of the same prompt were engine-side only.
        for p in batch.prefill.iter().filter(|p| p.completes_prompt) {
            let prompt = p
                .prompt
                .expect("pjrt backend requires prompt token payloads (submit real prompts)");
            self.prefill_request(p.id, prompt);
        }
        if !batch.decode.is_empty() {
            let ids: Vec<RequestId> = batch.decode.iter().map(|d| d.id).collect();
            self.decode_batch(&ids);
        }
        ExecResult {
            gpu_time: t0.elapsed().as_secs_f64().max(1e-9),
            dispatch_time: 0.0,
            sm_util: 0.0,
            hbm_util: 0.0,
            flops: 0.0,
            bytes: 0.0,
        }
    }

    fn run_spatial(&mut self, _batch: &IterationBatch<'_>, _plan: &PartitionPlan) -> SpatialResult {
        unreachable!("core degrades spatial plans for backends without SM partitioning")
    }

    fn pop_token(&mut self, id: RequestId, _index: u64) -> i32 {
        self.out
            .get_mut(&id)
            .and_then(|q| q.pop_front())
            .expect("pjrt backend has no pending token for this request")
    }

    /// Tokens are real argmax values queued on this device — another
    /// worker's backend cannot reproduce them, so cluster topologies
    /// must not stream in-transfer requests from a stand-in backend.
    fn deterministic_tokens(&self) -> bool {
        false
    }

    fn release(&mut self, id: RequestId) {
        if let Some(slot) = self.slots.remove(&id) {
            self.rt.clear_slot(slot);
            self.slot_token[slot] = 0;
            self.slot_len[slot] = 0;
            self.free_slots.push(slot);
        }
        self.out.remove(&id);
    }

    /// Single-device runtime: prefill and decode share one device, so
    /// there is no P2P cache movement to model.
    fn kv_transfer_time(&self, _tokens: u64) -> f64 {
        0.0
    }
}
