//! A real continuous-batching serving engine over the PJRT runtime.
//!
//! This is the end-to-end validation path (DESIGN.md): real model, real
//! tokens, real wall-clock latency — exercising router → scheduler →
//! slot/KV management → PJRT execution with Python nowhere in sight.
//!
//! Two policies mirror the paper's aggregated-vs-duet contrast at the
//! software level (no SMs to partition on a CPU):
//! - `PrefillFirst`: drain every waiting prefill before decoding
//!   (SGLang-Default-flavoured; inflates TBT).
//! - `DuetInterleave`: decode-priority with `k`-step look-ahead decode
//!   between prefills (§4.3's look-ahead execution, CPU edition).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::util::stats::Summary;

use super::pjrt::{TinyRuntime, MAX_SLOTS};

/// A request for the real engine.
#[derive(Debug, Clone)]
pub struct RealRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Scheduling policy for the real engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RealPolicy {
    PrefillFirst,
    DuetInterleave { lookahead: u32 },
}

impl RealPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RealPolicy::PrefillFirst => "prefill-first",
            RealPolicy::DuetInterleave { .. } => "duet-interleave",
        }
    }
}

#[derive(Debug, Clone)]
struct Slot {
    id: u64,
    length: usize,
    generated: Vec<i32>,
    max_new: usize,
    next_token: i32,
    t_arrival: Instant,
    t_first: Option<Instant>,
    token_gaps: Vec<f64>,
    t_last: Instant,
}

/// Per-run statistics (real wall-clock).
#[derive(Debug, Clone)]
pub struct RealStats {
    pub policy: &'static str,
    pub completed: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub output_tokens: usize,
    pub decode_tokens_per_s: f64,
    pub ttft: Summary,
    pub tbt: Summary,
    /// Completed sequences with their generated tokens (determinism
    /// checks in tests).
    pub outputs: Vec<(u64, Vec<i32>)>,
}

/// The engine.
pub struct RealEngine {
    pub rt: TinyRuntime,
    pub policy: RealPolicy,
}

impl RealEngine {
    pub fn new(rt: TinyRuntime, policy: RealPolicy) -> RealEngine {
        RealEngine { rt, policy }
    }

    /// Serve `requests` to completion (closed-loop: all submitted at t0).
    pub fn serve(&mut self, requests: Vec<RealRequest>) -> Result<RealStats> {
        let t0 = Instant::now();
        let mut queue: VecDeque<RealRequest> = requests.into();
        let mut slots: Vec<Option<Slot>> = (0..MAX_SLOTS).map(|_| None).collect();
        let mut ttft = Vec::new();
        let mut tbt = Vec::new();
        let mut outputs = Vec::new();
        let mut output_tokens = 0usize;
        let mut decode_time = 0.0f64;

        let lookahead = match self.policy {
            RealPolicy::DuetInterleave { lookahead } => lookahead.max(1),
            RealPolicy::PrefillFirst => 1,
        };

        loop {
            let active = slots.iter().filter(|s| s.is_some()).count();
            if active == 0 && queue.is_empty() {
                break;
            }

            // --- Admission / prefill ---------------------------------
            let admit_now = match self.policy {
                // Drain ALL waiting prefills first whenever any wait.
                RealPolicy::PrefillFirst => !queue.is_empty() && active < MAX_SLOTS,
                // Decode-priority: only prefill when decode has no work
                // or a slot is free AND we just finished a look-ahead
                // span (this branch point *is* the admission boundary).
                RealPolicy::DuetInterleave { .. } => {
                    !queue.is_empty() && active < MAX_SLOTS
                }
            };
            if admit_now {
                // PrefillFirst admits every waiting request back-to-back.
                // DuetInterleave: while decode occupancy is low (ramp-up
                // or drain) fill the free slots — decode steps cost the
                // same regardless of active slots, so starving the batch
                // wastes throughput; once the batch is half full, admit
                // one per look-ahead span (decode priority).
                let n_admit = match self.policy {
                    RealPolicy::PrefillFirst => MAX_SLOTS - active,
                    RealPolicy::DuetInterleave { .. } => {
                        if active < MAX_SLOTS / 2 {
                            MAX_SLOTS - active
                        } else {
                            1
                        }
                    }
                };
                for _ in 0..n_admit {
                    let Some(req) = queue.pop_front() else { break };
                    let Some(slot_idx) = slots.iter().position(|s| s.is_none()) else {
                        queue.push_front(req);
                        break;
                    };
                    let arrived = t0; // closed-loop: all arrive at t0
                    let pre = self.rt.prefill(&req.prompt)?;
                    let now = Instant::now();
                    self.rt
                        .install_slot(slot_idx, req.prompt.len(), &pre.k, &pre.v);
                    let slot = Slot {
                        id: req.id,
                        length: req.prompt.len(),
                        generated: vec![pre.next_token],
                        max_new: req.max_new_tokens,
                        next_token: pre.next_token,
                        t_arrival: arrived,
                        t_first: Some(now),
                        token_gaps: Vec::new(),
                        t_last: now,
                    };
                    output_tokens += 1;
                    if slot.generated.len() >= slot.max_new {
                        // Single-token request: finish immediately.
                        ttft.push(now.duration_since(slot.t_arrival).as_secs_f64());
                        outputs.push((slot.id, slot.generated.clone()));
                        self.rt.clear_slot(slot_idx);
                    } else {
                        slots[slot_idx] = Some(slot.clone());
                    }
                    let _ = &slot;
                }
            }

            // --- Decode span (k look-ahead steps, no admission) -------
            let any_active = slots.iter().any(|s| s.is_some());
            if any_active {
                for _ in 0..lookahead {
                    let mut tokens = [0i32; MAX_SLOTS];
                    let mut lengths = [0i32; MAX_SLOTS];
                    for (i, s) in slots.iter().enumerate() {
                        if let Some(s) = s {
                            tokens[i] = s.next_token;
                            lengths[i] = s.length as i32;
                        }
                    }
                    let td = Instant::now();
                    let next = self.rt.decode_step(&tokens, &lengths)?;
                    decode_time += td.elapsed().as_secs_f64();
                    let now = Instant::now();
                    for i in 0..MAX_SLOTS {
                        let finished = {
                            let Some(s) = slots[i].as_mut() else { continue };
                            s.length += 1; // the step appended K/V
                            s.next_token = next[i];
                            s.generated.push(next[i]);
                            output_tokens += 1;
                            s.token_gaps
                                .push(now.duration_since(s.t_last).as_secs_f64());
                            s.t_last = now;
                            s.generated.len() >= s.max_new
                                || s.length + 1 >= self.rt.meta.max_context
                        };
                        if finished {
                            let s = slots[i].take().unwrap();
                            ttft.push(
                                s.t_first
                                    .unwrap()
                                    .duration_since(s.t_arrival)
                                    .as_secs_f64(),
                            );
                            tbt.extend(s.token_gaps.iter());
                            outputs.push((s.id, s.generated));
                            self.rt.clear_slot(i);
                        }
                    }
                    if slots.iter().all(|s| s.is_none()) {
                        break;
                    }
                }
            }
        }

        let wall = t0.elapsed().as_secs_f64();
        outputs.sort_by_key(|(id, _)| *id);
        Ok(RealStats {
            policy: self.policy.name(),
            completed: outputs.len(),
            wall_s: wall,
            throughput_rps: outputs.len() as f64 / wall.max(1e-9),
            output_tokens,
            decode_tokens_per_s: if decode_time > 0.0 {
                (output_tokens as f64 - outputs.len() as f64) / decode_time
            } else {
                0.0
            },
            ttft: Summary::of(&ttft),
            tbt: Summary::of(&tbt),
            outputs,
        })
    }
}
