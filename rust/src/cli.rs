//! Minimal command-line argument parser (no `clap` in the offline vendor
//! set). Supports subcommands, `--flag value`, `--flag=value`, and bare
//! boolean flags.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, positional args.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.opts.insert(flag.to_string(), v);
                } else {
                    out.opts.insert(flag.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Numeric option without a default: `Ok(None)` when absent,
    /// `Err` (for the caller to surface) when present but not a number —
    /// a typo'd value must not silently fall back to a default.
    pub fn usize_opt(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                format!("--{key} must be a non-negative integer, got `{v}`")
            }),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Enumerated option: returns the value if present, erroring (for the
    /// caller to surface) when it is not one of `allowed`. `None` when
    /// the flag was not given.
    pub fn one_of(&self, key: &str, allowed: &[&str]) -> Result<Option<&str>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) if allowed.contains(&v) => Ok(Some(v)),
            Some(v) => Err(format!(
                "--{key} must be one of [{}], got `{v}`",
                allowed.join("|")
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve extra --trace mooncake --qps 4.5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("trace"), Some("mooncake"));
        assert_eq!(a.f64_or("qps", 0.0), 4.5);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --n=100 --policy=duet");
        assert_eq!(a.usize_or("n", 0), 100);
        assert_eq!(a.str_or("policy", ""), "duet");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.f64_or("missing", 2.5), 2.5);
        assert!(!a.flag("missing"));
    }

    #[test]
    fn usize_opt_distinguishes_absent_from_unparseable() {
        let a = parse("serve --queue-cap 64");
        assert_eq!(a.usize_opt("queue-cap"), Ok(Some(64)));
        assert_eq!(a.usize_opt("missing"), Ok(None));
        assert!(parse("serve --queue-cap 10O").usize_opt("queue-cap").is_err());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("cmd --dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn one_of_validates_choices() {
        let a = parse("serve --router least-loaded");
        assert_eq!(
            a.one_of("router", &["round-robin", "least-loaded"]),
            Ok(Some("least-loaded"))
        );
        assert_eq!(a.one_of("missing", &["x"]), Ok(None));
        assert!(a.one_of("router", &["round-robin"]).is_err());
    }
}
