//! TPC-granular SM masks — the libsmctrl equivalent.
//!
//! `libsmctrl` (Bakita & Anderson 2023) masks TPCs visible to a kernel or
//! stream at launch time; the smallest unit is one TPC (2 SMs on H100).
//! [`SmMask`] models a contiguous TPC range (partitions in the paper are
//! two disjoint sets; contiguity is irrelevant to the cost model), and
//! [`PartitionPlan`] is the scheduler's chosen configuration
//! `(S_p, S_d, k)` from Algorithm 1.

use crate::config::GpuSpec;

/// A set of TPCs assigned to one stream, `[start_tpc, start_tpc + n_tpcs)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SmMask {
    pub start_tpc: u32,
    pub n_tpcs: u32,
}

impl SmMask {
    /// Mask covering a TPC range.
    pub fn tpcs(start_tpc: u32, n_tpcs: u32) -> SmMask {
        SmMask { start_tpc, n_tpcs }
    }

    /// The whole device.
    pub fn full(spec: &GpuSpec) -> SmMask {
        SmMask {
            start_tpc: 0,
            n_tpcs: spec.num_tpcs(),
        }
    }

    /// Number of SMs this mask exposes on `spec`.
    pub fn num_sms(&self, spec: &GpuSpec) -> u32 {
        self.n_tpcs * spec.sms_per_tpc
    }

    /// Fraction of the device.
    pub fn fraction(&self, spec: &GpuSpec) -> f64 {
        self.n_tpcs as f64 / spec.num_tpcs() as f64
    }

    pub fn is_empty(&self) -> bool {
        self.n_tpcs == 0
    }

    /// Whether two masks overlap (must be disjoint for spatial sharing).
    pub fn overlaps(&self, other: &SmMask) -> bool {
        if self.is_empty() || other.is_empty() {
            return false;
        }
        let a_end = self.start_tpc + self.n_tpcs;
        let b_end = other.start_tpc + other.n_tpcs;
        self.start_tpc < b_end && other.start_tpc < a_end
    }
}

/// Algorithm 1's output: the spatial-sharing configuration `C* = (S_p,
/// S_d, k)` plus the masks realizing it. `decode` gets the low TPCs,
/// `prefill` the high ones.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionPlan {
    pub decode: SmMask,
    pub prefill: SmMask,
    /// Look-ahead decode steps per prefill span.
    pub k: u32,
    /// Predicted decode step latency under this plan (seconds).
    pub t_decode: f64,
    /// Predicted prefill span latency under this plan (seconds).
    pub t_prefill: f64,
    /// Predicted token throughput ρ of this plan (tokens/second).
    pub rho: f64,
}

impl PartitionPlan {
    /// Construct a plan splitting `spec` into `decode_tpcs` low TPCs for
    /// decode and the rest for prefill.
    pub fn split(spec: &GpuSpec, decode_tpcs: u32, k: u32) -> PartitionPlan {
        let total = spec.num_tpcs();
        assert!(decode_tpcs <= total, "decode partition exceeds device");
        PartitionPlan {
            decode: SmMask::tpcs(0, decode_tpcs),
            prefill: SmMask::tpcs(decode_tpcs, total - decode_tpcs),
            k,
            t_decode: 0.0,
            t_prefill: 0.0,
            rho: 0.0,
        }
    }

    /// Predicted wall time of the spatial iteration:
    /// `max(k · t_d, t_p)` (paper §4.2).
    pub fn span(&self) -> f64 {
        (self.k as f64 * self.t_decode).max(self.t_prefill)
    }

    /// Partition invariant: masks disjoint and exactly covering the device.
    pub fn is_valid(&self, spec: &GpuSpec) -> bool {
        !self.decode.overlaps(&self.prefill)
            && self.decode.n_tpcs + self.prefill.n_tpcs <= spec.num_tpcs()
            && self.k >= 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    #[test]
    fn mask_sm_count_and_fraction() {
        let spec = GpuSpec::h100();
        let m = SmMask::tpcs(0, 33);
        assert_eq!(m.num_sms(&spec), 66);
        assert!((m.fraction(&spec) - 0.5).abs() < 1e-9);
        assert_eq!(SmMask::full(&spec).num_sms(&spec), 132);
    }

    #[test]
    fn overlap_detection() {
        let a = SmMask::tpcs(0, 20);
        let b = SmMask::tpcs(20, 46);
        let c = SmMask::tpcs(19, 2);
        assert!(!a.overlaps(&b));
        assert!(a.overlaps(&c));
        assert!(b.overlaps(&c));
        assert!(!a.overlaps(&SmMask::tpcs(5, 0)), "empty never overlaps");
    }

    #[test]
    fn split_covers_device_disjointly() {
        let spec = GpuSpec::h100();
        for d in 1..spec.num_tpcs() {
            let p = PartitionPlan::split(&spec, d, 3);
            assert!(p.is_valid(&spec), "d={d}");
            assert_eq!(p.decode.n_tpcs + p.prefill.n_tpcs, spec.num_tpcs());
        }
    }

    #[test]
    fn span_is_max_of_sides() {
        let mut p = PartitionPlan::split(&GpuSpec::h100(), 9, 5);
        p.t_decode = 0.01;
        p.t_prefill = 0.04;
        assert!((p.span() - 0.05).abs() < 1e-12); // 5*0.01 > 0.04
        p.t_prefill = 0.08;
        assert!((p.span() - 0.08).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn oversized_split_panics() {
        PartitionPlan::split(&GpuSpec::h100(), 67, 1);
    }
}
