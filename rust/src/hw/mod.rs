//! Simulated GPU hardware substrate.
//!
//! The paper partitions H100 SMs per CUDA stream with `libsmctrl`
//! (driver-level SM masks, TPC granularity). No GPU exists in this
//! environment, so this module provides the equivalent abstraction over
//! the simulated device: TPC-granular [`SmMask`]s, a [`Gpu`] that exposes
//! achievable Π_SM / B_HBM for a partition, and a multi-GPU [`Node`] with
//! NVLink. The discrete-event executor in [`crate::sim`] consumes these.

pub mod partition;

pub use partition::{PartitionPlan, SmMask};

use crate::config::GpuSpec;

/// One simulated GPU device.
#[derive(Debug, Clone)]
pub struct Gpu {
    pub spec: GpuSpec,
    pub id: u32,
}

impl Gpu {
    pub fn new(id: u32, spec: GpuSpec) -> Gpu {
        Gpu { spec, id }
    }

    /// Achievable FLOP/s for a partition (TPC-quantized SM count).
    pub fn pi(&self, mask: &SmMask) -> f64 {
        self.spec.pi_sm(mask.num_sms(&self.spec))
    }

    /// Achievable HBM bandwidth for a partition. NOTE: when two partitions
    /// run concurrently their *combined* demand is capped by the device
    /// peak — the executor enforces that; this is the isolated-curve value.
    pub fn bw(&self, mask: &SmMask) -> f64 {
        self.spec.b_hbm(mask.num_sms(&self.spec))
    }
}

/// A single-node multi-GPU server (the paper's testbed: 2×H100 NVLink,
/// Table 3: 8×H100).
#[derive(Debug, Clone)]
pub struct Node {
    pub gpus: Vec<Gpu>,
}

impl Node {
    pub fn new(n: u32, spec: GpuSpec) -> Node {
        Node {
            gpus: (0..n).map(|i| Gpu::new(i, spec.clone())).collect(),
        }
    }

    pub fn n_gpus(&self) -> u32 {
        self.gpus.len() as u32
    }

    /// Peer-to-peer KV transfer time over NVLink for `bytes` bytes
    /// (disaggregated prefill→decode handoff).
    pub fn p2p_transfer_time(&self, bytes: u64) -> f64 {
        let bw = self.gpus[0].spec.nvlink_bandwidth;
        // NIXL-style P2P achieves ~80% of link peak; plus a fixed setup.
        20e-6 + bytes as f64 / (0.8 * bw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    #[test]
    fn gpu_partition_curves() {
        let g = Gpu::new(0, GpuSpec::h100());
        let full = SmMask::full(&g.spec);
        let half = SmMask::tpcs(0, 33);
        assert!((g.pi(&full) - g.spec.peak_flops).abs() < 1.0);
        assert!((g.pi(&half) / g.spec.peak_flops - 0.5).abs() < 1e-9);
        // bandwidth at half the SMs is way above half of peak (super-linear)
        assert!(g.bw(&half) / g.spec.hbm_bandwidth > 0.8);
    }

    #[test]
    fn node_p2p_time_scales() {
        let node = Node::new(2, GpuSpec::h100());
        let t_small = node.p2p_transfer_time(1 << 20);
        let t_big = node.p2p_transfer_time(1 << 30);
        assert!(t_big > t_small);
        // 1 GiB over 0.8*450GB/s ≈ 3 ms
        assert!((t_big - 3.0e-3).abs() < 1.0e-3);
    }
}
