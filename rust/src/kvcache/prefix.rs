//! Block-granular prefix caching (SGLang-radix-style; Zheng et al. 2024).
//!
//! [`PrefixIndex`] lets finished requests *decay* their prompt KV blocks
//! into a cached pool instead of freeing them, so later requests with an
//! overlapping prompt skip recomputing the shared prefix.
//!
//! The index is keyed by **chained block hashes**: block `i`'s key mixes
//! block `i-1`'s key with block `i`'s content, so one key identifies the
//! entire token prefix up to and including that block (vLLM's prefix-hash
//! trick). A flat `HashMap` over chained keys is equivalent to a radix
//! tree over token sequences — longest-prefix match is "walk the keys in
//! order until the first miss" — without the tree's pointer chasing.
//!
//! Lifecycle of a cached block:
//!
//! - **held** (`refs > 0`): shared by one or more live block tables;
//!   never evictable.
//! - **cached** (`refs == 0`): content retained speculatively, sitting in
//!   a deterministic LRU (ordered by a logical touch tick). Cached blocks
//!   count as *free* for every capacity signal — they are reclaimed on
//!   demand by [`evict`](PrefixIndex::evict) before the allocator reports
//!   `OutOfBlocks`.
//!
//! Only *full prompt* blocks are indexable: a block holding the prompt
//! tail plus generated tokens is not a pure function of the prompt and
//! frees normally.

use std::collections::{BTreeSet, HashMap};

use super::BlockId;
use crate::request::Request;

/// Chained content hash identifying a whole prompt prefix at block
/// granularity.
pub type BlockKey = u64;

const CHAIN_SEED: u64 = 0x6b76_7072_6566_6978; // "kvprefix"
const BLOCK_SALT: u64 = 0x9e37_79b9_7f4a_7c15;

/// SplitMix64 finalizer: a cheap, well-mixed 64-bit permutation.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Chained block keys for `r`'s prompt, one per **full** prompt block
/// (`prompt_len / block_tokens`, floor). Real token ids are hashed when
/// the request carries them; synthetic requests fall back to a
/// deterministic hash of `(prefix_id, block index)`, so two synthetic
/// requests share exactly the blocks where their `prefix_id` matches.
/// A request with neither payload nor `prefix_id` has no cacheable
/// identity and returns no keys.
pub fn block_keys(r: &Request, block_tokens: u32) -> Vec<BlockKey> {
    let full = (r.prompt_len / block_tokens as u64) as usize;
    let mut keys = Vec::with_capacity(full);
    if let Some(tokens) = &r.prompt_tokens {
        let mut chain = CHAIN_SEED;
        for block in tokens.chunks_exact(block_tokens as usize) {
            let mut h = chain;
            for t in block {
                h = mix(h ^ (*t as u32 as u64));
            }
            chain = mix(h ^ BLOCK_SALT);
            keys.push(chain);
        }
    } else if let Some(pid) = r.prefix_id {
        let mut chain = mix(pid ^ CHAIN_SEED);
        for i in 0..full {
            chain = mix(chain ^ (i as u64).wrapping_mul(BLOCK_SALT));
            keys.push(chain);
        }
    }
    debug_assert!(keys.len() <= full);
    keys
}

#[derive(Debug)]
struct CachedBlock {
    key: BlockKey,
    /// Live block tables currently sharing this block.
    refs: u32,
    /// Logical LRU tick of the last release into the cached pool; only
    /// meaningful while `refs == 0` (it addresses the `lru` entry).
    last_use: u64,
}

/// The prefix index + cached-block pool (one per [`KvManager`]).
///
/// [`KvManager`]: super::KvManager
#[derive(Debug, Default)]
pub struct PrefixIndex {
    /// Chained prefix key → physical block holding that content.
    by_key: HashMap<BlockKey, BlockId>,
    /// Every block the index knows about (held or cached).
    blocks: HashMap<BlockId, CachedBlock>,
    /// Evictable blocks (`refs == 0`), ordered oldest-touch first. The
    /// `(tick, id)` pair makes eviction order deterministic.
    lru: BTreeSet<(u64, BlockId)>,
    /// Logical clock bumped on every pool insertion.
    tick: u64,
    /// Cached blocks reclaimed under allocation pressure (lifetime
    /// counter).
    evictions: u64,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Longest cached prefix of `keys`, in blocks (read-only probe; the
    /// routing signal).
    pub fn matched(&self, keys: &[BlockKey]) -> usize {
        keys.iter()
            .take_while(|k| self.by_key.contains_key(k))
            .count()
    }

    /// Take a reference on the longest cached prefix of `keys` (capped at
    /// `max_blocks`), appending the shared block ids to `out` in prefix
    /// order. Returns the number of blocks acquired.
    pub fn acquire(
        &mut self,
        keys: &[BlockKey],
        max_blocks: usize,
        out: &mut Vec<BlockId>,
    ) -> usize {
        let mut n = 0;
        for key in keys.iter().take(max_blocks) {
            let Some(&b) = self.by_key.get(key) else { break };
            let c = self.blocks.get_mut(&b).expect("indexed block missing");
            if c.refs == 0 {
                self.lru.remove(&(c.last_use, b));
            }
            c.refs += 1;
            out.push(b);
            n += 1;
        }
        n
    }

    /// Decay a finished request's private block into the cached pool
    /// under `key`. Returns false when the content is already indexed
    /// (the caller frees the duplicate block to the allocator instead).
    pub fn insert(&mut self, key: BlockKey, block: BlockId) -> bool {
        if self.by_key.contains_key(&key) {
            return false;
        }
        self.tick += 1;
        self.by_key.insert(key, block);
        let prev = self.blocks.insert(
            block,
            CachedBlock {
                key,
                refs: 0,
                last_use: self.tick,
            },
        );
        assert!(prev.is_none(), "block {block} already cached");
        self.lru.insert((self.tick, block));
        true
    }

    /// Drop one table's reference on a shared block; the last reference
    /// decays it into the cached (evictable) pool rather than freeing it.
    pub fn decref(&mut self, block: BlockId) {
        let c = self.blocks.get_mut(&block).expect("decref of unknown block");
        assert!(c.refs > 0, "refcount underflow on block {block}");
        c.refs -= 1;
        if c.refs == 0 {
            self.tick += 1;
            c.last_use = self.tick;
            self.lru.insert((self.tick, block));
        }
    }

    /// Reclaim up to `want` cached blocks, oldest first, pushing the
    /// freed ids into `freed` (the caller returns them to the
    /// allocator). Returns the number evicted.
    pub fn evict(&mut self, want: u64, freed: &mut Vec<BlockId>) -> u64 {
        let mut n = 0;
        while n < want {
            let Some(&(tick, b)) = self.lru.iter().next() else {
                break;
            };
            self.lru.remove(&(tick, b));
            let c = self.blocks.remove(&b).expect("lru entry without block");
            debug_assert_eq!(c.refs, 0, "evicting a referenced block");
            let owner = self.by_key.remove(&c.key);
            debug_assert_eq!(owner, Some(b));
            freed.push(b);
            n += 1;
        }
        self.evictions += n;
        n
    }

    /// Evictable (`refs == 0`) blocks — these count as free capacity.
    pub fn cached(&self) -> u64 {
        self.lru.len() as u64
    }

    /// Every block the index holds content for (held + cached): the
    /// router's residency signal.
    pub fn resident(&self) -> u64 {
        self.blocks.len() as u64
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn contains_block(&self, b: BlockId) -> bool {
        self.blocks.contains_key(&b)
    }

    /// Internal consistency against the block tables' view:
    /// `expected_refs[b]` is how many live tables list shared block `b`.
    pub fn check_invariants(
        &self,
        expected_refs: &HashMap<BlockId, u32>,
    ) -> Result<(), String> {
        if self.by_key.len() != self.blocks.len() {
            return Err(format!(
                "key index size {} != block set size {}",
                self.by_key.len(),
                self.blocks.len()
            ));
        }
        let mut zero = 0u64;
        for (b, c) in &self.blocks {
            if self.by_key.get(&c.key) != Some(b) {
                return Err(format!("block {b}: key→block index mismatch"));
            }
            let want = expected_refs.get(b).copied().unwrap_or(0);
            if c.refs != want {
                return Err(format!(
                    "block {b}: refs {} != table membership {want}",
                    c.refs
                ));
            }
            let in_lru = self.lru.contains(&(c.last_use, *b));
            if (c.refs == 0) != in_lru {
                return Err(format!(
                    "block {b}: refs {} but lru membership {in_lru}",
                    c.refs
                ));
            }
            if c.refs == 0 {
                zero += 1;
            }
        }
        for b in expected_refs.keys() {
            if !self.blocks.contains_key(b) {
                return Err(format!("shared block {b} missing from prefix index"));
            }
        }
        if zero != self.lru.len() as u64 {
            return Err(format!(
                "lru size {} != zero-ref block count {zero}",
                self.lru.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_with_tokens(id: u64, tokens: Vec<i32>) -> Request {
        let n = tokens.len() as u64;
        Request::new(id, 0.0, n, 1).with_prompt_tokens(tokens)
    }

    #[test]
    fn chained_keys_share_prefix_and_diverge_at_first_difference() {
        let a = req_with_tokens(1, (0..64).collect());
        let mut btoks: Vec<i32> = (0..64).collect();
        btoks[40] = 999; // differs inside block 2
        let b = req_with_tokens(2, btoks);
        let ka = block_keys(&a, 16);
        let kb = block_keys(&b, 16);
        assert_eq!(ka.len(), 4);
        assert_eq!(ka[..2], kb[..2], "identical prefix blocks share keys");
        assert_ne!(ka[2], kb[2], "divergent block gets a new key");
        assert_ne!(ka[3], kb[3], "chain propagates the divergence");
    }

    #[test]
    fn partial_tail_block_is_not_keyed() {
        let r = req_with_tokens(1, (0..40).collect());
        assert_eq!(block_keys(&r, 16).len(), 2); // 40/16 = 2 full blocks
    }

    #[test]
    fn fallback_keys_follow_prefix_id() {
        let a = Request::new(1, 0.0, 64, 1).with_prefix_id(7);
        let b = Request::new(2, 0.0, 48, 1).with_prefix_id(7);
        let c = Request::new(3, 0.0, 64, 1).with_prefix_id(8);
        let ka = block_keys(&a, 16);
        let kb = block_keys(&b, 16);
        let kc = block_keys(&c, 16);
        assert_eq!(ka[..3], kb[..3], "same prefix_id shares every block");
        assert!(ka.iter().zip(&kc).all(|(x, y)| x != y));
        // No identity at all → nothing cacheable.
        assert!(block_keys(&Request::new(4, 0.0, 64, 1), 16).is_empty());
    }

    #[test]
    fn acquire_decay_evict_roundtrip() {
        let mut idx = PrefixIndex::new();
        assert!(idx.insert(11, 0));
        assert!(idx.insert(22, 1));
        assert!(!idx.insert(11, 2), "duplicate content is rejected");
        assert_eq!(idx.cached(), 2);
        assert_eq!(idx.resident(), 2);

        // Longest-prefix acquire stops at the first miss.
        let mut table = Vec::new();
        let n = idx.acquire(&[11, 99, 22], 8, &mut table);
        assert_eq!(n, 1);
        assert_eq!(table, vec![0]);
        assert_eq!(idx.cached(), 1, "held block left the LRU");

        // A held block is never evicted.
        let mut freed = Vec::new();
        assert_eq!(idx.evict(10, &mut freed), 1);
        assert_eq!(freed, vec![1]);
        assert_eq!(idx.evictions(), 1);

        // Decay back to cached, then evict.
        idx.decref(0);
        assert_eq!(idx.cached(), 1);
        freed.clear();
        assert_eq!(idx.evict(1, &mut freed), 1);
        assert_eq!(freed, vec![0]);
        assert_eq!(idx.resident(), 0);
        idx.check_invariants(&HashMap::new()).unwrap();
    }

    #[test]
    fn eviction_is_lru_ordered() {
        let mut idx = PrefixIndex::new();
        idx.insert(1, 10);
        idx.insert(2, 20);
        idx.insert(3, 30);
        // Touch block 10 (acquire + decay) so it becomes most recent.
        let mut t = Vec::new();
        idx.acquire(&[1], 8, &mut t);
        idx.decref(10);
        let mut freed = Vec::new();
        idx.evict(2, &mut freed);
        assert_eq!(freed, vec![20, 30], "oldest-touched evict first");
        idx.check_invariants(&HashMap::new()).unwrap();
    }

    #[test]
    fn acquire_respects_block_cap() {
        let mut idx = PrefixIndex::new();
        idx.insert(1, 10);
        idx.insert(2, 20);
        let mut t = Vec::new();
        assert_eq!(idx.acquire(&[1, 2], 1, &mut t), 1);
        assert_eq!(t, vec![10]);
        assert_eq!(idx.matched(&[1, 2]), 2, "probe ignores the cap");
    }
}
