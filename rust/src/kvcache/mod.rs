//! Paged KV-cache management (vLLM-style; Kwon et al. 2023).
//!
//! The engines track KV memory at block granularity: a block holds
//! `block_tokens` tokens of K+V for all layers. The allocator hands out
//! physical block ids; [`KvManager`] maps each request to its block table
//! and implements the look-ahead preallocation DuetServe's §4.3 engine
//! needs (reserve `k` future decode slots up front so k decode steps can
//! run without CPU synchronization).

pub mod allocator;

pub use allocator::BlockAllocator;

use crate::request::RequestId;
use std::collections::HashMap;

/// Physical block id.
pub type BlockId = u32;

/// Errors surfaced to the scheduler (admission control reacts to these).
/// (Display/Error are hand-implemented — no `thiserror` in the offline
/// vendor set.)
#[derive(Debug, PartialEq)]
pub enum KvError {
    OutOfBlocks { need: u64, free: u64 },
    UnknownRequest(RequestId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { need, free } => {
                write!(f, "out of KV blocks: need {need}, free {free}")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
        }
    }
}

impl std::error::Error for KvError {}

/// Per-request block table.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    /// Tokens stored (≤ blocks.len() * block_tokens).
    pub tokens: u64,
    /// Tokens *reserved* ahead of time (look-ahead decode slots).
    pub reserved_tokens: u64,
}

/// KV-cache manager: allocator + block tables + watermark admission.
#[derive(Debug)]
pub struct KvManager {
    alloc: BlockAllocator,
    block_tokens: u32,
    tables: HashMap<RequestId, BlockTable>,
}

impl KvManager {
    pub fn new(total_blocks: u64, block_tokens: u32) -> KvManager {
        KvManager {
            alloc: BlockAllocator::new(total_blocks),
            block_tokens,
            tables: HashMap::new(),
        }
    }

    pub fn free_blocks(&self) -> u64 {
        self.alloc.free()
    }

    pub fn total_blocks(&self) -> u64 {
        self.alloc.total()
    }

    pub fn free_fraction(&self) -> f64 {
        self.alloc.free() as f64 / self.alloc.total().max(1) as f64
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.block_tokens as u64)
    }

    /// Can `tokens` additional tokens be appended for `id` without
    /// exceeding capacity? (Headroom in already-held blocks counts.)
    pub fn can_append(&self, id: RequestId, tokens: u64) -> bool {
        let headroom = self
            .tables
            .get(&id)
            .map(|t| t.blocks.len() as u64 * self.block_tokens as u64 - t.tokens)
            .unwrap_or(0);
        let extra = tokens.saturating_sub(headroom);
        extra == 0 || self.blocks_for(extra) <= self.alloc.free()
    }

    /// Register a request (no allocation yet).
    pub fn register(&mut self, id: RequestId) {
        self.tables.entry(id).or_default();
    }

    /// Append `tokens` tokens to `id`'s cache, allocating blocks as
    /// needed. Fails atomically (no partial allocation) when blocks run
    /// out.
    pub fn append(&mut self, id: RequestId, tokens: u64) -> Result<(), KvError> {
        let bt = self.block_tokens as u64;
        let table = self
            .tables
            .get_mut(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        let capacity = table.blocks.len() as u64 * bt;
        let needed_tokens = (table.tokens + tokens).saturating_sub(capacity);
        let need_blocks = needed_tokens.div_ceil(bt);
        if need_blocks > 0 {
            self.alloc
                .allocate_into(need_blocks, &mut table.blocks)
                .map_err(|free| KvError::OutOfBlocks {
                    need: need_blocks,
                    free,
                })?;
        }
        table.tokens += tokens;
        table.reserved_tokens = table.reserved_tokens.saturating_sub(tokens);
        Ok(())
    }

    /// Reserve room for `tokens` future tokens (look-ahead decode §4.3):
    /// blocks are allocated now so `k` decode steps can append without
    /// ever taking the allocator lock / syncing with the CPU.
    pub fn reserve(&mut self, id: RequestId, tokens: u64) -> Result<(), KvError> {
        let bt = self.block_tokens as u64;
        let table = self
            .tables
            .get_mut(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        let capacity = table.blocks.len() as u64 * bt;
        let want = table.tokens + table.reserved_tokens + tokens;
        let needed_tokens = want.saturating_sub(capacity);
        let need_blocks = needed_tokens.div_ceil(bt);
        if need_blocks > 0 {
            self.alloc
                .allocate_into(need_blocks, &mut table.blocks)
                .map_err(|free| KvError::OutOfBlocks {
                    need: need_blocks,
                    free,
                })?;
        }
        table.reserved_tokens += tokens;
        Ok(())
    }

    /// Release everything held by `id` (request finished or preempted).
    pub fn release(&mut self, id: RequestId) -> Result<(), KvError> {
        let table = self.tables.remove(&id).ok_or(KvError::UnknownRequest(id))?;
        self.alloc.release(&table.blocks);
        Ok(())
    }

    /// Tokens currently stored for `id`.
    pub fn tokens_of(&self, id: RequestId) -> u64 {
        self.tables.get(&id).map(|t| t.tokens).unwrap_or(0)
    }

    /// Blocks held by `id`.
    pub fn blocks_of(&self, id: RequestId) -> u64 {
        self.tables.get(&id).map(|t| t.blocks.len() as u64).unwrap_or(0)
    }

    /// Used blocks across all requests.
    pub fn used_blocks(&self) -> u64 {
        self.alloc.total() - self.alloc.free()
    }

    /// Invariant check used by property tests: allocator accounting must
    /// match the sum of table holdings, and no block may appear twice.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        let mut held = 0u64;
        for (id, t) in &self.tables {
            held += t.blocks.len() as u64;
            for b in &t.blocks {
                if !seen.insert(*b) {
                    return Err(format!("block {b} double-owned (req {id})"));
                }
            }
            let cap = t.blocks.len() as u64 * self.block_tokens as u64;
            if t.tokens + t.reserved_tokens > cap {
                return Err(format!(
                    "req {id}: tokens {} + reserved {} exceed capacity {cap}",
                    t.tokens, t.reserved_tokens
                ));
            }
        }
        if held + self.alloc.free() != self.alloc.total() {
            return Err(format!(
                "leak: held {held} + free {} != total {}",
                self.alloc.free(),
                self.alloc.total()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_allocates_blocks() {
        let mut kv = KvManager::new(10, 16);
        kv.register(1);
        kv.append(1, 20).unwrap();
        assert_eq!(kv.blocks_of(1), 2);
        assert_eq!(kv.tokens_of(1), 20);
        assert_eq!(kv.free_blocks(), 8);
        kv.append(1, 12).unwrap(); // fits in existing block
        assert_eq!(kv.blocks_of(1), 2);
        kv.append(1, 1).unwrap(); // spills
        assert_eq!(kv.blocks_of(1), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn out_of_blocks_is_atomic() {
        let mut kv = KvManager::new(2, 16);
        kv.register(1);
        kv.append(1, 16).unwrap();
        let err = kv.append(1, 100).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        // failed append must not change state
        assert_eq!(kv.tokens_of(1), 16);
        assert_eq!(kv.free_blocks(), 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_blocks() {
        let mut kv = KvManager::new(8, 16);
        kv.register(1);
        kv.register(2);
        kv.append(1, 64).unwrap();
        kv.append(2, 32).unwrap();
        assert_eq!(kv.free_blocks(), 2);
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 6);
        assert_eq!(kv.release(1).unwrap_err(), KvError::UnknownRequest(1));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_then_append_consumes_reservation() {
        let mut kv = KvManager::new(8, 16);
        kv.register(1);
        kv.append(1, 10).unwrap();
        // reserve 8 look-ahead tokens: 10+8=18 -> needs 2 blocks total
        kv.reserve(1, 8).unwrap();
        assert_eq!(kv.blocks_of(1), 2);
        let free_before = kv.free_blocks();
        // appending within the reservation must not allocate
        kv.append(1, 6).unwrap();
        assert_eq!(kv.free_blocks(), free_before);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn free_fraction_for_watermark() {
        let mut kv = KvManager::new(100, 16);
        kv.register(1);
        kv.append(1, 16 * 98).unwrap();
        assert!((kv.free_fraction() - 0.02).abs() < 1e-9);
    }

    #[test]
    fn property_no_leak_under_random_ops() {
        use crate::util::proptest::check;
        check(64, |g| {
            let total = g.u64_range(4, 64);
            let mut kv = KvManager::new(total, 16);
            let mut live: Vec<RequestId> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_range(5, 60) {
                match g.u64_range(0, 3) {
                    0 => {
                        kv.register(next_id);
                        live.push(next_id);
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        let _ = kv.append(id, g.u64_range(1, 64));
                    }
                    2 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        let _ = kv.reserve(id, g.u64_range(1, 32));
                    }
                    3 if !live.is_empty() => {
                        let idx = g.usize_range(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        kv.release(id).map_err(|e| e.to_string())?;
                    }
                    _ => {}
                }
                kv.check_invariants()?;
            }
            Ok(())
        });
    }
}
