//! Paged KV-cache management (vLLM-style; Kwon et al. 2023).
//!
//! The engines track KV memory at block granularity: a block holds
//! `block_tokens` tokens of K+V for all layers. The allocator hands out
//! physical block ids; [`KvManager`] maps each request to its block table
//! and implements the look-ahead preallocation DuetServe's §4.3 engine
//! needs (reserve `k` future decode slots up front so k decode steps can
//! run without CPU synchronization).
//!
//! With the optional [`prefix`] subsystem enabled, block tables can begin
//! with *shared* blocks (refcounted in the [`PrefixIndex`]); finished
//! requests decay their full prompt blocks into a cached LRU pool instead
//! of freeing them, and allocation under pressure evicts cached blocks
//! before reporting [`KvError::OutOfBlocks`]. Cached blocks count as free
//! in every capacity signal (`free_blocks`, `free_fraction`,
//! `can_append`), so a prefix-enabled manager serving *disjoint* prompts
//! is capacity-indistinguishable from a plain one.

pub mod allocator;
pub mod prefix;

pub use allocator::BlockAllocator;
pub use prefix::{block_keys, BlockKey, PrefixIndex};

use crate::request::RequestId;
use std::collections::HashMap;

/// Physical block id.
pub type BlockId = u32;

/// Errors surfaced to the scheduler (admission control reacts to these).
/// (Display/Error are hand-implemented — no `thiserror` in the offline
/// vendor set.)
#[derive(Debug, PartialEq)]
pub enum KvError {
    OutOfBlocks { need: u64, free: u64 },
    UnknownRequest(RequestId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { need, free } => {
                write!(f, "out of KV blocks: need {need}, free {free}")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
        }
    }
}

impl std::error::Error for KvError {}

/// Per-request block table.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    /// Tokens stored (≤ blocks.len() * block_tokens).
    pub tokens: u64,
    /// Tokens *reserved* ahead of time (look-ahead decode slots).
    pub reserved_tokens: u64,
    /// The first `shared` entries of `blocks` are prefix-cache blocks
    /// refcounted in the [`PrefixIndex`]; the rest are privately owned.
    pub shared: usize,
}

/// KV-cache manager: allocator + block tables + watermark admission.
#[derive(Debug)]
pub struct KvManager {
    alloc: BlockAllocator,
    block_tokens: u32,
    tables: HashMap<RequestId, BlockTable>,
    /// Prefix cache (None = plain vLLM-style paging, the default).
    prefix: Option<PrefixIndex>,
}

impl KvManager {
    pub fn new(total_blocks: u64, block_tokens: u32) -> KvManager {
        KvManager {
            alloc: BlockAllocator::new(total_blocks),
            block_tokens,
            tables: HashMap::new(),
            prefix: None,
        }
    }

    /// Turn on block-level prefix caching (before any traffic).
    pub fn enable_prefix_cache(&mut self) {
        self.prefix.get_or_insert_with(PrefixIndex::new);
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Free capacity in blocks. Cached (unreferenced) prefix blocks are
    /// reclaimable on demand, so they count as free — this keeps every
    /// admission signal identical to a prefix-less manager when no
    /// prompt ever overlaps.
    pub fn free_blocks(&self) -> u64 {
        self.alloc.free() + self.prefix.as_ref().map_or(0, |p| p.cached())
    }

    pub fn total_blocks(&self) -> u64 {
        self.alloc.total()
    }

    pub fn free_fraction(&self) -> f64 {
        self.free_blocks() as f64 / self.alloc.total().max(1) as f64
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.block_tokens as u64)
    }

    /// Can `tokens` additional tokens be appended for `id` without
    /// exceeding capacity? Headroom in already-held blocks counts, but
    /// only the part not spoken for by look-ahead reservations.
    pub fn can_append(&self, id: RequestId, tokens: u64) -> bool {
        let headroom = self
            .tables
            .get(&id)
            .map(|t| {
                (t.blocks.len() as u64 * self.block_tokens as u64)
                    .saturating_sub(t.tokens + t.reserved_tokens)
            })
            .unwrap_or(0);
        let extra = tokens.saturating_sub(headroom);
        extra == 0 || self.blocks_for(extra) <= self.free_blocks()
    }

    /// Register a request (no allocation yet).
    pub fn register(&mut self, id: RequestId) {
        self.tables.entry(id).or_default();
    }

    /// Allocate `need` blocks into `out`, evicting LRU cached prefix
    /// blocks first when the free list alone cannot cover the request.
    /// On failure reports the reclaimable capacity (free + cached).
    fn allocate_evicting(
        alloc: &mut BlockAllocator,
        prefix: &mut Option<PrefixIndex>,
        need: u64,
        out: &mut Vec<BlockId>,
    ) -> Result<(), u64> {
        if need > alloc.free() {
            if let Some(pool) = prefix {
                let shortfall = need - alloc.free();
                let mut freed = Vec::new();
                pool.evict(shortfall, &mut freed);
                alloc.release(&freed);
            }
        }
        alloc
            .allocate_into(need, out)
            .map_err(|free| free + prefix.as_ref().map_or(0, |p| p.cached()))
    }

    /// Append `tokens` tokens to `id`'s cache, allocating blocks as
    /// needed. Fails atomically (no partial allocation) when blocks run
    /// out.
    pub fn append(&mut self, id: RequestId, tokens: u64) -> Result<(), KvError> {
        let bt = self.block_tokens as u64;
        let KvManager {
            alloc,
            prefix,
            tables,
            ..
        } = self;
        let table = tables.get_mut(&id).ok_or(KvError::UnknownRequest(id))?;
        let capacity = table.blocks.len() as u64 * bt;
        let needed_tokens = (table.tokens + tokens).saturating_sub(capacity);
        let need_blocks = needed_tokens.div_ceil(bt);
        if need_blocks > 0 {
            Self::allocate_evicting(alloc, prefix, need_blocks, &mut table.blocks).map_err(
                |free| KvError::OutOfBlocks {
                    need: need_blocks,
                    free,
                },
            )?;
        }
        table.tokens += tokens;
        table.reserved_tokens = table.reserved_tokens.saturating_sub(tokens);
        Ok(())
    }

    /// Reserve room for `tokens` future tokens (look-ahead decode §4.3):
    /// blocks are allocated now so `k` decode steps can append without
    /// ever taking the allocator lock / syncing with the CPU.
    pub fn reserve(&mut self, id: RequestId, tokens: u64) -> Result<(), KvError> {
        let bt = self.block_tokens as u64;
        let KvManager {
            alloc,
            prefix,
            tables,
            ..
        } = self;
        let table = tables.get_mut(&id).ok_or(KvError::UnknownRequest(id))?;
        let capacity = table.blocks.len() as u64 * bt;
        let want = table.tokens + table.reserved_tokens + tokens;
        let needed_tokens = want.saturating_sub(capacity);
        let need_blocks = needed_tokens.div_ceil(bt);
        if need_blocks > 0 {
            Self::allocate_evicting(alloc, prefix, need_blocks, &mut table.blocks).map_err(
                |free| KvError::OutOfBlocks {
                    need: need_blocks,
                    free,
                },
            )?;
        }
        table.reserved_tokens += tokens;
        Ok(())
    }

    /// Seed `id`'s (empty) block table with the longest cached prefix of
    /// `keys`, capped at `max_tokens` (callers cap below the full prompt
    /// so at least one token is left to prefill). Returns the number of
    /// prompt tokens covered by the shared blocks (0 when the prefix
    /// cache is disabled or nothing matches).
    pub fn seed_prefix(&mut self, id: RequestId, keys: &[BlockKey], max_tokens: u64) -> u64 {
        let bt = self.block_tokens as u64;
        let Some(pool) = self.prefix.as_mut() else {
            return 0;
        };
        let table = self
            .tables
            .get_mut(&id)
            .expect("seed_prefix before register");
        assert!(
            table.blocks.is_empty() && table.tokens == 0,
            "seed_prefix into a non-empty table"
        );
        let max_blocks = (max_tokens / bt) as usize;
        let n = pool.acquire(keys, max_blocks, &mut table.blocks);
        table.shared = n;
        table.tokens = n as u64 * bt;
        table.tokens
    }

    /// Longest cached prefix of `keys` in tokens (read-only; the routing
    /// overlap signal). 0 when the prefix cache is disabled.
    pub fn probe_prefix(&self, keys: &[BlockKey]) -> u64 {
        self.prefix
            .as_ref()
            .map_or(0, |p| p.matched(keys) as u64 * self.block_tokens as u64)
    }

    /// Tokens of prompt content resident in the prefix index (held +
    /// cached): the router's residency signal.
    pub fn prefix_resident_tokens(&self) -> u64 {
        self.prefix
            .as_ref()
            .map_or(0, |p| p.resident() * self.block_tokens as u64)
    }

    /// Cached prefix blocks evicted under allocation pressure (lifetime).
    pub fn prefix_evictions(&self) -> u64 {
        self.prefix.as_ref().map_or(0, |p| p.evictions())
    }

    /// Cached (unreferenced, evictable) prefix blocks.
    pub fn cached_blocks(&self) -> u64 {
        self.prefix.as_ref().map_or(0, |p| p.cached())
    }

    /// Release everything held by `id` (preemption, cancel, transfer —
    /// any path where the KV content is *not* known-good to completion).
    /// Shared blocks drop their reference (decaying to cached when this
    /// was the last holder); private blocks free outright.
    pub fn release(&mut self, id: RequestId) -> Result<(), KvError> {
        let table = self.tables.remove(&id).ok_or(KvError::UnknownRequest(id))?;
        if let Some(pool) = self.prefix.as_mut() {
            for b in &table.blocks[..table.shared] {
                pool.decref(*b);
            }
            self.alloc.release(&table.blocks[table.shared..]);
        } else {
            self.alloc.release(&table.blocks);
        }
        Ok(())
    }

    /// Release a *finished* request, decaying its full prompt blocks
    /// (identified by `keys`, as produced by [`block_keys`]) into the
    /// cached pool for future reuse. Blocks holding the prompt tail or
    /// generated tokens, and blocks whose content is already indexed,
    /// free normally. Equivalent to [`release`](KvManager::release) when
    /// the prefix cache is disabled.
    pub fn finish_release(&mut self, id: RequestId, keys: &[BlockKey]) -> Result<(), KvError> {
        let Some(pool) = self.prefix.as_mut() else {
            return self.release(id);
        };
        let table = self.tables.remove(&id).ok_or(KvError::UnknownRequest(id))?;
        let mut freed: Vec<BlockId> = Vec::new();
        for (i, b) in table.blocks.iter().enumerate() {
            if i < table.shared {
                pool.decref(*b);
            } else if i < keys.len() {
                if !pool.insert(keys[i], *b) {
                    freed.push(*b); // content already cached elsewhere
                }
            } else {
                freed.push(*b);
            }
        }
        self.alloc.release(&freed);
        Ok(())
    }

    /// Tokens currently stored for `id`.
    pub fn tokens_of(&self, id: RequestId) -> u64 {
        self.tables.get(&id).map(|t| t.tokens).unwrap_or(0)
    }

    /// Blocks held by `id`.
    pub fn blocks_of(&self, id: RequestId) -> u64 {
        self.tables.get(&id).map(|t| t.blocks.len() as u64).unwrap_or(0)
    }

    /// Used blocks across all requests.
    pub fn used_blocks(&self) -> u64 {
        self.alloc.total() - self.free_blocks()
    }

    /// Invariant check used by property tests: allocator accounting must
    /// match the sum of table holdings plus prefix-index residency, no
    /// block may appear twice, and shared-block refcounts must equal
    /// live-table membership.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut refs: HashMap<BlockId, u32> = HashMap::new();
        let mut private = std::collections::HashSet::new();
        let mut held_private = 0u64;
        for (id, t) in &self.tables {
            if t.shared > t.blocks.len() {
                return Err(format!(
                    "req {id}: shared {} exceeds table size {}",
                    t.shared,
                    t.blocks.len()
                ));
            }
            if t.shared > 0 && self.prefix.is_none() {
                return Err(format!("req {id}: shared blocks without a prefix index"));
            }
            for b in &t.blocks[..t.shared] {
                *refs.entry(*b).or_insert(0) += 1;
            }
            for b in &t.blocks[t.shared..] {
                if !private.insert(*b) {
                    return Err(format!("block {b} double-owned (req {id})"));
                }
                held_private += 1;
            }
            let cap = t.blocks.len() as u64 * self.block_tokens as u64;
            if t.tokens + t.reserved_tokens > cap {
                return Err(format!(
                    "req {id}: tokens {} + reserved {} exceed capacity {cap}",
                    t.tokens, t.reserved_tokens
                ));
            }
        }
        let mut pool_blocks = 0u64;
        if let Some(pool) = &self.prefix {
            pool.check_invariants(&refs)?;
            pool_blocks = pool.resident();
            for b in &private {
                if pool.contains_block(*b) {
                    return Err(format!("private block {b} also in the prefix index"));
                }
            }
        }
        for b in refs.keys() {
            if private.contains(b) {
                return Err(format!("block {b} owned both shared and private"));
            }
        }
        if held_private + pool_blocks + self.alloc.free() != self.alloc.total() {
            return Err(format!(
                "leak: private {held_private} + prefix {pool_blocks} + free {} != total {}",
                self.alloc.free(),
                self.alloc.total()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_allocates_blocks() {
        let mut kv = KvManager::new(10, 16);
        kv.register(1);
        kv.append(1, 20).unwrap();
        assert_eq!(kv.blocks_of(1), 2);
        assert_eq!(kv.tokens_of(1), 20);
        assert_eq!(kv.free_blocks(), 8);
        kv.append(1, 12).unwrap(); // fits in existing block
        assert_eq!(kv.blocks_of(1), 2);
        kv.append(1, 1).unwrap(); // spills
        assert_eq!(kv.blocks_of(1), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn out_of_blocks_is_atomic() {
        let mut kv = KvManager::new(2, 16);
        kv.register(1);
        kv.append(1, 16).unwrap();
        let err = kv.append(1, 100).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        // failed append must not change state
        assert_eq!(kv.tokens_of(1), 16);
        assert_eq!(kv.free_blocks(), 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_blocks() {
        let mut kv = KvManager::new(8, 16);
        kv.register(1);
        kv.register(2);
        kv.append(1, 64).unwrap();
        kv.append(2, 32).unwrap();
        assert_eq!(kv.free_blocks(), 2);
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), 6);
        assert_eq!(kv.release(1).unwrap_err(), KvError::UnknownRequest(1));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_then_append_consumes_reservation() {
        let mut kv = KvManager::new(8, 16);
        kv.register(1);
        kv.append(1, 10).unwrap();
        // reserve 8 look-ahead tokens: 10+8=18 -> needs 2 blocks total
        kv.reserve(1, 8).unwrap();
        assert_eq!(kv.blocks_of(1), 2);
        let free_before = kv.free_blocks();
        // appending within the reservation must not allocate
        kv.append(1, 6).unwrap();
        assert_eq!(kv.free_blocks(), free_before);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn can_append_counts_reservations_against_headroom() {
        // Regression: headroom used to ignore reserved_tokens, promising
        // capacity the look-ahead slots had already claimed.
        let mut kv = KvManager::new(2, 16);
        kv.register(1);
        kv.append(1, 10).unwrap(); // 1 block, 6 tokens of headroom
        assert!(kv.can_append(1, 6), "headroom genuinely free before reserving");
        kv.reserve(1, 6).unwrap(); // look-ahead claims those 6 slots
        kv.register(2);
        kv.append(2, 16).unwrap(); // allocator now empty
        assert_eq!(kv.free_blocks(), 0);
        assert!(
            !kv.can_append(1, 1),
            "reserved look-ahead slots are not spare headroom"
        );
        kv.release(2).unwrap();
        assert!(kv.can_append(1, 6), "a fresh block restores capacity");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn free_fraction_for_watermark() {
        let mut kv = KvManager::new(100, 16);
        kv.register(1);
        kv.append(1, 16 * 98).unwrap();
        assert!((kv.free_fraction() - 0.02).abs() < 1e-9);
    }

    #[test]
    fn seed_matches_cached_prefix_and_caps_below_full_prompt() {
        let mut kv = KvManager::new(16, 16);
        kv.enable_prefix_cache();
        let keys = [100u64, 200, 300, 400];
        // First request computes everything, finishes, decays 4 blocks.
        kv.register(1);
        kv.append(1, 70).unwrap(); // 64 prompt-block tokens + tail
        kv.finish_release(1, &keys).unwrap();
        assert_eq!(kv.cached_blocks(), 4);
        assert_eq!(kv.free_blocks(), 16, "cached blocks count as free");
        assert_eq!(kv.prefix_resident_tokens(), 64);

        // Identical prompt: seeds the shared prefix, capped below the
        // full prompt so one token is left to prefill.
        kv.register(2);
        let seeded = kv.seed_prefix(2, &keys, 64 - 1);
        assert_eq!(seeded, 48, "cap of 63 tokens admits 3 full blocks");
        assert_eq!(kv.blocks_of(2), 3);
        assert_eq!(kv.tokens_of(2), 48);
        assert_eq!(kv.probe_prefix(&keys), 64);
        kv.check_invariants().unwrap();
        kv.release(2).unwrap(); // decay back
        assert_eq!(kv.cached_blocks(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn allocation_pressure_evicts_cached_blocks_before_failing() {
        let mut kv = KvManager::new(4, 16);
        kv.enable_prefix_cache();
        kv.register(1);
        kv.append(1, 64).unwrap();
        kv.finish_release(1, &[1, 2, 3, 4]).unwrap();
        assert_eq!(kv.cached_blocks(), 4);
        assert_eq!(kv.free_blocks(), 4);
        // A new request needs 3 fresh blocks: LRU eviction makes room.
        kv.register(2);
        kv.append(2, 48).unwrap();
        assert_eq!(kv.prefix_evictions(), 3);
        assert_eq!(kv.cached_blocks(), 1);
        // And true exhaustion still fails atomically.
        let err = kv.append(2, 32).unwrap_err();
        assert_eq!(err, KvError::OutOfBlocks { need: 2, free: 1 });
        kv.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_content_frees_instead_of_double_indexing() {
        let mut kv = KvManager::new(8, 16);
        kv.enable_prefix_cache();
        // Two concurrent requests with identical prompts, neither seeded
        // (the cache was cold when both arrived).
        kv.register(1);
        kv.register(2);
        kv.append(1, 32).unwrap();
        kv.append(2, 32).unwrap();
        kv.finish_release(1, &[7, 8]).unwrap();
        kv.finish_release(2, &[7, 8]).unwrap();
        assert_eq!(kv.cached_blocks(), 2, "second copy freed, not indexed");
        assert_eq!(kv.free_blocks(), 8);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn property_no_leak_under_random_ops() {
        use crate::util::proptest::check;
        check(96, |g| {
            let total = g.u64_range(4, 64);
            let with_prefix = g.bool();
            let mut kv = KvManager::new(total, 16);
            if with_prefix {
                kv.enable_prefix_cache();
            }
            // Requests in the same class share a key chain, so seeded
            // prefixes, duplicate decays and refcount sharing all occur.
            let keys_for = |class: u64| -> Vec<BlockKey> {
                (0..6).map(|i| class * 1000 + 100 + i).collect()
            };
            let mut live: Vec<(RequestId, u64)> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_range(5, 60) {
                match g.u64_range(0, 5) {
                    0 => {
                        let class = g.u64_range(0, 2);
                        kv.register(next_id);
                        // Half the new requests try to seed the cached
                        // prefix of their class (max 5 of the 6 blocks).
                        if g.bool() {
                            kv.seed_prefix(next_id, &keys_for(class), 6 * 16 - 1);
                        }
                        live.push((next_id, class));
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let (id, _) = *g.choose(&live);
                        let _ = kv.append(id, g.u64_range(1, 64));
                    }
                    2 if !live.is_empty() => {
                        let (id, _) = *g.choose(&live);
                        let _ = kv.reserve(id, g.u64_range(1, 32));
                    }
                    3 if !live.is_empty() => {
                        // Preemption-style release: progress discarded,
                        // shared blocks decay.
                        let idx = g.usize_range(0, live.len() - 1);
                        let (id, _) = live.swap_remove(idx);
                        kv.release(id).map_err(|e| e.to_string())?;
                    }
                    4 | 5 if !live.is_empty() => {
                        // Finish: full prompt blocks decay into the pool.
                        let idx = g.usize_range(0, live.len() - 1);
                        let (id, class) = live.swap_remove(idx);
                        kv.finish_release(id, &keys_for(class))
                            .map_err(|e| e.to_string())?;
                    }
                    _ => {}
                }
                kv.check_invariants()?;
            }
            // Draining every request must leave zero private holdings.
            for (id, _) in live {
                kv.release(id).map_err(|e| e.to_string())?;
            }
            kv.check_invariants()?;
            if kv.free_blocks() != kv.total_blocks() {
                return Err(format!(
                    "drained manager not fully free: {} of {}",
                    kv.free_blocks(),
                    kv.total_blocks()
                ));
            }
            Ok(())
        });
    }
}
