//! Free-list block allocator underlying the paged KV cache.

use super::BlockId;

/// LIFO free-list allocator over `total` physical blocks. Atomic
/// multi-block allocation: either all requested blocks are returned or
/// none (the scheduler relies on that for admission decisions).
#[derive(Debug)]
pub struct BlockAllocator {
    free_list: Vec<BlockId>,
    total: u64,
}

impl BlockAllocator {
    pub fn new(total: u64) -> BlockAllocator {
        assert!(total <= u32::MAX as u64, "block id space");
        // LIFO order: recently-freed blocks are reused first (cache-warm
        // on real hardware; here it keeps ids dense for debuggability).
        BlockAllocator {
            free_list: (0..total as u32).rev().collect(),
            total,
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn free(&self) -> u64 {
        self.free_list.len() as u64
    }

    /// Allocate exactly `n` blocks, or Err(free_count) without side
    /// effects.
    pub fn allocate(&mut self, n: u64) -> Result<Vec<BlockId>, u64> {
        let mut out = Vec::new();
        self.allocate_into(n, &mut out)?;
        Ok(out)
    }

    /// Allocate exactly `n` blocks by appending them to `out` (the hot
    /// path: block tables grow in place without an intermediate Vec per
    /// append). Err(free_count) without side effects when blocks run out.
    pub fn allocate_into(&mut self, n: u64, out: &mut Vec<BlockId>) -> Result<(), u64> {
        if n > self.free_list.len() as u64 {
            return Err(self.free_list.len() as u64);
        }
        let at = self.free_list.len() - n as usize;
        out.extend_from_slice(&self.free_list[at..]);
        self.free_list.truncate(at);
        Ok(())
    }

    /// Return blocks to the pool. Double-free is a bug upstream and
    /// panics (debug builds check membership).
    pub fn release(&mut self, blocks: &[BlockId]) {
        debug_assert!(
            blocks.iter().all(|b| !self.free_list.contains(b)),
            "double free"
        );
        self.free_list.extend_from_slice(blocks);
        assert!(
            self.free_list.len() as u64 <= self.total,
            "released more blocks than exist"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut a = BlockAllocator::new(10);
        let b1 = a.allocate(4).unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(a.free(), 6);
        a.release(&b1);
        assert_eq!(a.free(), 10);
    }

    #[test]
    fn allocate_into_appends_without_intermediate_vec() {
        let mut a = BlockAllocator::new(10);
        let mut table = a.allocate(2).unwrap();
        a.allocate_into(3, &mut table).unwrap();
        assert_eq!(table.len(), 5);
        assert_eq!(a.free(), 5);
        // Failure leaves both the pool and the output untouched.
        assert_eq!(a.allocate_into(6, &mut table), Err(5));
        assert_eq!(table.len(), 5);
        assert_eq!(a.free(), 5);
        a.release(&table);
        assert_eq!(a.free(), 10);
    }

    #[test]
    fn failed_allocation_has_no_side_effects() {
        let mut a = BlockAllocator::new(3);
        let _held = a.allocate(2).unwrap();
        assert_eq!(a.allocate(2), Err(1));
        assert_eq!(a.free(), 1);
    }

    #[test]
    fn unique_ids() {
        let mut a = BlockAllocator::new(100);
        let mut all: Vec<BlockId> = Vec::new();
        for _ in 0..10 {
            all.extend(a.allocate(10).unwrap());
        }
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 100);
        assert_eq!(a.allocate(1), Err(0));
    }

    #[test]
    #[cfg(debug_assertions)] // the membership check is a debug_assert
    #[should_panic(expected = "double free")]
    fn double_free_panics_in_debug() {
        let mut a = BlockAllocator::new(4);
        let b = a.allocate(1).unwrap();
        a.release(&b);
        a.release(&b);
    }
}
