//! Plain-text table formatting for bench outputs and reports.
//!
//! Every bench target prints the same rows/series the paper reports; this
//! module keeps their output aligned and diff-friendly.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with column alignment: first column left, others right.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = widths[i]));
                } else {
                    line.push_str(&format!("{:>width$}", c, width = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with the given number of decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format milliseconds from seconds.
pub fn ms(seconds: f64) -> String {
    format!("{:.1}", seconds * 1e3)
}

/// Section banner used by bench binaries.
pub fn banner(title: &str) {
    let bar = "=".repeat(title.len().max(8) + 8);
    println!("\n{bar}\n=== {title} ===\n{bar}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1.0"]);
        t.row(vec!["long-name", "22.5"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // all data lines same width
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[0].starts_with("name"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(ms(0.1234), "123.4");
    }
}
