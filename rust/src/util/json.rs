//! Minimal JSON parser + serializer.
//!
//! The offline vendor set has no `serde`, so the HTTP transport
//! ([`crate::server::http`]) carries its wire format through this
//! in-crate module instead. It covers exactly the value subset the
//! OpenAI-compatible API needs — null, bool, f64 numbers, strings,
//! arrays and (insertion-ordered) objects — with strict-enough parsing
//! that malformed client input maps cleanly to HTTP 400.
//!
//! Guarantees the transport and its tests rely on:
//!
//! - `parse(v.dump()) == v` for every value this module can produce
//!   (property-tested round trip; finite f64s round-trip exactly because
//!   Rust's `{}` float formatting emits the shortest re-parseable form).
//! - Parse errors carry the byte offset so 400 responses can say *where*
//!   the body went wrong.
//! - Nesting depth is capped ([`MAX_DEPTH`]) so adversarial bodies like
//!   `[[[[…` cannot blow the stack.
//!
//! Non-goals (documented deviations from full JSON): `NaN`/`Inf` numbers
//! serialize as `null`; duplicate object keys are kept (first match wins
//! on [`Json::get`]); numbers beyond f64 range parse to infinity.

use std::fmt;

/// Maximum nesting depth accepted by the parser.
pub const MAX_DEPTH: u32 = 128;

/// A JSON value. Object fields keep insertion order (no map type in the
/// subset — linear scans are fine at API-request sizes).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number, when it is an exact non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// The number, when it is an exact integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n)
                if n.fract() == 0.0 && *n >= i64::MIN as f64 && *n <= i64::MAX as f64 =>
            {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object builder: `Json::obj(vec![("k", Json::Num(1.0))])`.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array builder.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// String builder.
    pub fn string(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize to compact JSON text.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Inf literal; degrade to null.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    // Exact integers print without a trailing ".0" so
                    // counters look like counters on the wire.
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    // `{}` emits the shortest string that re-parses to
                    // the same f64 — the round-trip property depends on
                    // this.
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// A parse failure, with the byte offset where it was detected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} at byte {}", self.msg, self.at)
    }
}

impl std::error::Error for JsonError {}

/// Parse one JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            at: self.i,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected `{}`", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn value(&mut self, depth: u32) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err(format!("nesting deeper than {MAX_DEPTH}")));
        }
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character `{}`", c as char))),
        }
    }

    fn array(&mut self, depth: u32) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self, depth: u32) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(self.err("expected string object key"));
            }
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let c = self.unicode_escape()?;
                            out.push(c);
                            continue;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => {
                    return Err(self.err("raw control character in string"));
                }
                Some(c) => {
                    // Copy one UTF-8 scalar. The input came in as &str,
                    // so lead bytes and widths are always consistent.
                    let width = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = self.i + width;
                    let chunk = self
                        .b
                        .get(self.i..end)
                        .ok_or_else(|| self.err("truncated UTF-8 sequence"))?;
                    let s = std::str::from_utf8(chunk)
                        .map_err(|_| self.err("invalid UTF-8 in string"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    /// Parse the 4 hex digits after `\u` (the `u` is already consumed),
    /// combining UTF-16 surrogate pairs. Leaves `self.i` one past the
    /// last consumed digit.
    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // High surrogate: require a `\uXXXX` low surrogate.
            if self.peek() == Some(b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                self.i += 2;
                let lo = self.hex4()?;
                if !(0xDC00..0xE000).contains(&lo) {
                    return Err(self.err("invalid low surrogate"));
                }
                let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(c).ok_or_else(|| self.err("invalid surrogate pair"));
            }
            return Err(self.err("lone high surrogate"));
        }
        if (0xDC00..0xE000).contains(&hi) {
            return Err(self.err("lone low surrogate"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("invalid \\u escape"))
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("non-hex digit in \\u escape"))?;
            v = (v << 4) | d;
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        // Integer part: `0` or [1-9][0-9]* (JSON forbids leading zeros).
        match self.peek() {
            Some(b'0') => self.i += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.i += 1;
                }
            }
            _ => return Err(self.err("expected digit")),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("expected digit after `.`"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("expected digit in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number `{text}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_containers() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(
            v.get("a").and_then(|a| a.as_array()).map(|a| a.len()),
            Some(3)
        );
        assert_eq!(v.get("c").and_then(|c| c.as_str()), Some("x"));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\"b\\c\n\t\u0041\u00e9""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\n\tA\u{e9}"));
        // Surrogate pair: 😀
        assert_eq!(
            parse(r#""\ud83d\ude00""#).unwrap().as_str(),
            Some("\u{1F600}")
        );
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\":}",
            "{\"a\" 1}",
            "{a:1}",
            "\"unterminated",
            "\"bad\\q\"",
            "\"\\ud83d\"",
            "01",
            "1.",
            "1e",
            "+1",
            "nul",
            "[1] trailing",
            "\"raw\ncontrol\"",
        ] {
            assert!(parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn rejects_depth_bombs() {
        let deep = "[".repeat(4096) + &"]".repeat(4096);
        let e = parse(&deep).unwrap_err();
        assert!(e.msg.contains("nesting"), "{e}");
    }

    #[test]
    fn errors_carry_offsets() {
        let e = parse("[1, )").unwrap_err();
        assert_eq!(e.at, 4);
    }

    #[test]
    fn dump_escapes_and_formats() {
        let v = Json::obj(vec![
            ("s", Json::string("a\"b\\c\n\u{1}")),
            ("i", Json::Num(3.0)),
            ("f", Json::Num(0.25)),
            ("n", Json::Null),
            ("b", Json::Bool(true)),
            ("a", Json::arr(vec![Json::Num(-2.0)])),
        ]);
        assert_eq!(
            v.dump(),
            r#"{"s":"a\"b\\c\n\u0001","i":3,"f":0.25,"n":null,"b":true,"a":[-2]}"#
        );
        // Non-finite numbers degrade to null rather than emit invalid JSON.
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }

    #[test]
    fn integer_accessors_validate() {
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(parse("-7").unwrap().as_u64(), None);
        assert_eq!(parse("-7").unwrap().as_i64(), Some(-7));
        assert_eq!(parse("7.5").unwrap().as_u64(), None);
        assert_eq!(parse("\"7\"").unwrap().as_u64(), None);
    }

    fn gen_json(g: &mut Gen, depth: u32) -> Json {
        let kind = if depth >= 3 {
            g.usize_range(0, 3) // leaves only
        } else {
            g.usize_range(0, 5)
        };
        match kind {
            0 => Json::Null,
            1 => Json::Bool(g.bool(0.5)),
            2 => {
                if g.bool(0.5) {
                    Json::Num(g.u64_range(0, 1_000_000) as f64 - 500_000.0)
                } else {
                    Json::Num(g.f64_range(-1e9, 1e9))
                }
            }
            3 => {
                let charset: Vec<char> =
                    "ab\"\\\n\t\u{1}\u{e9}\u{1F600} {}[]:,".chars().collect();
                let chars = g.vec(0..=12, |g| *g.choose(&charset));
                Json::Str(chars.into_iter().collect())
            }
            4 => Json::Arr(g.vec(0..=4, |g| gen_json(g, depth + 1))),
            _ => {
                let keys = ['k', 'x', '"', '\\', 'é'];
                let fields = g.vec(0..=4, |g| {
                    let key: String = g.vec(0..=6, |g| *g.choose(&keys)).into_iter().collect();
                    (key, gen_json(g, depth + 1))
                });
                Json::Obj(fields)
            }
        }
    }

    /// The transport guarantee: every value this module can produce
    /// survives a dump → parse round trip bit-exactly.
    #[test]
    fn dump_parse_round_trip_property() {
        check(256, |g| {
            let v = gen_json(g, 0);
            let text = v.dump();
            let back = parse(&text).map_err(|e| format!("`{text}` failed to re-parse: {e}"))?;
            if back != v {
                return Err(format!("round trip changed value: {v:?} -> {back:?}"));
            }
            Ok(())
        });
    }
}
