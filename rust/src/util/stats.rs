//! Descriptive statistics used by the metrics recorder and bench harness.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (0..=100) by linear interpolation on a *sorted copy*.
/// 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(f64::total_cmp);
    percentile_sorted(&s, p)
}

/// Percentile on an already-sorted slice.
pub fn percentile_sorted(s: &[f64], p: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Summary of a sample: n, mean, std, min, p50, p90, p99, max.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut s = xs.to_vec();
        s.sort_by(f64::total_cmp);
        Summary {
            n: s.len(),
            mean: mean(&s),
            std: std_dev(&s),
            min: s[0],
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
            max: s[s.len() - 1],
        }
    }
}

/// Ordinary least squares fit y = a + b*x, returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    if den == 0.0 {
        return (my, 0.0);
    }
    let b = num / den;
    (my - b * mx, b)
}

/// Mean absolute percentage error between predictions and observations.
pub fn mape(pred: &[f64], obs: &[f64]) -> f64 {
    assert_eq!(pred.len(), obs.len());
    if pred.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for (p, o) in pred.iter().zip(obs) {
        if *o != 0.0 {
            acc += ((p - o) / o).abs();
        }
    }
    acc / pred.len() as f64 * 100.0
}

/// Streaming histogram with fixed-width buckets, used by the GPU
/// utilization tracker.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub width: f64,
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Histogram {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            width: (hi - lo) / nbuckets as f64,
            buckets: vec![0; nbuckets],
            underflow: 0,
            overflow: 0,
            count: 0,
            sum: 0.0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.lo {
            self.underflow += 1;
        } else {
            let idx = ((x - self.lo) / self.width) as usize;
            if idx >= self.buckets.len() {
                self.overflow += 1;
            } else {
                self.buckets[idx] += 1;
            }
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p99 - 99.01).abs() < 0.01);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mape_basic() {
        let pred = [110.0, 90.0];
        let obs = [100.0, 100.0];
        assert!((mape(&pred, &obs) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.count, 12);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert!(h.buckets.iter().all(|&b| b == 1));
    }
}
