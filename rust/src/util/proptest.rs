//! Minimal property-based testing framework.
//!
//! The offline vendor set has no `proptest`, so the coordinator invariants
//! (routing, batching, KV-cache state) are checked with this in-tree
//! mini-framework: seeded generators + a fixed number of random cases +
//! a greedy input-minimization pass on failure.
//!
//! Usage:
//! ```ignore
//! check(256, |g| {
//!     let budget = g.usize_range(1, 8192);
//!     let lens = g.vec(1..=64, |g| g.usize_range(1, 10_000));
//!     // ... exercise the system, return Err(msg) on violation
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Trace of raw draws so a failing case can be reported reproducibly.
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            case_seed: seed,
        }
    }

    pub fn u64_range(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.int_range(lo, hi)
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.int_range(lo as u64, hi as u64) as usize
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector with length drawn from `len` and elements from `elem`.
    pub fn vec<T>(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        mut elem: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize_range(*len.start(), *len.end());
        (0..n).map(|_| elem(self)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `cases` random cases of the property. Panics with the failing seed
/// on the first violation so the case can be replayed with `replay`.
pub fn check(cases: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    // Base seed is fixed: tests must be deterministic in CI.
    let base = 0xD0E7_5EED;
    for i in 0..cases {
        let seed = base ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property failed (case {i}, seed {seed:#x}): {msg}\nreplay with util::proptest::replay({seed:#x}, prop)");
        }
    }
}

/// Replay one specific failing case.
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("replayed failure (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check(64, |g| {
            ran += 1;
            let a = g.u64_range(0, 100);
            let b = g.u64_range(0, 100);
            if a + b >= a {
                Ok(())
            } else {
                Err("overflow".into())
            }
        });
        assert_eq!(ran, 64);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(64, |g| {
            let v = g.usize_range(0, 10);
            if v < 10 {
                Ok(())
            } else {
                Err(format!("hit {v}"))
            }
        });
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut g1 = Gen::new(99);
        let mut g2 = Gen::new(99);
        for _ in 0..50 {
            assert_eq!(g1.u64_range(0, 1000), g2.u64_range(0, 1000));
        }
    }

    #[test]
    fn vec_respects_length_bounds() {
        let mut g = Gen::new(5);
        for _ in 0..100 {
            let v = g.vec(2..=7, |g| g.u64_range(0, 1));
            assert!((2..=7).contains(&v.len()));
        }
    }
}
