//! Deterministic PRNG + distribution samplers.
//!
//! The offline build has no `rand` crate, so this module provides a
//! xoshiro256++ generator (Blackman & Vigna) plus the distributions the
//! serving workloads need: uniform, exponential (Poisson inter-arrivals),
//! Poisson counts, normal / lognormal (trace length distributions), and
//! Zipf (prefix-sharing skew). Everything is seeded and reproducible —
//! benches and tests rely on that.

/// xoshiro256++ PRNG. Fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn int_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "int_range: lo > hi");
        let span = hi - lo + 1;
        // Lemire's multiply-shift rejection method.
        if span == 0 {
            return self.next_u64(); // full range
        }
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Inter-arrival times
    /// of a Poisson process with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // Avoid ln(0).
        let u = 1.0 - self.f64();
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    /// Knuth's method for small lambda, normal approximation for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal(lambda, lambda.sqrt());
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn standard_normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.standard_normal()
    }

    /// Lognormal parameterized by the *target* mean and coefficient of
    /// variation of the resulting distribution (easier to calibrate traces
    /// against published mean lengths than (mu, sigma)).
    pub fn lognormal_mean_cv(&mut self, mean: f64, cv: f64) -> f64 {
        assert!(mean > 0.0 && cv > 0.0);
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        (mu + sigma2.sqrt() * self.standard_normal()).exp()
    }

    /// Zipf-distributed rank in [1, n] with exponent `s` (rejection-free
    /// inverse-CDF over precomputed weights is overkill; use the classic
    /// rejection sampler).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1);
        // Rejection sampling (Devroye). Good enough for workload skew.
        let t = if (s - 1.0).abs() < 1e-12 {
            1.0 + (n as f64).ln()
        } else {
            ((n as f64).powf(1.0 - s) - s) / (1.0 - s)
        };
        loop {
            let u = self.f64() * t;
            let x = if (s - 1.0).abs() < 1e-12 {
                u.exp()
            } else {
                (u * (1.0 - s) + s).powf(1.0 / (1.0 - s))
            };
            let k = x.floor().max(1.0).min(n as f64) as u64;
            let ratio = (k as f64).powf(-s) / x.powf(-s).min(1.0);
            if self.f64() <= ratio {
                return k;
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.int_range(0, i as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.int_range(0, xs.len() as u64 - 1) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn int_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.int_range(5, 14);
            assert!((5..=14).contains(&v));
            seen[(v - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should appear");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let lambda = 4.0;
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exponential(lambda)).sum();
        let mean = sum / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 3.0, 80.0] {
            let n = 50_000;
            let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() / lambda < 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn lognormal_calibration() {
        let mut r = Rng::new(19);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal_mean_cv(2047.0, 1.2)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!(
            (mean - 2047.0).abs() / 2047.0 < 0.03,
            "lognormal mean {mean} should match target 2047"
        );
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(23);
        let n = 20_000;
        let mut ones = 0;
        for _ in 0..n {
            let k = r.zipf(100, 1.1);
            assert!((1..=100).contains(&k));
            if k == 1 {
                ones += 1;
            }
        }
        // rank 1 should dominate under zipf(1.1)
        assert!(ones > n / 20, "ones={ones}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
