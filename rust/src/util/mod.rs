//! In-tree infrastructure substrates (the offline build has no rand /
//! criterion / proptest / serde — see DESIGN.md "Dependency reality").

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tablefmt;
