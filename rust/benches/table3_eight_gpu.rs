//! Table 3 (Appendix B) — eight-GPU comparison: Qwen3-32B on Azure-Conv
//! at QPS 24; DuetServe with TP=8 vs Dynamo starting at 4P+4D with its
//! planner allowed to reconfigure at runtime (role switch preempts
//! in-flight decodes and costs ~40 s of downtime).
//!
//! Paper shape: DuetServe ~1.4x Dynamo's request throughput, lower TTFT,
//! higher average GPU utilization (93.5% vs 74.6%); Dynamo's TBT is
//! lower (underutilized decode workers).
//!
//!     cargo bench --bench table3_eight_gpu

use duetserve::config::{ModelSpec, Policy, ServingConfig};
use duetserve::engine::{engine_for, DisaggEngine};
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::traces::{generate, TraceKind};

fn main() {
    banner("Table 3: 8x H100, Qwen3-32B, Azure-Conv @ QPS 24");
    let quick = std::env::var("DUET_BENCH_QUICK").is_ok();
    let n = if quick { 200 } else { 500 };
    let qps = 24.0;
    let w = generate(TraceKind::AzureConv, Some(n), qps, 0x8690);

    let mut t = Table::new(vec![
        "system",
        "thpt(req/s)",
        "ttft(s)",
        "tbt(ms)",
        "avg-gpu-util",
        "reconfigs",
    ]);

    // Dynamo: 4P+4D with runtime reconfiguration enabled.
    let mut dcfg = ServingConfig::default_8b().with_model(ModelSpec::qwen3_32b(), 1);
    dcfg.policy = Policy::DisaggPD {
        prefill_gpus: 4,
        decode_gpus: 4,
    };
    let mut dynamo = DisaggEngine::new(dcfg, 4, 4, 1);
    dynamo.reconfigurable = true;
    let rd = dynamo.run(w.clone());
    let d_util = rd.busy_frac / dynamo.n_workers() as f64;
    t.row(vec![
        rd.system.clone(),
        format!("{:.2}", rd.throughput_rps),
        format!("{:.1}", rd.ttft.mean),
        format!("{:.1}", rd.tbt.mean * 1e3),
        format!("{:.1}%", d_util * 100.0),
        format!("{}", dynamo.reconfigs),
    ]);

    // DuetServe: one TP=8 group over all eight GPUs.
    let duet_cfg = ServingConfig::default_8b()
        .with_model(ModelSpec::qwen3_32b(), 8)
        .with_policy(Policy::Duet);
    let mut duet = engine_for(duet_cfg, 1);
    let ru = duet.run(w);
    t.row(vec![
        "DuetServe-TP8".to_string(),
        format!("{:.2}", ru.throughput_rps),
        format!("{:.1}", ru.ttft.mean),
        format!("{:.1}", ru.tbt.mean * 1e3),
        format!("{:.1}%", ru.busy_frac * 100.0),
        "0".to_string(),
    ]);
    t.print();
    println!(
        "\n(paper: Duet 8.02 vs Dynamo 5.69 req/s (1.4x), TTFT 58.9 vs 110.2 s,\n\
         util 93.5% vs 74.6%; Dynamo TBT lower because decode workers idle)"
    );
}
