//! CI perf-trajectory snapshot: one small fig2-style throughput/latency
//! row, one hot-path engine number, and the O(1)-scrape demonstration —
//! `/metrics`-style recorder snapshots timed before and after 100k
//! synthetic samples, in exact (per-sample history) vs streaming
//! (aggregates + quantile sketch) mode. Emits `BENCH_ci.json` for the CI
//! workflow to upload as an artifact, so the perf trajectory is tracked
//! per PR.
//!
//!     cargo bench --bench bench_ci

use std::time::Instant;

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{
    engine_for, router_by_name, ClusterEngine, ReplicatedEngine, RoundRobinRouter,
    ServingTopology, TopologyStep,
};
use duetserve::metrics::{Recorder, RecorderMode};
use duetserve::request::Request;
use duetserve::util::json::Json;
use duetserve::util::tablefmt::banner;
use duetserve::workload::sessions::shared_prefix_workload;
use duetserve::workload::synthetic::fixed_workload;

/// Mean µs per call of `f` over `iters` runs (after `warmup`).
fn time_us<T>(warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let t = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    t.elapsed().as_secs_f64() / iters as f64 * 1e6
}

/// A recorder loaded with `n` synthetic finished requests (3 tokens ⇒
/// 2 tbt gaps each, plus ttft/e2e samples).
fn loaded_recorder(mode: RecorderMode, n: u64) -> Recorder {
    let mut rec = Recorder::with_mode(mode);
    for i in 0..n {
        let mut r = Request::new(i, 0.0, 64, 3);
        r.advance_prefill(64);
        let base = 0.05 + (i % 1000) as f64 * 1e-4;
        r.advance_decode(base);
        r.advance_decode(base + 0.02 + (i % 97) as f64 * 1e-4);
        r.advance_decode(base + 0.05 + (i % 53) as f64 * 1e-4);
        rec.record_finished(&r);
    }
    rec.duration = n as f64 * 0.1;
    rec
}

/// The live `/metrics` path per scrape: non-destructive snapshot (clone)
/// + report build.
fn scrape_us(rec: &Recorder) -> f64 {
    time_us(3, 30, || {
        let snap = rec.clone();
        snap.report("scrape")
    })
}

/// Cluster event-loop throughput at fleet size `n`: inject a synthetic
/// workload into an N-replica cluster and drive `step_next` to
/// `Exhausted`, returning (steps/s, total steps). `naive` pins the
/// retained O(N)-scan reference path; the default is the heap-driven
/// event queue + incremental load board. The trajectory is identical
/// either way (property-tested in `tests/fleet_hotpath.rs`), so the two
/// runs do the same number of steps and the ratio isolates coordinator
/// cost.
fn fleet_steps_per_s(n: u32, naive: bool) -> (f64, u64) {
    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let mut cluster =
        ClusterEngine::replicated(cfg, n, 0xF1EE7, Box::new(RoundRobinRouter::new()));
    cluster.set_naive_scan(naive);
    let requests = 2 * n as usize;
    let w = fixed_workload(requests, 512, 8, n as f64 * 8.0, 0xC1);
    for r in w.sorted_by_arrival().requests {
        cluster.inject(r);
    }
    let t = Instant::now();
    let mut steps = 0u64;
    loop {
        match cluster.step_next(None) {
            TopologyStep::Exhausted | TopologyStep::Diverged(_) => break,
            _ => steps += 1,
        }
    }
    let secs = t.elapsed().as_secs_f64();
    let rep = ServingTopology::fold_report(&mut cluster);
    assert_eq!(
        rep.completed, requests as u64,
        "fleet bench (n={n}, naive={naive}) did not complete its workload"
    );
    (steps as f64 / secs, steps)
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// One prefix-cache hit-rate sweep point: 48 shared-prefix requests
/// (constant 2048-token prompts split `shared`+`unique`) over a 2-worker
/// replicated cluster with the prefix cache on, routed by `router`.
/// Returns the JSON row plus the TTFT p50 and computed-prefill-token
/// figures the guardrails compare across hit rates.
fn prefix_sweep_point(shared: u64, unique: u64, router: &str) -> (Json, f64, u64) {
    let cfg = ServingConfig::default_8b()
        .with_policy(Policy::VllmChunked)
        .with_prefix_cache(true);
    // Low qps + short outputs: turns finish before the next same-tenant
    // arrival, so decayed blocks are actually there to hit.
    let w = shared_prefix_workload(48, shared, unique, 16, 2.0, 2, 0xCA_FE);
    let mut e = ReplicatedEngine::new(cfg, 2, 7)
        .with_router(router_by_name(router).expect("known router"));
    let rep = e.run(w);
    assert_eq!(
        rep.completed, 48,
        "prefix sweep ({shared}+{unique}, {router}) did not complete"
    );
    let mut ttfts: Vec<f64> = e.finished.iter().filter_map(|r| r.ttft()).collect();
    ttfts.sort_by(f64::total_cmp);
    let p50 = percentile(&ttfts, 0.50);
    let p99 = percentile(&ttfts, 0.99);
    let row = Json::obj(vec![
        ("hit_rate", Json::Num(shared as f64 / (shared + unique) as f64)),
        ("router", Json::string(router)),
        ("ttft_p50_s", Json::Num(p50)),
        ("ttft_p99_s", Json::Num(p99)),
        ("token_throughput", Json::Num(rep.token_throughput)),
        ("prefix_hits", Json::Num(rep.prefix_hits as f64)),
        ("prefix_cached_tokens", Json::Num(rep.prefix_cached_tokens as f64)),
        ("prefilled_tokens", Json::Num(rep.prefilled_tokens as f64)),
    ]);
    (row, p50, rep.prefilled_tokens)
}

fn main() {
    banner("CI bench: throughput row + scrape-cost demonstration");

    // Fig2-style row (small: one qps point, CI budget).
    let qps = 6.0;
    let w = fixed_workload(60, 8000, 200, qps, 0xF16_2);
    let mut agg = ReplicatedEngine::new(
        ServingConfig::default_8b().with_policy(Policy::VllmChunked),
        2,
        1,
    );
    let ra = agg.run(w);
    let mut duet = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 1);
    let t0 = Instant::now();
    let rd = duet.run(fixed_workload(60, 4096, 64, 8.0, 5));
    let duet_wall = t0.elapsed().as_secs_f64();

    // Scrape latency before/after N = 100k synthetic samples, both
    // recorder modes. Streaming must stay flat (O(1) in samples); exact
    // grows with history — the contrast the acceptance criterion asks
    // CI to demonstrate.
    let n_small = 1_000u64;
    let n_large = 100_000u64;
    let stream_small = scrape_us(&loaded_recorder(RecorderMode::Streaming, n_small));
    let stream_large = scrape_us(&loaded_recorder(RecorderMode::Streaming, n_large));
    let exact_small = scrape_us(&loaded_recorder(RecorderMode::Exact, n_small));
    let exact_large = scrape_us(&loaded_recorder(RecorderMode::Exact, n_large));
    let stream_ratio = stream_large / stream_small.max(1e-9);
    let exact_ratio = exact_large / exact_small.max(1e-9);

    // Fleet-scale cluster event loop: steps/s at N=8 and N=256 replicas,
    // heap-driven event queue vs the retained naive O(N)-scan reference
    // on the byte-identical trajectory.
    let (heap_n8, steps_n8) = fleet_steps_per_s(8, false);
    let (naive_n8, steps_n8_naive) = fleet_steps_per_s(8, true);
    let (heap_n256, steps_n256) = fleet_steps_per_s(256, false);
    let (naive_n256, steps_n256_naive) = fleet_steps_per_s(256, true);
    assert_eq!(
        steps_n8, steps_n8_naive,
        "heap and naive paths diverged at N=8"
    );
    assert_eq!(
        steps_n256, steps_n256_naive,
        "heap and naive paths diverged at N=256"
    );
    let fleet_speedup_n8 = heap_n8 / naive_n8.max(1e-9);
    let fleet_speedup_n256 = heap_n256 / naive_n256.max(1e-9);

    // Prefix-cache hit-rate sweep: TTFT/throughput at hit rates ~0, ~0.5
    // and ~0.9 (block-aligned shared/unique splits of a constant
    // 2048-token prompt), cache-aware kv-overlap routing vs round-robin.
    let mut sweep_rows = Vec::new();
    let mut overlap_points = Vec::new(); // (shared, ttft_p50, prefilled) per hit rate
    for &(shared, unique) in &[(0u64, 2048u64), (1024, 1024), (1840, 208)] {
        for router in ["kv-overlap", "round-robin"] {
            let (row, p50, prefilled) = prefix_sweep_point(shared, unique, router);
            if router == "kv-overlap" {
                overlap_points.push((shared, p50, prefilled));
            }
            sweep_rows.push(row);
        }
    }

    println!(
        "agg 2x vLLM @qps {qps}: {:.0} tok/s, tbt-p99 {:.1} ms | duet: {:.0} it/s, {:.1} µs sched",
        ra.token_throughput,
        ra.tbt_p99 * 1e3,
        rd.iterations as f64 / duet_wall,
        rd.sched_overhead_per_iter * 1e6,
    );
    println!(
        "scrape µs @1k/@100k samples — streaming: {stream_small:.1}/{stream_large:.1} \
         (x{stream_ratio:.2}), exact: {exact_small:.1}/{exact_large:.1} (x{exact_ratio:.2})"
    );
    println!(
        "fleet steps/s — N=8: heap {heap_n8:.0} vs naive {naive_n8:.0} \
         (x{fleet_speedup_n8:.1}), N=256: heap {heap_n256:.0} vs naive {naive_n256:.0} \
         (x{fleet_speedup_n256:.1}, {steps_n256} steps)"
    );
    println!(
        "prefix sweep (kv-overlap) ttft p50: {:.1} ms @hit 0 -> {:.1} ms @hit 0.9; \
         prefilled tokens {} -> {}",
        overlap_points[0].1 * 1e3,
        overlap_points[2].1 * 1e3,
        overlap_points[0].2,
        overlap_points[2].2,
    );

    let out = Json::obj(vec![
        (
            "fig2_point",
            Json::obj(vec![
                ("qps", Json::Num(qps)),
                ("agg_token_throughput", Json::Num(ra.token_throughput)),
                ("agg_tbt_p99_ms", Json::Num(ra.tbt_p99 * 1e3)),
                ("agg_ttft_mean_s", Json::Num(ra.ttft.mean)),
                ("agg_completed", Json::Num(ra.completed as f64)),
            ]),
        ),
        (
            "hotpath",
            Json::obj(vec![
                (
                    "duet_iters_per_s",
                    Json::Num(rd.iterations as f64 / duet_wall),
                ),
                (
                    "duet_sched_overhead_us_per_iter",
                    Json::Num(rd.sched_overhead_per_iter * 1e6),
                ),
                ("duet_tbt_p99_ms", Json::Num(rd.tbt_p99 * 1e3)),
            ]),
        ),
        (
            "fleet",
            Json::obj(vec![
                ("n_small", Json::Num(8.0)),
                ("n_large", Json::Num(256.0)),
                ("heap_steps_per_s_n8", Json::Num(heap_n8)),
                ("naive_steps_per_s_n8", Json::Num(naive_n8)),
                ("heap_steps_per_s_n256", Json::Num(heap_n256)),
                ("naive_steps_per_s_n256", Json::Num(naive_n256)),
                ("speedup_n8", Json::Num(fleet_speedup_n8)),
                ("speedup_n256", Json::Num(fleet_speedup_n256)),
                ("steps_n256", Json::Num(steps_n256 as f64)),
            ]),
        ),
        (
            "prefix_sweep",
            Json::obj(vec![("rows", Json::arr(sweep_rows))]),
        ),
        (
            "scrape_latency",
            Json::obj(vec![
                ("n_small", Json::Num(n_small as f64)),
                ("n_large", Json::Num(n_large as f64)),
                ("streaming_us_small", Json::Num(stream_small)),
                ("streaming_us_large", Json::Num(stream_large)),
                ("streaming_ratio", Json::Num(stream_ratio)),
                ("exact_us_small", Json::Num(exact_small)),
                ("exact_us_large", Json::Num(exact_large)),
                ("exact_ratio", Json::Num(exact_ratio)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_ci.json", out.dump()).expect("write BENCH_ci.json");
    println!("wrote BENCH_ci.json");

    // Guardrail, not a flaky threshold: a streaming scrape after 100k
    // samples must not cost 100× a 1k-sample scrape (it is O(sketch),
    // not O(samples)); generous bound so CI noise cannot trip it.
    assert!(
        stream_ratio < 20.0,
        "streaming scrape cost grew with samples: x{stream_ratio:.1}"
    );

    // Guardrail for the fleet hot path: at N=256 the heap-driven event
    // queue must beat the retained O(N)-scan reference by ≥5× on the
    // identical trajectory. The measured gap is far larger (the naive
    // path pays several O(N) fleet scans plus three Vec allocations per
    // event), so CI noise cannot trip this.
    assert!(
        fleet_speedup_n256 >= 5.0,
        "N=256 fleet event loop only x{fleet_speedup_n256:.1} over naive scan (need >= 5)"
    );

    // Prefix-cache guardrails (engine-clock metrics, so CI wall-clock
    // noise cannot touch them): with 90% of every prompt cacheable and
    // kv-overlap routing, TTFT p50 must strictly improve over the
    // disjoint-prompt baseline, and the prefill volume actually computed
    // must drop by at least the cached-prefix fraction (here: to ≤25%,
    // leaving generous room for the per-tenant cold misses).
    let (_, p50_cold, prefilled_cold) = overlap_points[0];
    let (_, p50_hot, prefilled_hot) = overlap_points[2];
    assert!(
        p50_hot < p50_cold,
        "hit-rate 0.9 ttft p50 {p50_hot:.4}s must beat hit-rate 0 {p50_cold:.4}s"
    );
    assert!(
        prefilled_hot * 4 <= prefilled_cold,
        "prefill volume must drop with the cached fraction: {prefilled_hot} vs {prefilled_cold}"
    );
}
