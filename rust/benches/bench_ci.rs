//! CI perf-trajectory snapshot: one small fig2-style throughput/latency
//! row, one hot-path engine number, and the O(1)-scrape demonstration —
//! `/metrics`-style recorder snapshots timed before and after 100k
//! synthetic samples, in exact (per-sample history) vs streaming
//! (aggregates + quantile sketch) mode. Emits `BENCH_ci.json` for the CI
//! workflow to upload as an artifact, so the perf trajectory is tracked
//! per PR.
//!
//!     cargo bench --bench bench_ci
//!
//! The connection-churn section opens ~1k concurrent sockets (plus the
//! server's own); run it under `ulimit -n 8192` (the CI workflow does).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use duetserve::config::{Policy, ServingConfig};
use duetserve::engine::{
    engine_for, router_by_name, ClusterEngine, PlannerMode, ReplicatedEngine, RoundRobinRouter,
    ServingTopology, TopologyStep,
};
use duetserve::metrics::{Recorder, RecorderMode, Report};
use duetserve::request::{Request, SloClass};
use duetserve::server::http::{HttpConfig, HttpServer};
use duetserve::server::{Server, ServerCore};
use duetserve::util::json::Json;
use duetserve::util::tablefmt::banner;
use duetserve::workload::sessions::shared_prefix_workload;
use duetserve::workload::synthetic::{burst_mix_workload, fixed_workload, BurstProfile};
use duetserve::workload::Workload;

/// Mean µs per call of `f` over `iters` runs (after `warmup`).
fn time_us<T>(warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let t = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    t.elapsed().as_secs_f64() / iters as f64 * 1e6
}

/// A recorder loaded with `n` synthetic finished requests (3 tokens ⇒
/// 2 tbt gaps each, plus ttft/e2e samples).
fn loaded_recorder(mode: RecorderMode, n: u64) -> Recorder {
    let mut rec = Recorder::with_mode(mode);
    for i in 0..n {
        let mut r = Request::new(i, 0.0, 64, 3);
        r.advance_prefill(64);
        let base = 0.05 + (i % 1000) as f64 * 1e-4;
        r.advance_decode(base);
        r.advance_decode(base + 0.02 + (i % 97) as f64 * 1e-4);
        r.advance_decode(base + 0.05 + (i % 53) as f64 * 1e-4);
        rec.record_finished(&r);
    }
    rec.duration = n as f64 * 0.1;
    rec
}

/// The live `/metrics` path per scrape: non-destructive snapshot (clone)
/// + report build.
fn scrape_us(rec: &Recorder) -> f64 {
    time_us(3, 30, || {
        let snap = rec.clone();
        snap.report("scrape")
    })
}

/// Cluster event-loop throughput at fleet size `n`: inject a synthetic
/// workload into an N-replica cluster and drive `step_next` to
/// `Exhausted`, returning (steps/s, total steps). `naive` pins the
/// retained O(N)-scan reference path; the default is the heap-driven
/// event queue + incremental load board. The trajectory is identical
/// either way (property-tested in `tests/fleet_hotpath.rs`), so the two
/// runs do the same number of steps and the ratio isolates coordinator
/// cost.
fn fleet_steps_per_s(n: u32, naive: bool) -> (f64, u64) {
    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let mut cluster =
        ClusterEngine::replicated(cfg, n, 0xF1EE7, Box::new(RoundRobinRouter::new()));
    cluster.set_naive_scan(naive);
    let requests = 2 * n as usize;
    let w = fixed_workload(requests, 512, 8, n as f64 * 8.0, 0xC1);
    for r in w.sorted_by_arrival().requests {
        cluster.inject(r);
    }
    let t = Instant::now();
    let mut steps = 0u64;
    loop {
        match cluster.step_next(None) {
            TopologyStep::Exhausted | TopologyStep::Diverged(_) => break,
            _ => steps += 1,
        }
    }
    let secs = t.elapsed().as_secs_f64();
    let rep = ServingTopology::fold_report(&mut cluster);
    assert_eq!(
        rep.completed, requests as u64,
        "fleet bench (n={n}, naive={naive}) did not complete its workload"
    );
    (steps as f64 / secs, steps)
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// HTTP front door over a 1-replica sim engine for the connection-churn
/// rows. `pool_workers = 0` selects the thread-per-connection baseline.
fn churn_server(pool_workers: usize) -> HttpServer {
    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let server = Server::start(move || Ok(ServerCore::sim(cfg, 0xD00D).with_queue_depth(64)))
        .expect("engine server for churn bench");
    HttpServer::start(
        "127.0.0.1:0",
        server,
        HttpConfig {
            pool_workers,
            ..Default::default()
        },
    )
    .expect("http server for churn bench")
}

/// Read one `Content-Length`-framed response off a kept-alive socket.
fn churn_read_response(r: &mut BufReader<TcpStream>) -> std::io::Result<()> {
    let mut content_len = 0usize;
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed inside response head",
            ));
        }
        let t = line.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body)?;
    Ok(())
}

/// Drive `GET /healthz` from `threads` client threads holding
/// `per_thread` concurrent connections each. With `keep_alive` every
/// socket is opened once and reused across `rounds` (one in-flight
/// request per socket per round, written as a batch so the server sees
/// all connections active at once); without it every request pays a
/// fresh TCP connect + `Connection: close` — the churn the pooled front
/// door is built to avoid. Returns (requests/s, p99 latency ms, count).
fn conn_churn_run(
    addr: SocketAddr,
    threads: usize,
    per_thread: usize,
    rounds: usize,
    keep_alive: bool,
) -> (f64, f64, usize) {
    let t0 = Instant::now();
    let joins: Vec<_> = (0..threads)
        .map(|_| {
            std::thread::spawn(move || -> Vec<f64> {
                let mut lat = Vec::with_capacity(per_thread * rounds);
                if keep_alive {
                    let req: &[u8] = b"GET /healthz HTTP/1.1\r\nHost: bench\r\n\r\n";
                    let mut socks: Vec<(BufReader<TcpStream>, Instant)> = (0..per_thread)
                        .map(|_| {
                            let s = TcpStream::connect(addr).expect("churn connect");
                            s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                            s.set_nodelay(true).ok();
                            (BufReader::new(s), Instant::now())
                        })
                        .collect();
                    for _ in 0..rounds {
                        for (s, t) in socks.iter_mut() {
                            *t = Instant::now();
                            s.get_mut().write_all(req).expect("churn write");
                        }
                        for (s, t) in socks.iter_mut() {
                            churn_read_response(s).expect("churn framed response");
                            lat.push(t.elapsed().as_secs_f64());
                        }
                    }
                } else {
                    let req: &[u8] =
                        b"GET /healthz HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\r\n";
                    for _ in 0..rounds * per_thread {
                        let t = Instant::now();
                        let mut s = TcpStream::connect(addr).expect("churn connect");
                        s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                        s.write_all(req).expect("churn write");
                        let mut buf = Vec::new();
                        s.read_to_end(&mut buf).expect("churn read");
                        lat.push(t.elapsed().as_secs_f64());
                    }
                }
                lat
            })
        })
        .collect();
    let mut lats: Vec<f64> = joins
        .into_iter()
        .flat_map(|j| j.join().expect("churn client thread"))
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(f64::total_cmp);
    let n = lats.len();
    (n as f64 / wall.max(1e-9), percentile(&lats, 0.99) * 1e3, n)
}

/// One prefix-cache hit-rate sweep point: 48 shared-prefix requests
/// (constant 2048-token prompts split `shared`+`unique`) over a 2-worker
/// replicated cluster with the prefix cache on, routed by `router`.
/// Returns the JSON row plus the TTFT p50 and computed-prefill-token
/// figures the guardrails compare across hit rates.
fn prefix_sweep_point(shared: u64, unique: u64, router: &str) -> (Json, f64, u64) {
    let cfg = ServingConfig::default_8b()
        .with_policy(Policy::VllmChunked)
        .with_prefix_cache(true);
    // Low qps + short outputs: turns finish before the next same-tenant
    // arrival, so decayed blocks are actually there to hit.
    let w = shared_prefix_workload(48, shared, unique, 16, 2.0, 2, 0xCA_FE);
    let mut e = ReplicatedEngine::new(cfg, 2, 7)
        .with_router(router_by_name(router).expect("known router"));
    let rep = e.run(w);
    assert_eq!(
        rep.completed, 48,
        "prefix sweep ({shared}+{unique}, {router}) did not complete"
    );
    let mut ttfts: Vec<f64> = e.finished.iter().filter_map(|r| r.ttft()).collect();
    ttfts.sort_by(f64::total_cmp);
    let p50 = percentile(&ttfts, 0.50);
    let p99 = percentile(&ttfts, 0.99);
    let row = Json::obj(vec![
        ("hit_rate", Json::Num(shared as f64 / (shared + unique) as f64)),
        ("router", Json::string(router)),
        ("ttft_p50_s", Json::Num(p50)),
        ("ttft_p99_s", Json::Num(p99)),
        ("token_throughput", Json::Num(rep.token_throughput)),
        ("prefix_hits", Json::Num(rep.prefix_hits as f64)),
        ("prefix_cached_tokens", Json::Num(rep.prefix_cached_tokens as f64)),
        ("prefilled_tokens", Json::Num(rep.prefilled_tokens as f64)),
    ]);
    (row, p50, rep.prefilled_tokens)
}

/// Mixed-class goodput workload for the QoS guardrail: a burst of long
/// batch-class prompts contending with a stream of short latency-class
/// requests that declare a 40 ms TBT SLO. The SLO sits between the
/// decode-only iteration time (a few ms) and the 100 ms mixed-iteration
/// bound the config allows, so FCFS scheduling violates it whenever a
/// batch prefill chunk shares the iteration, while QoS preemption
/// (tightened effective SLO + lower-class prefill shed) keeps latency
/// decodes under it.
fn goodput_workload() -> Workload {
    let mut requests = Vec::new();
    let mut id = 0u64;
    for i in 0..40u64 {
        requests.push(Request::new(id, i as f64 * 0.15, 4096, 32).with_class(SloClass::Batch));
        id += 1;
    }
    for i in 0..24u64 {
        requests.push(
            Request::new(id, 0.05 + i as f64 * 0.25, 256, 64)
                .with_class(SloClass::Latency)
                .with_slo_tbt(0.040),
        );
        id += 1;
    }
    Workload {
        name: "goodput-mix".into(),
        requests,
    }
    .sorted_by_arrival()
}

/// Burst-mix profile for the elastic-planner rows: a 40 s stream of short
/// latency-class chats overlaid with 10 s windows of 12k-token batch
/// prefills every 25 s. Both static shapes lose somewhere: the unified
/// fleet inflates short-request TBT whenever a long chunk shares an
/// iteration, the static disagg fleet queues shorts' prefills behind the
/// burst on its two permanent prefill workers.
fn elastic_bench_profile() -> BurstProfile {
    BurstProfile {
        shorts: 200,
        short_isl: 256,
        short_osl: 64,
        short_qps: 5.0,
        short_slo_ttft: 2.0,
        short_slo_tbt: 0.05,
        longs: 40,
        long_isl: 12_000,
        long_osl: 8,
        long_qps: 3.0,
        period_s: 25.0,
        burst_s: 10.0,
        diurnal: false,
    }
}

/// Serve the burst mix on a 4-GPU fleet of the named shape and return its
/// report. All three shapes run the identical workload, policy, seed and
/// (conditional) router — only the role topology and the planner differ,
/// so the contrast isolates what elastic re-roling buys. Engine-clock
/// metrics only; CI wall-clock noise cannot touch the guardrails.
fn elastic_bench_fleet(kind: &str) -> Report {
    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let p = elastic_bench_profile();
    let w = burst_mix_workload(&p, 0xE1A5);
    let n = w.requests.len() as u64;
    let router = router_by_name("conditional").expect("conditional router");
    let mut cluster = match kind {
        "static-unified" => ClusterEngine::replicated(cfg, 4, 0xE1A5, router),
        "static-disagg" => ClusterEngine::disagg(cfg, 2, 2, 0xE1A5, router),
        "elastic" => {
            let mut c = ClusterEngine::replicated(cfg, 4, 0xE1A5, router);
            // Fast flips on a short bench horizon: plan every 2 s, 1 s of
            // re-role downtime (the CLI defaults are sized for minutes).
            c.reconfig_s = 1.0;
            c.set_planner(PlannerMode::Elastic);
            c.set_planner_interval(2.0);
            c
        }
        _ => unreachable!("unknown fleet kind {kind}"),
    };
    let rep = cluster.run(w);
    assert_eq!(
        rep.completed, n,
        "elastic bench fleet `{kind}` did not complete its workload"
    );
    rep
}

/// DistServe-style goodput: latency-class requests per engine-second that
/// met every declared SLO.
fn elastic_goodput(rep: &Report) -> f64 {
    let c = rep.class(SloClass::Latency);
    c.attainment().unwrap_or(0.0) * c.completed as f64 / rep.duration.max(1e-9)
}

fn main() {
    banner("CI bench: throughput row + scrape-cost demonstration");

    // Fig2-style row (small: one qps point, CI budget).
    let qps = 6.0;
    let w = fixed_workload(60, 8000, 200, qps, 0xF16_2);
    let mut agg = ReplicatedEngine::new(
        ServingConfig::default_8b().with_policy(Policy::VllmChunked),
        2,
        1,
    );
    let ra = agg.run(w);
    let mut duet = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 1);
    let t0 = Instant::now();
    let rd = duet.run(fixed_workload(60, 4096, 64, 8.0, 5));
    let duet_wall = t0.elapsed().as_secs_f64();

    // Scrape latency before/after N = 100k synthetic samples, both
    // recorder modes. Streaming must stay flat (O(1) in samples); exact
    // grows with history — the contrast the acceptance criterion asks
    // CI to demonstrate.
    let n_small = 1_000u64;
    let n_large = 100_000u64;
    let stream_small = scrape_us(&loaded_recorder(RecorderMode::Streaming, n_small));
    let stream_large = scrape_us(&loaded_recorder(RecorderMode::Streaming, n_large));
    let exact_small = scrape_us(&loaded_recorder(RecorderMode::Exact, n_small));
    let exact_large = scrape_us(&loaded_recorder(RecorderMode::Exact, n_large));
    let stream_ratio = stream_large / stream_small.max(1e-9);
    let exact_ratio = exact_large / exact_small.max(1e-9);

    // Fleet-scale cluster event loop: steps/s at N=8 and N=256 replicas,
    // heap-driven event queue vs the retained naive O(N)-scan reference
    // on the byte-identical trajectory.
    let (heap_n8, steps_n8) = fleet_steps_per_s(8, false);
    let (naive_n8, steps_n8_naive) = fleet_steps_per_s(8, true);
    let (heap_n256, steps_n256) = fleet_steps_per_s(256, false);
    let (naive_n256, steps_n256_naive) = fleet_steps_per_s(256, true);
    assert_eq!(
        steps_n8, steps_n8_naive,
        "heap and naive paths diverged at N=8"
    );
    assert_eq!(
        steps_n256, steps_n256_naive,
        "heap and naive paths diverged at N=256"
    );
    let fleet_speedup_n8 = heap_n8 / naive_n8.max(1e-9);
    let fleet_speedup_n256 = heap_n256 / naive_n256.max(1e-9);

    // Prefix-cache hit-rate sweep: TTFT/throughput at hit rates ~0, ~0.5
    // and ~0.9 (block-aligned shared/unique splits of a constant
    // 2048-token prompt), cache-aware kv-overlap routing vs round-robin.
    let mut sweep_rows = Vec::new();
    let mut overlap_points = Vec::new(); // (shared, ttft_p50, prefilled) per hit rate
    for &(shared, unique) in &[(0u64, 2048u64), (1024, 1024), (1840, 208)] {
        for router in ["kv-overlap", "round-robin"] {
            let (row, p50, prefilled) = prefix_sweep_point(shared, unique, router);
            if router == "kv-overlap" {
                overlap_points.push((shared, p50, prefilled));
            }
            sweep_rows.push(row);
        }
    }

    // Per-class goodput: the same mixed-class burst served by the duet
    // scheduler with QoS preemption on vs off (off = the class-blind
    // FCFS baseline, the pre-QoS behavior). Engine-clock metrics only, so
    // CI wall-clock noise cannot touch the guardrails.
    let gw = goodput_workload();
    let mut qos_engine = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 11);
    let rq = qos_engine.run(gw.clone());
    let mut fcfs_engine = engine_for(
        ServingConfig::default_8b()
            .with_policy(Policy::Duet)
            .with_qos(false),
        11,
    );
    let rf = fcfs_engine.run(gw);
    assert_eq!(rq.completed, 64, "goodput QoS run did not complete");
    assert_eq!(rf.completed, 64, "goodput FCFS run did not complete");
    let qos_lat_att = rq.class(SloClass::Latency).attainment().unwrap_or(0.0);
    let fcfs_lat_att = rf.class(SloClass::Latency).attainment().unwrap_or(0.0);

    // Elastic role planning: the same burst mix on three same-size fleets.
    let re_uni = elastic_bench_fleet("static-unified");
    let re_dis = elastic_bench_fleet("static-disagg");
    let re_ela = elastic_bench_fleet("elastic");
    let gp_uni = elastic_goodput(&re_uni);
    let gp_dis = elastic_goodput(&re_dis);
    let gp_ela = elastic_goodput(&re_ela);

    // Connection churn: ~1k concurrent keep-alive sockets against the
    // readiness-polled pool vs a fresh TCP connect + `Connection: close`
    // per request against the thread-per-connection baseline. Unix-only:
    // elsewhere the pool front door falls back to thread-per-connection
    // and there is no contrast to measure.
    let churn_threads = 16usize;
    let churn_per_thread = 64usize;
    let churn_concurrent = churn_threads * churn_per_thread;
    let (pool_rps, pool_p99_ms, pool_n, base_rps, base_p99_ms, base_n) = if cfg!(unix) {
        let pooled = churn_server(4);
        let (rps, p99, n) =
            conn_churn_run(pooled.addr(), churn_threads, churn_per_thread, 4, true);
        pooled.shutdown().expect("pooled churn shutdown");
        let baseline = churn_server(0);
        let (brps, bp99, bn) =
            conn_churn_run(baseline.addr(), churn_threads, churn_per_thread, 1, false);
        baseline.shutdown().expect("baseline churn shutdown");
        (rps, p99, n, brps, bp99, bn)
    } else {
        (0.0, 0.0, 0, 0.0, 0.0, 0)
    };
    let churn_speedup = pool_rps / base_rps.max(1e-9);

    println!(
        "agg 2x vLLM @qps {qps}: {:.0} tok/s, tbt-p99 {:.1} ms | duet: {:.0} it/s, {:.1} µs sched",
        ra.token_throughput,
        ra.tbt_p99 * 1e3,
        rd.iterations as f64 / duet_wall,
        rd.sched_overhead_per_iter * 1e6,
    );
    println!(
        "scrape µs @1k/@100k samples — streaming: {stream_small:.1}/{stream_large:.1} \
         (x{stream_ratio:.2}), exact: {exact_small:.1}/{exact_large:.1} (x{exact_ratio:.2})"
    );
    println!(
        "fleet steps/s — N=8: heap {heap_n8:.0} vs naive {naive_n8:.0} \
         (x{fleet_speedup_n8:.1}), N=256: heap {heap_n256:.0} vs naive {naive_n256:.0} \
         (x{fleet_speedup_n256:.1}, {steps_n256} steps)"
    );
    println!(
        "prefix sweep (kv-overlap) ttft p50: {:.1} ms @hit 0 -> {:.1} ms @hit 0.9; \
         prefilled tokens {} -> {}",
        overlap_points[0].1 * 1e3,
        overlap_points[2].1 * 1e3,
        overlap_points[0].2,
        overlap_points[2].2,
    );
    println!(
        "conn churn @{churn_concurrent} conns — pool: {pool_rps:.0} req/s \
         (p99 {pool_p99_ms:.2} ms, n={pool_n}) vs thread-per-conn: {base_rps:.0} req/s \
         (p99 {base_p99_ms:.2} ms, n={base_n}), x{churn_speedup:.1}"
    );
    println!(
        "goodput (latency-class attainment) — qos: {:.0}% vs fcfs: {:.0}%; \
         tok/s {:.0} vs {:.0}; {} qos preemptions",
        qos_lat_att * 100.0,
        fcfs_lat_att * 100.0,
        rq.token_throughput,
        rf.token_throughput,
        rq.qos_preemptions,
    );
    println!(
        "elastic burst mix (latency goodput req/s) — elastic: {gp_ela:.2} \
         ({} reconfigs, occupancy u/p/d {:.0}/{:.0}/{:.0}s) vs \
         static-unified: {gp_uni:.2} vs static-disagg: {gp_dis:.2}",
        re_ela.reconfigs,
        re_ela.role_occupancy[0],
        re_ela.role_occupancy[1],
        re_ela.role_occupancy[2],
    );

    let out = Json::obj(vec![
        (
            "fig2_point",
            Json::obj(vec![
                ("qps", Json::Num(qps)),
                ("agg_token_throughput", Json::Num(ra.token_throughput)),
                ("agg_tbt_p99_ms", Json::Num(ra.tbt_p99 * 1e3)),
                ("agg_ttft_mean_s", Json::Num(ra.ttft.mean)),
                ("agg_completed", Json::Num(ra.completed as f64)),
            ]),
        ),
        (
            "hotpath",
            Json::obj(vec![
                (
                    "duet_iters_per_s",
                    Json::Num(rd.iterations as f64 / duet_wall),
                ),
                (
                    "duet_sched_overhead_us_per_iter",
                    Json::Num(rd.sched_overhead_per_iter * 1e6),
                ),
                ("duet_tbt_p99_ms", Json::Num(rd.tbt_p99 * 1e3)),
            ]),
        ),
        (
            "fleet",
            Json::obj(vec![
                ("n_small", Json::Num(8.0)),
                ("n_large", Json::Num(256.0)),
                ("heap_steps_per_s_n8", Json::Num(heap_n8)),
                ("naive_steps_per_s_n8", Json::Num(naive_n8)),
                ("heap_steps_per_s_n256", Json::Num(heap_n256)),
                ("naive_steps_per_s_n256", Json::Num(naive_n256)),
                ("speedup_n8", Json::Num(fleet_speedup_n8)),
                ("speedup_n256", Json::Num(fleet_speedup_n256)),
                ("steps_n256", Json::Num(steps_n256 as f64)),
            ]),
        ),
        (
            "conn_churn",
            Json::obj(vec![
                ("concurrent", Json::Num(churn_concurrent as f64)),
                ("pool_rps", Json::Num(pool_rps)),
                ("pool_p99_ms", Json::Num(pool_p99_ms)),
                ("pool_requests", Json::Num(pool_n as f64)),
                ("baseline_rps", Json::Num(base_rps)),
                ("baseline_p99_ms", Json::Num(base_p99_ms)),
                ("baseline_requests", Json::Num(base_n as f64)),
                ("speedup", Json::Num(churn_speedup)),
            ]),
        ),
        (
            "prefix_sweep",
            Json::obj(vec![("rows", Json::arr(sweep_rows))]),
        ),
        (
            "goodput",
            Json::obj(vec![
                ("qos_latency_attainment", Json::Num(qos_lat_att)),
                ("fcfs_latency_attainment", Json::Num(fcfs_lat_att)),
                ("qos_token_throughput", Json::Num(rq.token_throughput)),
                ("fcfs_token_throughput", Json::Num(rf.token_throughput)),
                ("qos_preemptions", Json::Num(rq.qos_preemptions as f64)),
                (
                    "qos_batch_completed",
                    Json::Num(rq.class(SloClass::Batch).completed as f64),
                ),
            ]),
        ),
        (
            "elastic",
            Json::obj(vec![
                ("elastic_goodput", Json::Num(gp_ela)),
                ("static_unified_goodput", Json::Num(gp_uni)),
                ("static_disagg_goodput", Json::Num(gp_dis)),
                (
                    "elastic_latency_attainment",
                    Json::Num(re_ela.class(SloClass::Latency).attainment().unwrap_or(0.0)),
                ),
                ("reconfigs", Json::Num(re_ela.reconfigs as f64)),
                (
                    "prefill_occupancy_s",
                    Json::Num(re_ela.role_occupancy[1]),
                ),
                (
                    "decode_occupancy_s",
                    Json::Num(re_ela.role_occupancy[2]),
                ),
                (
                    "advantage_vs_unified",
                    Json::Num(gp_ela / gp_uni.max(1e-9)),
                ),
                (
                    "advantage_vs_disagg",
                    Json::Num(gp_ela / gp_dis.max(1e-9)),
                ),
            ]),
        ),
        (
            "scrape_latency",
            Json::obj(vec![
                ("n_small", Json::Num(n_small as f64)),
                ("n_large", Json::Num(n_large as f64)),
                ("streaming_us_small", Json::Num(stream_small)),
                ("streaming_us_large", Json::Num(stream_large)),
                ("streaming_ratio", Json::Num(stream_ratio)),
                ("exact_us_small", Json::Num(exact_small)),
                ("exact_us_large", Json::Num(exact_large)),
                ("exact_ratio", Json::Num(exact_ratio)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_ci.json", out.dump()).expect("write BENCH_ci.json");
    println!("wrote BENCH_ci.json");

    // Guardrail, not a flaky threshold: a streaming scrape after 100k
    // samples must not cost 100× a 1k-sample scrape (it is O(sketch),
    // not O(samples)); generous bound so CI noise cannot trip it.
    assert!(
        stream_ratio < 20.0,
        "streaming scrape cost grew with samples: x{stream_ratio:.1}"
    );

    // Guardrail for the fleet hot path: at N=256 the heap-driven event
    // queue must beat the retained O(N)-scan reference by ≥5× on the
    // identical trajectory. The measured gap is far larger (the naive
    // path pays several O(N) fleet scans plus three Vec allocations per
    // event), so CI noise cannot trip this.
    assert!(
        fleet_speedup_n256 >= 5.0,
        "N=256 fleet event loop only x{fleet_speedup_n256:.1} over naive scan (need >= 5)"
    );

    // Keep-alive front-door guardrail (unix only — elsewhere the pool
    // falls back to thread-per-connection and the contrast vanishes): at
    // ~1k concurrent connections the readiness-polled pool must serve at
    // least 5× the requests/s of per-request connection churn. The
    // measured gap is far larger (no connect, teardown, or thread spawn
    // per request, and ~1k requests in flight at once vs at most one per
    // client thread), so CI noise cannot trip this.
    if cfg!(unix) {
        assert!(
            churn_speedup >= 5.0,
            "pool only x{churn_speedup:.1} over per-request connection churn (need >= 5)"
        );
    }

    // Prefix-cache guardrails (engine-clock metrics, so CI wall-clock
    // noise cannot touch them): with 90% of every prompt cacheable and
    // kv-overlap routing, TTFT p50 must strictly improve over the
    // disjoint-prompt baseline, and the prefill volume actually computed
    // must drop by at least the cached-prefix fraction (here: to ≤25%,
    // leaving generous room for the per-tenant cold misses).
    // Goodput guardrails (engine-clock, deterministic workload + seed, so
    // CI noise cannot trip them): QoS preemption must strictly improve
    // latency-class SLO attainment over the class-blind FCFS baseline —
    // the 40 ms TBT SLO is violated by 100 ms mixed iterations and
    // protected by the tightened effective SLO — while total token
    // throughput stays within 10% (deferred batch prefill catches up in
    // the latency-free tail).
    assert!(
        qos_lat_att > fcfs_lat_att,
        "QoS latency-class attainment {qos_lat_att:.3} must strictly beat FCFS {fcfs_lat_att:.3}"
    );
    assert!(
        rq.token_throughput >= 0.9 * rf.token_throughput,
        "QoS token throughput {:.0} fell more than 10% below FCFS {:.0}",
        rq.token_throughput,
        rf.token_throughput
    );

    // Elastic-planner guardrails (engine-clock, deterministic workload +
    // seed): on the burst mix, elastic re-roling must strictly beat both
    // same-size static fleets on latency-class goodput — the unified
    // fleet pollutes short-request TBT with long prefill chunks, the
    // static disagg fleet strands half its GPUs between bursts and queues
    // short prefills behind the burst during them — and it must actually
    // have re-roled workers to get there.
    assert!(
        gp_ela > gp_uni,
        "elastic goodput {gp_ela:.3} must strictly beat static-unified {gp_uni:.3}"
    );
    assert!(
        gp_ela > gp_dis,
        "elastic goodput {gp_ela:.3} must strictly beat static-disagg {gp_dis:.3}"
    );
    assert!(
        re_ela.reconfigs > 0,
        "elastic fleet never re-roled a worker on the burst mix"
    );

    let (_, p50_cold, prefilled_cold) = overlap_points[0];
    let (_, p50_hot, prefilled_hot) = overlap_points[2];
    assert!(
        p50_hot < p50_cold,
        "hit-rate 0.9 ttft p50 {p50_hot:.4}s must beat hit-rate 0 {p50_cold:.4}s"
    );
    assert!(
        prefilled_hot * 4 <= prefilled_cold,
        "prefill volume must drop with the cached fraction: {prefilled_hot} vs {prefilled_cold}"
    );
}
