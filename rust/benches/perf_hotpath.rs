//! §Perf — hot-path microbenchmarks for the L3 coordinator.
//!
//! Times the pieces that sit on the per-iteration critical path:
//! roofline prediction, the Algorithm-1 partition solve, chunked-batch
//! construction, one simulated-executor forward, and whole engine
//! iterations. Results + the optimization log live in EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench perf_hotpath

use std::time::Instant;

use duetserve::config::{GpuSpec, ModelSpec, Policy, ServingConfig};
use duetserve::engine::{engine_for, ClusterEngine, RoundRobinRouter, TopologyStep};
use duetserve::model::AttnShape;
use duetserve::roofline::{BatchShape, Predictor};
use duetserve::sched::optimize_partition;
use duetserve::sim::{DispatchMode, GpuExecutor};
use duetserve::util::stats::Summary;
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::synthetic::fixed_workload;

/// µs per cluster event (`step_next`) draining a synthetic workload at
/// fleet size `n` — heap-driven event queue vs the retained naive-scan
/// reference over the identical event trajectory.
fn cluster_step_us(n: u32, naive: bool) -> f64 {
    let cfg = ServingConfig::default_8b().with_policy(Policy::VllmChunked);
    let mut cluster =
        ClusterEngine::replicated(cfg, n, 0xF1EE7, Box::new(RoundRobinRouter::new()));
    cluster.set_naive_scan(naive);
    let w = fixed_workload(2 * n as usize, 512, 8, n as f64 * 8.0, 0xC1);
    for r in w.sorted_by_arrival().requests {
        cluster.inject(r);
    }
    let t = Instant::now();
    let mut steps = 0u64;
    loop {
        match cluster.step_next(None) {
            TopologyStep::Exhausted | TopologyStep::Diverged(_) => break,
            _ => steps += 1,
        }
    }
    t.elapsed().as_secs_f64() / steps.max(1) as f64 * 1e6
}

/// Time `f` over `iters` runs (after `warmup`), returning per-call stats.
fn time_it<T>(warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64() * 1e6); // µs
    }
    Summary::of(&samples)
}

fn main() {
    banner("§Perf: L3 hot-path microbenchmarks (all times in µs/call)");
    let model = ModelSpec::qwen3_8b();
    let gpu = GpuSpec::h100();
    let pred = Predictor::new(model.clone(), gpu.clone(), 1);
    let mut exec = GpuExecutor::new(model.clone(), gpu.clone(), 1, 1);

    let decode_big =
        BatchShape::from_shapes((0..256).map(|i| AttnShape { q: 1, c: 2048 + i * 8 }).collect());
    let prefill = BatchShape::from_shapes(vec![AttnShape { q: 8192, c: 0 }]);
    let mixed = {
        let mut s = decode_big.shapes.clone();
        s.extend(prefill.shapes.iter().copied());
        BatchShape::from_shapes(s)
    };

    let mut t = Table::new(vec!["path", "mean", "p50", "p99", "max"]);
    let mut bench = |name: &str, s: Summary| {
        t.row(vec![
            name.to_string(),
            format!("{:.1}", s.mean),
            format!("{:.1}", s.p50),
            format!("{:.1}", s.p99),
            format!("{:.1}", s.max),
        ]);
    };

    bench(
        "roofline predict (256-req mixed batch)",
        time_it(50, 500, || pred.predict_total(&mixed, 132)),
    );
    bench(
        "algorithm-1 solve (256 dec + 8K prefill)",
        time_it(20, 200, || {
            optimize_partition(&pred, &decode_big, &prefill, 0.1, 16)
        }),
    );
    bench(
        "sim executor forward (mixed batch)",
        time_it(20, 200, || {
            exec.run(&mixed, 132, DispatchMode::Eager, None)
        }),
    );

    // Fleet coordinator cost: µs per cluster event at N=8 and N=256
    // replicas, heap event queue vs the retained naive O(N)-scan
    // reference (same trajectory; the gap is pure coordinator overhead).
    for n in [8u32, 256] {
        bench(
            &format!("cluster step_next N={n} (heap queue)"),
            Summary::of(&[cluster_step_us(n, false)]),
        );
        bench(
            &format!("cluster step_next N={n} (naive scan)"),
            Summary::of(&[cluster_step_us(n, true)]),
        );
    }

    // Whole-engine iteration throughput: iterations/second of simulated
    // serving (scheduling + bookkeeping per simulated iteration).
    let t0 = Instant::now();
    let mut e = engine_for(ServingConfig::default_8b().with_policy(Policy::Duet), 1);
    let rep = e.run(fixed_workload(120, 4096, 64, 12.0, 5));
    let wall = t0.elapsed().as_secs_f64();
    bench(
        "full engine iteration (duet, incl sched)",
        Summary::of(&[wall / rep.iterations as f64 * 1e6]),
    );
    t.print();
    println!(
        "\nengine: {} iterations ({} spatial) simulated in {:.2}s wall = {:.0} iters/s",
        rep.iterations,
        rep.spatial_iterations,
        wall,
        rep.iterations as f64 / wall
    );
    println!(
        "CPU scheduling overhead per iteration: {:.1} µs (paper budget: <1 ms)",
        rep.sched_overhead_per_iter * 1e6
    );
}
