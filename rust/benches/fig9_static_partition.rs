//! Figure 9 (Appendix A) — static SM partitioning ablation: fixed
//! Sd22-Sp44 / Sd33-Sp33 / Sd44-Sp22 TPC splits vs DuetServe's adaptive
//! partitioning, across the three workloads, Qwen3-8B (TP=1) and
//! Qwen3-14B (TP=2).
//!
//! Paper shape: throughput varies across workloads for every static split
//! (persistent imbalance) while adaptive DuetServe wins or matches the
//! best static split on each workload.
//!
//!     cargo bench --bench fig9_static_partition

use duetserve::config::{ModelSpec, Policy, ServingConfig};
use duetserve::engine::engine_for;
use duetserve::util::tablefmt::{banner, Table};
use duetserve::workload::traces::{generate, TraceKind};

fn main() {
    let quick = std::env::var("DUET_BENCH_QUICK").is_ok();
    let n = if quick { 100 } else { 250 };
    let configs: &[(&str, ModelSpec, u32)] = &[
        ("Qwen3-8B TP=1", ModelSpec::qwen3_8b(), 1),
        ("Qwen3-14B TP=2", ModelSpec::qwen3_14b(), 2),
    ];
    let policies = [
        Policy::StaticPartition { decode_tpcs: 22, prefill_tpcs: 44 },
        Policy::StaticPartition { decode_tpcs: 33, prefill_tpcs: 33 },
        Policy::StaticPartition { decode_tpcs: 44, prefill_tpcs: 22 },
        Policy::Duet,
    ];
    // Saturating QPS per trace (same spirit as the paper's peak-load bars).
    let traces = [
        (TraceKind::AzureCode, 12.0),
        (TraceKind::AzureConv, 12.0),
        (TraceKind::Mooncake, 4.0),
    ];
    for (label, model, tp) in configs {
        banner(&format!("Fig 9: throughput (req/s), {label}"));
        let base = ServingConfig::default_8b().with_model(model.clone(), *tp);
        let mut t = Table::new(vec![
            "policy",
            "Azure-Code",
            "Azure-Conv",
            "Mooncake",
        ]);
        for policy in &policies {
            let mut row = vec![policy.name()];
            for (trace, qps) in &traces {
                let w = generate(*trace, Some(n), *qps, 99);
                let mut e = engine_for(base.clone().with_policy(policy.clone()), 1);
                let rep = e.run(w);
                row.push(format!("{:.2}", rep.throughput_rps));
            }
            t.row(row);
        }
        t.print();
    }
    println!(
        "\n(paper: no static split wins everywhere — adaptive reallocation\n\
         avoids the idle-compute vs congestion imbalance)"
    );
}
